"""Continuous-batching inference engine over the slot-pooled routing cache.

Request lifecycle::

    WAITING --admit (free slot + token budget)--> PREFILL
    PREFILL --first token sampled, lane written--> DECODE
    PREFILL --preempted mid-stages (chunked)----> PARKED (partial dropped,
                                                  request requeued)
    DECODE  --eos_id / max_new_tokens----------->  FINISHED (lane reset,
                                                   slot returned to pool)
    DECODE  --park (preempted / time-sliced / handle.park())--> PARKED
    PARKED  --readmitted, lane streamed back----> DECODE (any free slot)
    PARKED  --export_session (disaggregation)---> EXPORTED (lane + request
                                                  state shipped through a
                                                  transport blob; a peer
                                                  engine's import_session
                                                  continues the decode
                                                  bit-exact)

Each engine ``step()``:

  1. admit: pop admittable requests (priority-then-FCFS, see the named
     PRIORITY_* classes in scheduler.py) and place each into a free
     lane — fresh requests prefill (one jitted prefill per request at its
     exact prompt length; distinct lengths compile once and are cached by
     jit), parked requests stream their saved lane back from the KV
     store. When slots are full, the admission path parks the
     lowest-priority active session (preferring a mid-prefill job, which
     has produced nothing yet and just requeues), or time-slices the
     oldest one, to the tiered KV store instead of blocking, so sessions
     ≫ slots all make progress. The first output token of a fresh request
     is sampled from the prefill logits; with a PrefixCache attached, an
     exact prompt match skips the model call entirely.
  2. chunked prefill (``chunked_prefill=N``): admission only runs the
     embed stage and enqueues a _PrefillJob; each step then advances at
     most N depth stages (serving.make_prefill_stages, one scan group
     per stage) across the outstanding jobs, oldest first, so a long
     prompt's prefill interleaves with step 3 instead of head-of-line-
     blocking active decodes. With ``chunked_prefill=None`` (default)
     prefill completes at admission in one jitted call.
  3. decode: ONE jitted ``serve_step`` over ALL pool slots with a per-slot
     active mask — free/finished lanes are exact no-ops, so requests at
     different positions, prompt lengths, and sampling settings share the
     batch. Per-slot sampling is a second jitted call.
  4. retire: finished requests free their lane (``reset_slot``) so the next
     admission reuses it without reallocation.

Because every lane is computed independently and sampling keys are
counter-based per request, a request's outputs are bit-identical no matter
which slot it occupies, who its co-tenants are, or how many park/resume
round-trips it took (tested).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, with_overrides
from repro.obs import JsonlSink, pages_health
from repro.obs import routing_stats as obs_rt
from repro.obs.trace import span
from repro.serve.engine.metrics import EngineMetrics
from repro.serve.engine.pool import (init_pool, read_slot, reset_slot,
                                     write_slot)
from repro.serve.engine.scheduler import FCFSScheduler
from repro.serve.engine.sampling import (SamplingParams, request_base_key,
                                         request_key, sample_tokens)
from repro.serve.kvstore import KVStore, PrefixCache, StoreConfig
from repro.serve.serving import (assemble_prefill_cache, decode_backends,
                                 decode_cache_layouts, init_cache,
                                 make_prefill_stages, make_serve_step,
                                 prefill, slice_cache_groups)

WAITING, PREFILL, DECODE, FINISHED = "WAITING", "PREFILL", "DECODE", "FINISHED"
PARKED, CANCELLED, EXPORTED = "PARKED", "CANCELLED", "EXPORTED"

# cache layouts whose prefill and decode write identical state for
# identical token streams — the gate for partial-prefix reuse (a cached
# shorter prefix + teacher-forced tail is bit-exact iff every layout in
# the stack is here; cluster-page layouts are not: prefill routes with
# balanced top-k, decode with argmax)
_PARTIAL_SAFE_LAYOUTS = frozenset({"append", "ring"})


@dataclass
class Request:
    uid: int
    prompt: Sequence[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival_step: int = 0       # engine step at which the request shows up
    priority: int = 0           # higher admits first and preempts lower
    state: str = WAITING
    output: List[int] = field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


class SessionHandle:
    """What ``Engine.submit`` returns: uid + state + park/resume/cancel.

    ``int(handle)`` is the uid, so existing uid-keyed code (metrics,
    output maps, PRNG streams) interoperates unchanged.
    """

    def __init__(self, engine: "InferenceEngine", request: Request):
        self._engine = engine
        self._request = request

    @property
    def uid(self) -> int:
        return self._request.uid

    def __int__(self) -> int:
        return self._request.uid

    __index__ = __int__

    @property
    def state(self) -> str:
        return {WAITING: "queued", PREFILL: "active", DECODE: "active",
                PARKED: "parked", FINISHED: "finished",
                CANCELLED: "cancelled", EXPORTED: "exported"}[
                    self._request.state]

    @property
    def output(self) -> List[int]:
        return list(self._request.output)

    def park(self) -> None:
        """Evict this session's lane to the KV store and hold it (it will
        not be rescheduled until ``resume()``)."""
        self._engine.park_session(self.uid)

    def resume(self) -> None:
        """Requeue a held (parked) session for readmission."""
        self._engine.resume_session(self.uid)

    def cancel(self) -> None:
        self._engine.cancel_session(self.uid)

    def __repr__(self) -> str:
        return f"SessionHandle(uid={self.uid}, state={self.state!r})"


@dataclass
class _Slot:
    request: Request
    pos: int                    # next decode position (= tokens in context)
    last_token: int
    base_key: np.ndarray        # request_base_key, host-side
    admit_seq: int = 0          # monotonic placement order (rotation age)
    tokens_at_admit: int = 0    # len(output) when (re)placed — time-slice


@dataclass
class _PrefillJob:
    """A mid-flight chunked prefill occupying a pool slot: activations
    after the last finished depth stage plus the cache chunks those
    stages produced. Parking or preempting a job drops the partial work
    and requeues the request — it has produced no tokens yet, so the
    cheap exit is to redo the prefill on readmission."""
    request: Request
    x: jax.Array                # (1, N, d) activations entering stage_idx
    positions: jax.Array
    chunks: List = field(default_factory=list)   # per-stage cache chunks
    stats: List = field(default_factory=list)    # per-stage routing stats
    stage_idx: int = 0
    admit_seq: int = 0
    t0: float = 0.0             # wall-clock at admission (TTFT accounting)


@dataclass
class _ParkedMeta:
    """Host-side decode state of a parked session (the lane itself lives
    in the KV store). ``pos is None`` marks a session parked before
    prefill — resuming it is a plain (re)prefill."""
    request: Request
    pos: Optional[int] = None
    last_token: int = 0
    base_key: Optional[np.ndarray] = None
    held: bool = False          # user-parked: stays out until resume()


def _make_decode_sample(cfg: ModelConfig, mesh=None):
    """Fused decode + per-slot key fold-in + sampling: ONE dispatch/step."""
    serve_step = make_serve_step(cfg, mesh=mesh)

    def decode_sample(params, kstate, pool, tokens, pos, active,
                      base_keys, tok_idx, temps, top_ks, top_ps):
        logits, new_pool = serve_step(params, kstate, pool, tokens, pos,
                                      active)
        keys = jax.vmap(jax.random.fold_in)(base_keys, tok_idx)
        toks = sample_tokens(keys, logits, temps, top_ks, top_ps)
        return toks, logits, new_pool

    return decode_sample


def _make_decode_greedy(cfg: ModelConfig, mesh=None):
    """Greedy fast path: skips the sort/PRNG machinery of the full sampler
    (several ms/step on CPU) when every active slot decodes at temp 0."""
    serve_step = make_serve_step(cfg, mesh=mesh)

    def decode_greedy(params, kstate, pool, tokens, pos, active):
        logits, new_pool = serve_step(params, kstate, pool, tokens, pos,
                                      active)
        return jnp.argmax(logits, -1).astype(jnp.int32), logits, new_pool

    return decode_greedy


class InferenceEngine:
    """Admits, schedules, decodes, and retires requests independently."""

    def __init__(self, cfg: ModelConfig, params, kstate, *, max_slots: int,
                 max_len: int, token_budget: Optional[int] = None,
                 record_logits: bool = False, mesh=None,
                 obs_jsonl: Optional[str] = None,
                 routing_stats: bool = False,
                 kvstore: Optional[KVStore] = None,
                 prefix_cache: Optional[PrefixCache] = None,
                 time_slice: Optional[int] = None,
                 chunked_prefill: Optional[int] = None,
                 prefill_only: bool = False):
        if routing_stats:
            # flip the static stats flag so prefill forwards compute the
            # routing-health aux (decode-side health comes from the
            # cluster-page occupancy, which needs no recompile)
            cfg = with_overrides(
                cfg, routing=with_overrides(cfg.routing, stats=True))
        self.routing_stats = routing_stats
        self._sink = (JsonlSink(obs_jsonl, source="engine")
                      if obs_jsonl else None)
        self._last_routing: Dict[str, float] = {}
        self.cfg = cfg
        self.params = params
        self.kstate = kstate
        self.max_slots = max_slots
        self.max_len = max_len
        self.mesh = mesh
        # every decode/prefill step resolves its attention backends (and
        # with them the pool's cache layout) from the repro.attn registry;
        # the resolution is recorded here for observability
        self.attn_backends = decode_backends(cfg, mesh=mesh)
        # the engine owns self.pool exclusively and reassigns it on every
        # call, so the decode steps donate it for in-place cache updates
        # (donation is a no-op warning on backends that lack aliasing)
        self._decode_sample = jax.jit(_make_decode_sample(cfg, mesh=mesh),
                                      donate_argnums=(2,))
        self._decode_greedy = jax.jit(_make_decode_greedy(cfg, mesh=mesh),
                                      donate_argnums=(2,))
        self._prefill = jax.jit(functools.partial(
            prefill, cfg=cfg, mesh=mesh, return_stats=routing_stats))
        self.pool = init_pool(cfg, max_slots, max_len, mesh=mesh)
        # prefill never mutates its cache argument (functional), so one
        # fresh B=1 lane serves every admission without reallocation
        self._fresh_lane = init_cache(cfg, 1, max_len, mesh=mesh)
        if mesh is not None:
            # SPMD serving: slots over the data axes, attention heads over
            # "model" (dist/sharding rules). Inputs are committed once here;
            # every jitted step then computes with the sharded layouts and
            # preserves them through the donated pool. Per-lane math is
            # unchanged, so solo-decode parity holds on any mesh (tested).
            # The k-means centroids stay replicated: they are tiny
            # (Hr*kc*dh floats) and head-sharding them changes fusion-level
            # rounding of the cluster scores, whose argmax is discrete —
            # replication keeps routed decode bit-stable across meshes.
            from repro.dist import sharding as shd
            pool_spec = shd.cache_sharding(
                mesh, jax.eval_shape(lambda: self.pool), max_slots)
            self.params = jax.device_put(params,
                                         shd.replicated(mesh, params))
            self.kstate = jax.device_put(kstate,
                                         shd.replicated(mesh, kstate))
            self.pool = jax.device_put(self.pool, pool_spec)
            self._fresh_lane = jax.device_put(
                self._fresh_lane, shd.replicated(mesh, self._fresh_lane))
        self.slots: List[Optional[_Slot]] = [None] * max_slots
        self.scheduler = FCFSScheduler(token_budget)
        self.metrics = EngineMetrics()
        self.step_count = 0
        self.record_logits = record_logits
        self.logits_trace: Dict[int, List[np.ndarray]] = {}
        # tiered KV store: where parked sessions live (host tier by
        # default; StoreConfig adds disk spill and a remote transport).
        # The engine-owned default runs async transfers so the admission
        # path never blocks on a host copy; a caller-provided store keeps
        # whatever mode the caller chose.
        self._owns_kvstore = kvstore is None
        self.kvstore = (kvstore if kvstore is not None
                        else KVStore(StoreConfig(async_transfers=True)))
        self.prefix_cache = prefix_cache
        # partial-prefix reuse is only bit-exact when every decode cache
        # layout writes the same state under teacher-forcing as under
        # prefill (see _PARTIAL_SAFE_LAYOUTS); the teacher-forcing step
        # itself runs unsharded, so it is gated off on a mesh
        self._partial_prefix = (
            prefix_cache is not None and mesh is None
            and decode_cache_layouts(cfg) <= _PARTIAL_SAFE_LAYOUTS)
        self._tail_step = (jax.jit(make_serve_step(cfg))
                           if self._partial_prefix else None)
        # prefill_only: the disaggregated prefill pool's mode — sessions
        # park (held) right after their first token instead of decoding,
        # ready for export_session() to ship them to a decode pool
        self.prefill_only = prefill_only
        # time_slice: decode steps a session may hold a slot while others
        # wait; None = run to completion (park only on priority preemption
        # or an explicit handle.park())
        self.time_slice = time_slice
        self._parked: Dict[int, _ParkedMeta] = {}
        self._admit_seq = 0
        self._rotated_this_step = False
        # chunked_prefill: max depth stages advanced per step() across the
        # outstanding prefill jobs; None = prefill monolithically at
        # admission (the stage functions below are then never built)
        if chunked_prefill is not None and chunked_prefill < 1:
            raise ValueError("chunked_prefill must be >= 1 stage per step")
        self.chunked_prefill = chunked_prefill
        self._prefill_jobs: Dict[int, _PrefillJob] = {}
        if chunked_prefill is not None:
            embed, stages, head = make_prefill_stages(cfg, mesh=mesh,
                                                      groups_per_stage=1)
            self._pf_embed = jax.jit(embed)
            self._pf_head = jax.jit(head)
            self._pf_stages = [(st, jax.jit(st.fn)) for st in stages]
            # per-stage slices of the fresh B=1 lane — stages never mutate
            # their cache argument, so these are shared across every job
            self._pf_fresh = [
                slice_cache_groups(self._fresh_lane[st.si], st.g0, st.g1)
                for st in stages]

    # -- request intake ----------------------------------------------------
    def submit(self, request: Request) -> SessionHandle:
        if request.prompt_len < 1 or request.max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens>=1")
        reserved = request.prompt_len + request.max_new_tokens
        if reserved > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt+max_new {reserved} exceeds "
                f"pool max_len {self.max_len}")
        budget = self.scheduler.token_budget
        if budget is not None and reserved > budget:
            # would never be admittable; with FCFS head-of-line blocking it
            # would also starve everything queued behind it
            raise ValueError(
                f"request {request.uid}: reserved tokens {reserved} exceed "
                f"the scheduler token budget {budget}")
        if request.output:
            raise ValueError(
                f"request {request.uid} already has output; submit a fresh "
                f"Request (e.g. dataclasses.replace(r, output=[]))")
        if (self.scheduler.has_uid(request.uid)
                or request.uid in self._parked
                or any(j.request.uid == request.uid
                       for j in self._prefill_jobs.values())
                or any(s is not None and s.request.uid == request.uid
                       for s in self.slots)):
            raise ValueError(
                f"request uid {request.uid} is already queued, parked, or "
                f"active; uids key outputs, metrics, and PRNG streams")
        request.state = WAITING
        self.scheduler.submit(request)
        self.metrics.on_submit(request.uid, request.prompt_len,
                               self.step_count)
        return SessionHandle(self, request)

    # -- slot accounting ---------------------------------------------------
    def free_slot_ids(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s is None and i not in self._prefill_jobs]

    def tokens_in_flight(self) -> int:
        return (sum(FCFSScheduler.reserved_tokens(s.request)
                    for s in self.slots if s is not None)
                + sum(FCFSScheduler.reserved_tokens(j.request)
                      for j in self._prefill_jobs.values()))

    # -- sampling ----------------------------------------------------------
    def _sample_first(self, req: Request, logits_row) -> int:
        sp = req.sampling
        tok = sample_tokens(
            request_key(sp, req.uid, 0)[None],
            logits_row.astype(jnp.float32),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32))
        return int(tok[0])

    # -- park / resume -----------------------------------------------------
    def _tokens_since_admit(self, s: _Slot) -> int:
        return len(s.request.output) - s.tokens_at_admit

    def _park_slot(self, slot: int, *, held: bool) -> None:
        """Evict ``slot``'s session: lane to the KV store, slot freed.

        ``held=False`` requeues the session immediately (preemption /
        rotation); ``held=True`` keeps it out until ``resume_session``.
        """
        s = self.slots[slot]
        uid = s.request.uid
        t0 = time.perf_counter()
        with span("engine/park"):
            lane = read_slot(self.pool, slot)
            ps = self.kvstore.park(uid, lane)
            self.pool = reset_slot(self.pool, slot)
        dt = time.perf_counter() - t0
        s.request.state = PARKED
        self._parked[uid] = _ParkedMeta(s.request, pos=s.pos,
                                        last_token=s.last_token,
                                        base_key=s.base_key, held=held)
        self.slots[slot] = None
        self.metrics.on_park(uid, self.step_count)
        if not held:
            self.scheduler.submit(s.request)
        if self._sink is not None:
            self._sink.emit("kvstore_park", step=self.step_count, uid=uid,
                            metrics={"park_s": dt,
                                     "bytes": float(ps.nbytes),
                                     "tokens": float(s.pos)})

    def _resume_into(self, slot: int, req: Request) -> None:
        """Stream a parked session's lane back into ``slot`` (bit-exact
        with a never-evicted run: the lane round-trips byte-identical and
        sampling keys are counter-based per uid, not per slot)."""
        meta = self._parked.pop(req.uid)
        t0 = time.perf_counter()
        with span("engine/resume"):
            lane = self.kvstore.resume(req.uid)
            self.pool = write_slot(self.pool, slot, lane)
        dt = time.perf_counter() - t0
        req.state = DECODE
        self.slots[slot] = _Slot(
            req, pos=meta.pos, last_token=meta.last_token,
            base_key=meta.base_key, admit_seq=self._admit_seq,
            tokens_at_admit=len(req.output))
        self._admit_seq += 1
        self.metrics.on_resume(req.uid, slot, self.step_count)
        if self._sink is not None:
            self._sink.emit("kvstore_resume", step=self.step_count,
                            uid=req.uid,
                            metrics={"resume_s": dt, "slot": float(slot),
                                     "tokens": float(meta.pos)})

    def _maybe_park_for(self, head: Request) -> bool:
        """Try to free capacity for the queue head by parking one active
        session; True iff a park happened that makes ``head`` admittable."""
        active = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if not active and not self._prefill_jobs:
            return False
        need = FCFSScheduler.reserved_tokens(head)
        budget = self.scheduler.token_budget
        free_now = len(self.free_slot_ids())

        def admits_after(victim_req: Request) -> bool:
            tif = (self.tokens_in_flight()
                   - FCFSScheduler.reserved_tokens(victim_req))
            return budget is None or tif + need <= budget

        # 1. priority preemption: the lowest-priority session strictly
        # below the head's priority gives up its slot. Mid-prefill jobs
        # are the preferred victims — they have produced nothing yet, so
        # dropping one costs a re-prefill instead of a lane round-trip
        # through the KV store.
        lower_jobs = [(j.request.priority, j.admit_seq, slot, j)
                      for slot, j in self._prefill_jobs.items()
                      if j.request.priority < head.priority]
        if lower_jobs:
            _, _, slot, j = min(lower_jobs)
            if admits_after(j.request):
                self._drop_prefill_job(slot, held=False)
                return True
        lower = [(s.request.priority, s.admit_seq, i, s)
                 for i, s in active if s.request.priority < head.priority]
        if lower:
            _, _, i, s = min(lower)
            if admits_after(s.request):
                self._park_slot(i, held=False)
                return True
        # 2. time-slice rotation: with every slot busy and peers (at the
        # head's priority or below) waiting, the longest-admitted session
        # that has used up its slice rotates out — at most once per step,
        # so a solo session never thrashes
        if (self.time_slice is not None and free_now == 0
                and not self._rotated_this_step):
            eligible = [(s.admit_seq, i, s) for i, s in active
                        if (self._tokens_since_admit(s) >= self.time_slice
                            and s.request.priority <= head.priority)]
            if eligible:
                _, i, s = min(eligible)
                if admits_after(s.request):
                    self._rotated_this_step = True
                    self._park_slot(i, held=False)
                    return True
        return False

    def park_session(self, uid: int) -> None:
        """Explicitly park a session (handle.park()): active sessions
        evict their lane and are *held*; queued sessions are pulled from
        the queue and held without a lane."""
        for i, s in enumerate(self.slots):
            if s is not None and s.request.uid == uid:
                self._park_slot(i, held=True)
                return
        for slot, job in list(self._prefill_jobs.items()):
            if job.request.uid == uid:
                # mid-prefill: nothing to evict — drop the partial stages
                # and hold the request; resume() re-prefills from scratch
                self._drop_prefill_job(slot, held=True)
                return
        req = self.scheduler.remove(uid)
        if req is not None:
            req.state = PARKED
            self._parked[uid] = _ParkedMeta(req, held=True)
            return
        if uid in self._parked:
            self._parked[uid].held = True
            return
        raise ValueError(f"session {uid} is not active or queued")

    def resume_session(self, uid: int) -> None:
        """Requeue a held session for readmission (its lane streams back
        on placement)."""
        meta = self._parked.get(uid)
        if meta is None:
            raise ValueError(f"session {uid} is not parked")
        if meta.held:
            meta.held = False
            self.scheduler.submit(meta.request)
        if meta.pos is not None:
            # scheduler hint: readmission is coming — start pulling the
            # lane back toward the host tier now
            self.kvstore.prefetch(uid)

    # -- disaggregation rail (prefill pool -> decode pool) -----------------
    def export_session(self, uid: int, *, name: Optional[str] = None,
                       transport=None) -> str:
        """Ship a parked (post-prefill) session to another engine through
        a transport blob: the lane plus the request/decode state rides in
        one checksummed blob. The session leaves this engine (state
        EXPORTED); ownership transfers to whoever ``import_session``s the
        returned name."""
        meta = self._parked.get(uid)
        if meta is None or meta.pos is None:
            raise ValueError(
                f"session {uid} is not parked with a prefilled lane "
                f"(park it after prefill before exporting)")
        sp = meta.request.sampling
        m = {
            "uid": uid,
            "prompt": [int(t) for t in meta.request.prompt],
            "output": [int(t) for t in meta.request.output],
            "max_new_tokens": meta.request.max_new_tokens,
            "eos_id": meta.request.eos_id,
            "priority": meta.request.priority,
            "sampling": {"temperature": sp.temperature, "top_k": sp.top_k,
                         "top_p": sp.top_p, "seed": sp.seed},
            "pos": meta.pos,
            "last_token": meta.last_token,
            "base_key": {"data": np.asarray(meta.base_key).tolist(),
                         "dtype": str(np.asarray(meta.base_key).dtype)},
        }
        name = self.kvstore.export(uid, name=name, meta=m,
                                   transport=transport)
        self._parked.pop(uid)
        meta.request.state = EXPORTED
        if self._sink is not None:
            self._sink.emit("session_export", step=self.step_count,
                            uid=uid, name=name,
                            metrics={"tokens": float(meta.pos)})
        return name

    def import_session(self, name: str, *, transport=None) -> SessionHandle:
        """Adopt a session another engine exported: the lane goes into
        this engine's KV store, the request/decode state is rebuilt from
        the blob meta, and the session queues for readmission — decode
        continues bit-exact where the exporter stopped (counter-based
        sampling keys make the continuation engine-independent)."""
        uid, m = self.kvstore.import_remote(name, transport=transport)
        if (self.scheduler.has_uid(uid) or uid in self._parked
                or any(s is not None and s.request.uid == uid
                       for s in self.slots)):
            self.kvstore.drop(uid)
            raise ValueError(f"imported session uid {uid} collides with a "
                             f"live session here")
        req = Request(uid=uid, prompt=m["prompt"],
                      max_new_tokens=m["max_new_tokens"],
                      eos_id=m["eos_id"],
                      sampling=SamplingParams(**m["sampling"]),
                      priority=m["priority"], state=PARKED,
                      output=list(m["output"]))
        base_key = np.asarray(m["base_key"]["data"]).astype(
            np.dtype(m["base_key"]["dtype"]))
        self._parked[uid] = _ParkedMeta(req, pos=m["pos"],
                                        last_token=m["last_token"],
                                        base_key=base_key, held=False)
        self.scheduler.submit(req)
        self.metrics.on_submit(uid, req.prompt_len, self.step_count)
        if self._sink is not None:
            self._sink.emit("session_import", step=self.step_count,
                            uid=uid, name=name,
                            metrics={"tokens": float(m["pos"])})
        return SessionHandle(self, req)

    def cancel_session(self, uid: int) -> None:
        """Drop a session wherever it is (queue, slot, or KV store)."""
        req = self.scheduler.remove(uid)
        if req is not None and uid not in self._parked:
            req.state = CANCELLED
            return
        meta = self._parked.pop(uid, None)
        if meta is not None:
            if uid in self.kvstore:
                self.kvstore.drop(uid)
            meta.request.state = CANCELLED
            return
        for i, s in enumerate(self.slots):
            if s is not None and s.request.uid == uid:
                self.pool = reset_slot(self.pool, i)
                self.slots[i] = None
                s.request.state = CANCELLED
                return
        for slot, job in list(self._prefill_jobs.items()):
            if job.request.uid == uid:
                self._prefill_jobs.pop(slot)       # no lane written yet
                job.request.state = CANCELLED
                return
        raise ValueError(f"session {uid} is not queued, parked, or active")

    # -- lifecycle steps ---------------------------------------------------
    def _admit_and_prefill(self) -> None:
        while True:
            head = self.scheduler.peek()
            if head is None:
                return
            free = self.free_slot_ids()
            if not self.scheduler.admittable(head, len(free),
                                             self.tokens_in_flight()):
                # the head will be placed soon: warm its lane back toward
                # the host tier while it waits (no-op unless spilled)
                if head.uid in self._parked:
                    self.kvstore.prefetch(head.uid)
                if not self._maybe_park_for(head):
                    return
                free = self.free_slot_ids()
            req = self.scheduler.next_admittable(len(free),
                                                self.tokens_in_flight())
            if req is None:
                return
            self._place(free[0], req)

    def _place(self, slot: int, req: Request) -> None:
        meta = self._parked.get(req.uid)
        if meta is not None and meta.pos is not None:
            self._resume_into(slot, req)
        else:
            self._parked.pop(req.uid, None)     # held-before-prefill
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request) -> None:
        t0 = time.perf_counter()
        req.state = PREFILL
        hit = (self.prefix_cache.get(req.prompt,
                                     partial=self._partial_prefix)
               if self.prefix_cache is not None else None)
        if hit is not None and hit.matched == req.prompt_len:
            # exact-prompt hit: the shared read-only lane + stored logits
            # row stand in for the model call; write_slot copies the lane
            # into the pool, so the shared pages are never aliased
            self._activate(slot, req, hit.lane,
                           jnp.asarray(hit.last_logits), t0)
            return
        if hit is not None:
            # longest-prefix hit: teacher-force the remaining prompt tail
            # through decode steps over the cached lane. Bit-exact to a
            # full prefill by the layout gate (append/ring decode writes
            # exactly the rows prefill would), so the contract that a hit
            # is byte-identical to a miss still holds.
            self._prefill_from_prefix(slot, req, hit, t0)
            return
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        if self.chunked_prefill is not None:
            # enqueue a depth-staged job holding this slot; its stages run
            # in _advance_prefill_jobs, interleaved with decode steps
            x, positions = self._pf_embed(self.params, {"tokens": toks})
            self._prefill_jobs[slot] = _PrefillJob(
                req, x, positions, admit_seq=self._admit_seq, t0=t0)
            self._admit_seq += 1
            return
        with span("engine/prefill"):
            res = self._prefill(self.params, self.kstate,
                                self._fresh_lane, {"tokens": toks})
        logits, lane = res[0], res[1]
        last_logits = logits[:, -1]
        if self.routing_stats and len(res) > 2:
            self._emit_prefill_stats(req, res[2])
        if self.prefix_cache is not None:
            self.prefix_cache.put(req.prompt, lane, np.asarray(last_logits))
        self._activate(slot, req, lane, last_logits, t0)

    def _prefill_from_prefix(self, slot: int, req: Request, hit,
                             t0: float) -> None:
        """Fill ``slot`` from a cached shorter-prefix lane: run decode
        steps over the B=1 lane with the prompt tail as forced inputs
        (positions ``matched .. prompt_len-1``), then activate on the
        final logits row exactly like a monolithic prefill."""
        k = hit.matched
        lane = jax.tree.map(jnp.asarray, hit.lane)
        on = jnp.ones((1,), bool)
        last_logits = None
        with span("engine/prefill_tail"):
            for i, tok in enumerate(req.prompt[k:]):
                last_logits, lane = self._tail_step(
                    self.params, self.kstate, lane,
                    jnp.asarray([tok], jnp.int32),
                    jnp.asarray([k + i], jnp.int32), on)
        if self.prefix_cache is not None:
            # the extended lane becomes a full-prompt entry, so the next
            # identical prompt hits exactly
            self.prefix_cache.put(req.prompt, lane, np.asarray(last_logits))
        self._activate(slot, req, lane, last_logits, t0)

    def _emit_prefill_stats(self, req: Request, stats_tree) -> None:
        summ = jax.device_get(obs_rt.summarize(stats_tree))
        self._last_routing = {k: float(v) for k, v in summ.items()}
        if self._sink is not None:
            self._sink.emit("engine_prefill", metrics=self._last_routing,
                            step=self.step_count, uid=req.uid,
                            prompt_len=req.prompt_len)

    def _activate(self, slot: int, req: Request, lane, last_logits,
                  t0: float) -> None:
        """Write a prefilled lane into ``slot`` and sample the first token
        — the shared tail of monolithic, chunked, and prefix-hit prefill.
        ``t0`` is the admission wall-clock (for a chunked job the measured
        prefill time includes the decode steps it interleaved with)."""
        self.pool = write_slot(self.pool, slot, lane)
        tok = self._sample_first(req, last_logits)
        dt = time.perf_counter() - t0
        req.state = DECODE
        req.output.append(tok)
        if self.record_logits:
            self.logits_trace.setdefault(req.uid, []).append(
                np.asarray(last_logits[0]))
        self.metrics.on_prefill(req.uid, slot, self.step_count,
                                req.prompt_len, dt)
        self.metrics.on_token(req.uid)
        self.slots[slot] = _Slot(
            req, pos=req.prompt_len, last_token=tok,
            base_key=np.asarray(request_base_key(req.sampling, req.uid)),
            admit_seq=self._admit_seq, tokens_at_admit=0)
        self._admit_seq += 1
        if self._is_finished(req, tok):
            self._retire(slot)
        elif self.prefill_only:
            # disaggregated prefill pool: the session's work here is done
            # — park it held, ready for export_session() to ship it to a
            # decode pool
            self._park_slot(slot, held=True)

    # -- chunked prefill ---------------------------------------------------
    def _advance_prefill_jobs(self) -> None:
        """Advance at most ``chunked_prefill`` depth stages across the
        outstanding jobs, oldest job first (FCFS completion order, best
        TTFT under load); a job whose last stage completes activates its
        lane immediately, so it joins this very step's decode."""
        budget = self.chunked_prefill
        for slot in sorted(self._prefill_jobs,
                           key=lambda s: self._prefill_jobs[s].admit_seq):
            if budget <= 0:
                return
            job = self._prefill_jobs[slot]
            while budget > 0 and job.stage_idx < len(self._pf_stages):
                st, fn = self._pf_stages[job.stage_idx]
                with span("engine/prefill_stage"):
                    job.x, nc, st_g = fn(self.params, self.kstate,
                                         self._pf_fresh[job.stage_idx],
                                         job.x, job.positions, {})
                job.chunks.append(nc)
                job.stats.append(st_g)
                job.stage_idx += 1
                budget -= 1
            if job.stage_idx == len(self._pf_stages):
                self._finish_prefill_job(slot)

    def _finish_prefill_job(self, slot: int) -> None:
        job = self._prefill_jobs.pop(slot)
        req = job.request
        lane = assemble_prefill_cache([st for st, _ in self._pf_stages],
                                      job.chunks)
        last_logits = self._pf_head(self.params, job.x)[:, -1]
        if self.routing_stats:
            self._emit_prefill_stats(req, job.stats)
        if self.prefix_cache is not None:
            self.prefix_cache.put(req.prompt, lane, np.asarray(last_logits))
        self._activate(slot, req, lane, last_logits, job.t0)

    def _drop_prefill_job(self, slot: int, *, held: bool) -> None:
        """Abandon a mid-prefill job (preemption or explicit park): the
        partial stage work is dropped — no lane was written yet — and the
        request requeues as not-yet-prefilled (_ParkedMeta.pos=None, so
        readmission is a plain re-prefill)."""
        job = self._prefill_jobs.pop(slot)
        req = job.request
        req.state = PARKED
        self._parked[req.uid] = _ParkedMeta(req, held=held)
        self.metrics.on_park(req.uid, self.step_count)
        if not held:
            self.scheduler.submit(req)

    def _is_finished(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    def _retire(self, slot: int) -> None:
        s = self.slots[slot]
        s.request.state = FINISHED
        self.metrics.on_finish(s.request.uid, self.step_count)
        self.pool = reset_slot(self.pool, slot)
        self.slots[slot] = None

    def _decode_once(self) -> None:
        active_ids = [i for i, s in enumerate(self.slots) if s is not None]
        if not active_ids:
            return
        t0 = time.perf_counter()
        B = self.max_slots
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        act = np.zeros((B,), bool)
        for i in active_ids:
            s = self.slots[i]
            tokens[i], pos[i], act[i] = s.last_token, s.pos, True
        all_greedy = all(self.slots[i].request.sampling.temperature <= 0
                         for i in active_ids)
        if all_greedy:
            toks, logits, self.pool = self._decode_greedy(
                self.params, self.kstate, self.pool, tokens, pos, act)
        else:
            temps = np.zeros((B,), np.float32)
            tks = np.zeros((B,), np.int32)
            tps = np.ones((B,), np.float32)
            tok_idx = np.zeros((B,), np.uint32)
            ref = self.slots[active_ids[0]].base_key
            base_keys = np.zeros((B,) + ref.shape, ref.dtype)
            for i in active_ids:
                s = self.slots[i]
                sp = s.request.sampling
                temps[i], tks[i], tps[i] = sp.temperature, sp.top_k, sp.top_p
                tok_idx[i] = len(s.request.output)
                base_keys[i] = s.base_key
            toks, logits, self.pool = self._decode_sample(
                self.params, self.kstate, self.pool, tokens, pos, act,
                base_keys, tok_idx, temps, tks, tps)
        toks_host = np.asarray(toks)            # device sync
        dt = time.perf_counter() - t0
        self.metrics.on_decode_step(len(active_ids), dt)
        logits_host = (np.asarray(logits) if self.record_logits else None)
        for i in active_ids:
            s = self.slots[i]
            tok = int(toks_host[i])
            s.request.output.append(tok)
            s.last_token = tok
            s.pos += 1
            self.metrics.on_token(s.request.uid)
            if logits_host is not None:
                self.logits_trace.setdefault(s.request.uid, []).append(
                    logits_host[i])
            if self._is_finished(s.request, tok):
                self._retire(i)

    def step(self) -> None:
        """One engine iteration: admit (+ prefill), advance any chunked
        prefill stages, then one decode step over the active slots
        (skipped under ``prefill_only`` — that pool's sessions park right
        after their first token)."""
        self._rotated_this_step = False
        with span("engine/admit"):
            self._admit_and_prefill()
        if self._prefill_jobs:
            with span("engine/prefill_chunk"):
                self._advance_prefill_jobs()
        if not self.prefill_only:
            with span("engine/decode"):
                self._decode_once()
        self.step_count += 1
        if self._sink is not None:
            self._emit_tick()

    def _emit_tick(self) -> None:
        """One "engine_tick" JSONL record: queue/slot state plus routing
        health read off the cluster-page occupancy of active lanes
        (entropy/dead). Centroids are frozen in serving, so drift is 0 by
        construction; recall is carried from the latest prefill (the only
        place the full softmax is sampled)."""
        active = np.array([s is not None for s in self.slots], bool)
        metrics: Dict[str, float] = {
            "active_slots": float(active.sum()),
            "queued": float(len(self.scheduler)),
            "parked": float(len(self._parked)),
            "prefilling": float(len(self._prefill_jobs)),
            "decode_steps": float(self.metrics.decode_steps),
        }
        metrics.update(self.kvstore.stats())
        # tier events (e.g. kvstore_remote_degraded) become records of
        # their own kind, interleaved with the ticks
        for ev in self.kvstore.drain_events():
            ev = dict(ev)
            self._sink.emit(ev.pop("kind"), step=self.step_count, **ev)
        if self.prefix_cache is not None:
            metrics.update(self.prefix_cache.stats())
        # fetch only the (tiny) rlen occupancy leaves, never the pages
        rlens = [leaf for path, leaf
                 in jax.tree_util.tree_flatten_with_path(self.pool)[0]
                 if any(isinstance(e, jax.tree_util.DictKey)
                        and e.key == "rlen" for e in path)]
        health = pages_health(
            [{"rlen": r} for r in jax.device_get(rlens)],
            active=active) if (rlens and active.any()) else None
        if health is not None:
            metrics.update(health)
            metrics["routing/drift"] = 0.0
            if "routing/recall" in self._last_routing:
                metrics["routing/recall"] = \
                    self._last_routing["routing/recall"]
        self._sink.emit("engine_tick", metrics=metrics, step=self.step_count)

    def close(self) -> None:
        """Settle in-flight KV transfers, emit the final summary record,
        and close the JSONL sink (and the engine-owned KV store)."""
        self.kvstore.flush()
        if self._sink is not None:
            self._sink.emit("engine_summary", metrics=self.metrics.summary())
            self._sink.close()
        if self._owns_kvstore:
            self.kvstore.close()

    def has_work(self) -> bool:
        return (bool(len(self.scheduler)) or bool(self._prefill_jobs)
                or any(s is not None for s in self.slots))

    def run(self, requests: Sequence[Request] = (),
            max_steps: int = 1_000_000) -> Dict[int, List[int]]:
        """Submit ``requests`` at their arrival_step; run until drained."""
        pending = sorted(requests, key=lambda r: (r.arrival_step, r.uid))
        while pending or self.has_work():
            while pending and pending[0].arrival_step <= self.step_count:
                self.submit(pending.pop(0))
            self.step()
            if self.step_count > max_steps:
                raise RuntimeError("engine did not drain the workload")
        return {r.uid: list(r.output) for r in requests}
