"""Continuous-batching inference engine over the slot-pooled routing cache.

Request lifecycle::

    WAITING --admit (free slot + token budget)--> PREFILL
    PREFILL --first token sampled, lane written--> DECODE
    DECODE  --eos_id / max_new_tokens----------->  FINISHED (lane reset,
                                                   slot returned to pool)

Each engine ``step()``:

  1. admit: pop FCFS-admittable requests and prefill each into a free lane
     (one jitted prefill per request at its exact prompt length — distinct
     lengths compile once and are cached by jit). The first output token is
     sampled from the prefill logits.
  2. decode: ONE jitted ``serve_step`` over ALL pool slots with a per-slot
     active mask — free/finished lanes are exact no-ops, so requests at
     different positions, prompt lengths, and sampling settings share the
     batch. Per-slot sampling is a second jitted call.
  3. retire: finished requests free their lane (``reset_slot``) so the next
     admission reuses it without reallocation.

Because every lane is computed independently and sampling keys are
counter-based per request, a request's outputs are bit-identical no matter
which slot it occupies or who its co-tenants are (tested).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, with_overrides
from repro.obs import JsonlSink, pages_health
from repro.obs import routing_stats as obs_rt
from repro.obs.trace import span
from repro.serve.engine.metrics import EngineMetrics
from repro.serve.engine.pool import init_pool, reset_slot, write_slot
from repro.serve.engine.scheduler import FCFSScheduler
from repro.serve.engine.sampling import (SamplingParams, request_base_key,
                                         request_key, sample_tokens)
from repro.serve.serving import (decode_backends, init_cache,
                                 make_serve_step, prefill)

WAITING, PREFILL, DECODE, FINISHED = "WAITING", "PREFILL", "DECODE", "FINISHED"


@dataclass
class Request:
    uid: int
    prompt: Sequence[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    arrival_step: int = 0       # engine step at which the request shows up
    state: str = WAITING
    output: List[int] = field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclass
class _Slot:
    request: Request
    pos: int                    # next decode position (= tokens in context)
    last_token: int
    base_key: np.ndarray        # request_base_key, host-side


def _make_decode_sample(cfg: ModelConfig, mesh=None):
    """Fused decode + per-slot key fold-in + sampling: ONE dispatch/step."""
    serve_step = make_serve_step(cfg, mesh=mesh)

    def decode_sample(params, kstate, pool, tokens, pos, active,
                      base_keys, tok_idx, temps, top_ks, top_ps):
        logits, new_pool = serve_step(params, kstate, pool, tokens, pos,
                                      active)
        keys = jax.vmap(jax.random.fold_in)(base_keys, tok_idx)
        toks = sample_tokens(keys, logits, temps, top_ks, top_ps)
        return toks, logits, new_pool

    return decode_sample


def _make_decode_greedy(cfg: ModelConfig, mesh=None):
    """Greedy fast path: skips the sort/PRNG machinery of the full sampler
    (several ms/step on CPU) when every active slot decodes at temp 0."""
    serve_step = make_serve_step(cfg, mesh=mesh)

    def decode_greedy(params, kstate, pool, tokens, pos, active):
        logits, new_pool = serve_step(params, kstate, pool, tokens, pos,
                                      active)
        return jnp.argmax(logits, -1).astype(jnp.int32), logits, new_pool

    return decode_greedy


class InferenceEngine:
    """Admits, schedules, decodes, and retires requests independently."""

    def __init__(self, cfg: ModelConfig, params, kstate, *, max_slots: int,
                 max_len: int, token_budget: Optional[int] = None,
                 record_logits: bool = False, mesh=None,
                 obs_jsonl: Optional[str] = None,
                 routing_stats: bool = False):
        if routing_stats:
            # flip the static stats flag so prefill forwards compute the
            # routing-health aux (decode-side health comes from the
            # cluster-page occupancy, which needs no recompile)
            cfg = with_overrides(
                cfg, routing=with_overrides(cfg.routing, stats=True))
        self.routing_stats = routing_stats
        self._sink = (JsonlSink(obs_jsonl, source="engine")
                      if obs_jsonl else None)
        self._last_routing: Dict[str, float] = {}
        self.cfg = cfg
        self.params = params
        self.kstate = kstate
        self.max_slots = max_slots
        self.max_len = max_len
        self.mesh = mesh
        # every decode/prefill step resolves its attention backends (and
        # with them the pool's cache layout) from the repro.attn registry;
        # the resolution is recorded here for observability
        self.attn_backends = decode_backends(cfg, mesh=mesh)
        # the engine owns self.pool exclusively and reassigns it on every
        # call, so the decode steps donate it for in-place cache updates
        # (donation is a no-op warning on backends that lack aliasing)
        self._decode_sample = jax.jit(_make_decode_sample(cfg, mesh=mesh),
                                      donate_argnums=(2,))
        self._decode_greedy = jax.jit(_make_decode_greedy(cfg, mesh=mesh),
                                      donate_argnums=(2,))
        self._prefill = jax.jit(functools.partial(
            prefill, cfg=cfg, mesh=mesh, return_stats=routing_stats))
        self.pool = init_pool(cfg, max_slots, max_len, mesh=mesh)
        # prefill never mutates its cache argument (functional), so one
        # fresh B=1 lane serves every admission without reallocation
        self._fresh_lane = init_cache(cfg, 1, max_len, mesh=mesh)
        if mesh is not None:
            # SPMD serving: slots over the data axes, attention heads over
            # "model" (dist/sharding rules). Inputs are committed once here;
            # every jitted step then computes with the sharded layouts and
            # preserves them through the donated pool. Per-lane math is
            # unchanged, so solo-decode parity holds on any mesh (tested).
            # The k-means centroids stay replicated: they are tiny
            # (Hr*kc*dh floats) and head-sharding them changes fusion-level
            # rounding of the cluster scores, whose argmax is discrete —
            # replication keeps routed decode bit-stable across meshes.
            from repro.dist import sharding as shd
            pool_spec = shd.cache_sharding(
                mesh, jax.eval_shape(lambda: self.pool), max_slots)
            self.params = jax.device_put(params,
                                         shd.replicated(mesh, params))
            self.kstate = jax.device_put(kstate,
                                         shd.replicated(mesh, kstate))
            self.pool = jax.device_put(self.pool, pool_spec)
            self._fresh_lane = jax.device_put(
                self._fresh_lane, shd.replicated(mesh, self._fresh_lane))
        self.slots: List[Optional[_Slot]] = [None] * max_slots
        self.scheduler = FCFSScheduler(token_budget)
        self.metrics = EngineMetrics()
        self.step_count = 0
        self.record_logits = record_logits
        self.logits_trace: Dict[int, List[np.ndarray]] = {}

    # -- request intake ----------------------------------------------------
    def submit(self, request: Request) -> None:
        if request.prompt_len < 1 or request.max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens>=1")
        reserved = request.prompt_len + request.max_new_tokens
        if reserved > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt+max_new {reserved} exceeds "
                f"pool max_len {self.max_len}")
        budget = self.scheduler.token_budget
        if budget is not None and reserved > budget:
            # would never be admittable; with FCFS head-of-line blocking it
            # would also starve everything queued behind it
            raise ValueError(
                f"request {request.uid}: reserved tokens {reserved} exceed "
                f"the scheduler token budget {budget}")
        if request.output:
            raise ValueError(
                f"request {request.uid} already has output; submit a fresh "
                f"Request (e.g. dataclasses.replace(r, output=[]))")
        if (self.scheduler.has_uid(request.uid)
                or any(s is not None and s.request.uid == request.uid
                       for s in self.slots)):
            raise ValueError(
                f"request uid {request.uid} is already queued or active; "
                f"uids key outputs, metrics, and PRNG streams")
        request.state = WAITING
        self.scheduler.submit(request)
        self.metrics.on_submit(request.uid, request.prompt_len,
                               self.step_count)

    # -- slot accounting ---------------------------------------------------
    def free_slot_ids(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def tokens_in_flight(self) -> int:
        return sum(FCFSScheduler.reserved_tokens(s.request)
                   for s in self.slots if s is not None)

    # -- sampling ----------------------------------------------------------
    def _sample_first(self, req: Request, logits_row) -> int:
        sp = req.sampling
        tok = sample_tokens(
            request_key(sp, req.uid, 0)[None],
            logits_row.astype(jnp.float32),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32))
        return int(tok[0])

    # -- lifecycle steps ---------------------------------------------------
    def _admit_and_prefill(self) -> None:
        while True:
            free = self.free_slot_ids()
            if not free:
                return
            req = self.scheduler.next_admittable(len(free),
                                                self.tokens_in_flight())
            if req is None:
                return
            self._prefill_into(free[0], req)

    def _prefill_into(self, slot: int, req: Request) -> None:
        t0 = time.perf_counter()
        req.state = PREFILL
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        with span("engine/prefill"):
            res = self._prefill(self.params, self.kstate,
                                self._fresh_lane, {"tokens": toks})
        logits, lane = res[0], res[1]
        if self.routing_stats and len(res) > 2:
            summ = jax.device_get(obs_rt.summarize(res[2]))
            self._last_routing = {k: float(v) for k, v in summ.items()}
            if self._sink is not None:
                self._sink.emit("engine_prefill", metrics=self._last_routing,
                                step=self.step_count, uid=req.uid,
                                prompt_len=req.prompt_len)
        self.pool = write_slot(self.pool, slot, lane)
        tok = self._sample_first(req, logits[:, -1])
        dt = time.perf_counter() - t0
        req.state = DECODE
        req.output.append(tok)
        if self.record_logits:
            self.logits_trace.setdefault(req.uid, []).append(
                np.asarray(logits[0, -1]))
        self.metrics.on_prefill(req.uid, slot, self.step_count,
                                req.prompt_len, dt)
        self.metrics.on_token(req.uid)
        self.slots[slot] = _Slot(
            req, pos=req.prompt_len, last_token=tok,
            base_key=np.asarray(request_base_key(req.sampling, req.uid)))
        if self._is_finished(req, tok):
            self._retire(slot)

    def _is_finished(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    def _retire(self, slot: int) -> None:
        s = self.slots[slot]
        s.request.state = FINISHED
        self.metrics.on_finish(s.request.uid, self.step_count)
        self.pool = reset_slot(self.pool, slot)
        self.slots[slot] = None

    def _decode_once(self) -> None:
        active_ids = [i for i, s in enumerate(self.slots) if s is not None]
        if not active_ids:
            return
        t0 = time.perf_counter()
        B = self.max_slots
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        act = np.zeros((B,), bool)
        for i in active_ids:
            s = self.slots[i]
            tokens[i], pos[i], act[i] = s.last_token, s.pos, True
        all_greedy = all(self.slots[i].request.sampling.temperature <= 0
                         for i in active_ids)
        if all_greedy:
            toks, logits, self.pool = self._decode_greedy(
                self.params, self.kstate, self.pool, tokens, pos, act)
        else:
            temps = np.zeros((B,), np.float32)
            tks = np.zeros((B,), np.int32)
            tps = np.ones((B,), np.float32)
            tok_idx = np.zeros((B,), np.uint32)
            ref = self.slots[active_ids[0]].base_key
            base_keys = np.zeros((B,) + ref.shape, ref.dtype)
            for i in active_ids:
                s = self.slots[i]
                sp = s.request.sampling
                temps[i], tks[i], tps[i] = sp.temperature, sp.top_k, sp.top_p
                tok_idx[i] = len(s.request.output)
                base_keys[i] = s.base_key
            toks, logits, self.pool = self._decode_sample(
                self.params, self.kstate, self.pool, tokens, pos, act,
                base_keys, tok_idx, temps, tks, tps)
        toks_host = np.asarray(toks)            # device sync
        dt = time.perf_counter() - t0
        self.metrics.on_decode_step(len(active_ids), dt)
        logits_host = (np.asarray(logits) if self.record_logits else None)
        for i in active_ids:
            s = self.slots[i]
            tok = int(toks_host[i])
            s.request.output.append(tok)
            s.last_token = tok
            s.pos += 1
            self.metrics.on_token(s.request.uid)
            if logits_host is not None:
                self.logits_trace.setdefault(s.request.uid, []).append(
                    logits_host[i])
            if self._is_finished(s.request, tok):
                self._retire(i)

    def step(self) -> None:
        """One engine iteration: admit + prefill, then one decode step."""
        with span("engine/admit"):
            self._admit_and_prefill()
        with span("engine/decode"):
            self._decode_once()
        self.step_count += 1
        if self._sink is not None:
            self._emit_tick()

    def _emit_tick(self) -> None:
        """One "engine_tick" JSONL record: queue/slot state plus routing
        health read off the cluster-page occupancy of active lanes
        (entropy/dead). Centroids are frozen in serving, so drift is 0 by
        construction; recall is carried from the latest prefill (the only
        place the full softmax is sampled)."""
        active = np.array([s is not None for s in self.slots], bool)
        metrics: Dict[str, float] = {
            "active_slots": float(active.sum()),
            "queued": float(len(self.scheduler)),
            "decode_steps": float(self.metrics.decode_steps),
        }
        # fetch only the (tiny) rlen occupancy leaves, never the pages
        rlens = [leaf for path, leaf
                 in jax.tree_util.tree_flatten_with_path(self.pool)[0]
                 if any(isinstance(e, jax.tree_util.DictKey)
                        and e.key == "rlen" for e in path)]
        health = pages_health(
            [{"rlen": r} for r in jax.device_get(rlens)],
            active=active) if (rlens and active.any()) else None
        if health is not None:
            metrics.update(health)
            metrics["routing/drift"] = 0.0
            if "routing/recall" in self._last_routing:
                metrics["routing/recall"] = \
                    self._last_routing["routing/recall"]
        self._sink.emit("engine_tick", metrics=metrics, step=self.step_count)

    def close(self) -> None:
        """Emit the final summary record and close the JSONL sink."""
        if self._sink is not None:
            self._sink.emit("engine_summary", metrics=self.metrics.summary())
            self._sink.close()

    def has_work(self) -> bool:
        return bool(len(self.scheduler)) or any(s is not None
                                                for s in self.slots)

    def run(self, requests: Sequence[Request] = (),
            max_steps: int = 1_000_000) -> Dict[int, List[int]]:
        """Submit ``requests`` at their arrival_step; run until drained."""
        pending = sorted(requests, key=lambda r: (r.arrival_step, r.uid))
        while pending or self.has_work():
            while pending and pending[0].arrival_step <= self.step_count:
                self.submit(pending.pop(0))
            self.step()
            if self.step_count > max_steps:
                raise RuntimeError("engine did not drain the workload")
        return {r.uid: list(r.output) for r in requests}
