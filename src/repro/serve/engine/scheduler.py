"""FCFS admission with a token budget (preemption-free backpressure).

Requests are admitted strictly in submission order: the head of the queue
blocks until both a free slot AND token budget are available (no
reordering, no preemption — predictable latency, no cache thrash). The
token budget caps the total *reserved* context (prompt + max_new_tokens)
summed over active slots, bounding worst-case in-flight memory even when
max_slots is large relative to the pool's max_len.
"""
from __future__ import annotations

from collections import deque
from typing import Optional


class FCFSScheduler:
    """First-come-first-served queue with slot + token-budget gating."""

    def __init__(self, token_budget: Optional[int] = None):
        self.token_budget = token_budget
        self._queue = deque()

    def submit(self, request) -> None:
        self._queue.append(request)

    def __len__(self) -> int:
        return len(self._queue)

    def has_uid(self, uid: int) -> bool:
        return any(r.uid == uid for r in self._queue)

    @staticmethod
    def reserved_tokens(request) -> int:
        """Worst-case context this request can occupy."""
        return request.prompt_len + request.max_new_tokens

    def next_admittable(self, free_slots: int, tokens_in_flight: int):
        """Pop and return the head request if it can run now, else None.

        Head-of-line blocking is deliberate: admitting a smaller request
        from behind the head would starve long prompts under load.
        """
        if not self._queue or free_slots <= 0:
            return None
        head = self._queue[0]
        if (self.token_budget is not None
                and tokens_in_flight + self.reserved_tokens(head)
                > self.token_budget):
            return None
        return self._queue.popleft()
