"""Priority-aware FCFS admission with a token budget.

Requests are ordered by (priority desc, submission seq asc): within one
priority level admission is strictly first-come-first-served, and the
head of the queue blocks until both a free slot AND token budget are
available (no reordering past the head — predictable latency). The token
budget caps the total *reserved* context (prompt + max_new_tokens)
summed over active slots, bounding worst-case in-flight memory even when
max_slots is large relative to the pool's max_len.

Preemption lives in the engine, not here: when the head cannot be
admitted the engine may park a lower-priority (or time-sliced) active
session to the KV store and requeue it (``submit`` again — a fresh seq,
so a rotated session rejoins behind its peers). ``peek``/``remove``
exist for that path and for session cancellation.
"""
from __future__ import annotations

import bisect
from typing import List, Optional, Tuple

# Named priority classes for Request.priority. Any int works — the queue
# orders by the raw value — but the gaps leave room to nudge individual
# requests within a class (e.g. INTERACTIVE - 1 for a deprioritized but
# still-interactive session). A higher class admits first and, via the
# engine's preemption path, parks (or, mid-prefill, drops and requeues)
# strictly-lower-priority sessions when slots are full.
PRIORITY_BATCH = -10          # throughput traffic: yields to everything
PRIORITY_NORMAL = 0           # the Request default
PRIORITY_INTERACTIVE = 10     # latency-sensitive: preempts lower classes


class FCFSScheduler:
    """Priority-then-FCFS queue with slot + token-budget gating."""

    def __init__(self, token_budget: Optional[int] = None):
        self.token_budget = token_budget
        # sorted ascending by (-priority, seq): highest priority first,
        # FCFS within a level; seq is unique so requests never compare
        self._queue: List[Tuple[int, int, object]] = []
        self._seq = 0

    def submit(self, request) -> int:
        seq = self._seq
        self._seq += 1
        prio = getattr(request, "priority", 0)
        bisect.insort(self._queue, (-prio, seq, request))
        return seq

    def __len__(self) -> int:
        return len(self._queue)

    def has_uid(self, uid: int) -> bool:
        return any(r.uid == uid for _, _, r in self._queue)

    def peek(self):
        """The head request (next to admit), without popping."""
        return self._queue[0][2] if self._queue else None

    def remove(self, uid: int):
        """Pull a request out of the queue (cancel / hold); None if absent."""
        for i, (_, _, r) in enumerate(self._queue):
            if r.uid == uid:
                return self._queue.pop(i)[2]
        return None

    @staticmethod
    def reserved_tokens(request) -> int:
        """Worst-case context this request can occupy."""
        return request.prompt_len + request.max_new_tokens

    def admittable(self, request, free_slots: int,
                   tokens_in_flight: int) -> bool:
        """Would ``request`` fit right now? (No queue-position check.)"""
        if free_slots <= 0:
            return False
        return (self.token_budget is None
                or tokens_in_flight + self.reserved_tokens(request)
                <= self.token_budget)

    def next_admittable(self, free_slots: int, tokens_in_flight: int):
        """Pop and return the head request if it can run now, else None.

        Head-of-line blocking is deliberate: admitting a smaller request
        from behind the head would starve long prompts under load.
        """
        if not self._queue:
            return None
        head = self._queue[0][2]
        if not self.admittable(head, free_slots, tokens_in_flight):
            return None
        return self._queue.pop(0)[2]
