"""Continuous-batching inference engine (DESIGN.md §5, §11).

Public surface:
  InferenceEngine, Request      — request lifecycle + step loop
  SessionHandle                 — what submit() returns: uid + state +
                                  park()/resume()/cancel()
  SamplingParams                — per-request decode sampling knobs
  FCFSScheduler                 — admission / backpressure policy
  EngineMetrics                 — TTFT / throughput / occupancy counters
  init_pool, write_slot, reset_slot, read_slot — slot-pooled cache lanes
  (the tiered KV store behind the pool lives in repro.serve.kvstore)
"""
from repro.serve.engine.engine import (CANCELLED, DECODE, FINISHED, PARKED,
                                       PREFILL, WAITING, InferenceEngine,
                                       Request, SessionHandle)
from repro.serve.engine.metrics import EngineMetrics, RequestStats
from repro.serve.engine.pool import (init_pool, read_slot, reset_slot,
                                     write_slot)
from repro.serve.engine.sampling import (SamplingParams, request_key,
                                         sample_tokens)
from repro.serve.engine.scheduler import (PRIORITY_BATCH,
                                          PRIORITY_INTERACTIVE,
                                          PRIORITY_NORMAL, FCFSScheduler)

__all__ = [
    "InferenceEngine", "Request", "SessionHandle", "SamplingParams",
    "FCFSScheduler", "EngineMetrics", "RequestStats", "init_pool",
    "write_slot", "reset_slot", "read_slot", "request_key", "sample_tokens",
    "WAITING", "PREFILL", "DECODE", "FINISHED", "PARKED", "CANCELLED",
    "PRIORITY_BATCH", "PRIORITY_NORMAL", "PRIORITY_INTERACTIVE",
]
