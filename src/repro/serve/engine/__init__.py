"""Continuous-batching inference engine (DESIGN.md §5).

Public surface:
  InferenceEngine, Request      — request lifecycle + step loop
  SamplingParams                — per-request decode sampling knobs
  FCFSScheduler                 — admission / backpressure policy
  EngineMetrics                 — TTFT / throughput / occupancy counters
  init_pool, write_slot, reset_slot, read_slot — slot-pooled cache lanes
"""
from repro.serve.engine.engine import (DECODE, FINISHED, PREFILL, WAITING,
                                       InferenceEngine, Request)
from repro.serve.engine.metrics import EngineMetrics, RequestStats
from repro.serve.engine.pool import (init_pool, read_slot, reset_slot,
                                     write_slot)
from repro.serve.engine.sampling import (SamplingParams, request_key,
                                         sample_tokens)
from repro.serve.engine.scheduler import FCFSScheduler

__all__ = [
    "InferenceEngine", "Request", "SamplingParams", "FCFSScheduler",
    "EngineMetrics", "RequestStats", "init_pool", "write_slot", "reset_slot",
    "read_slot", "request_key", "sample_tokens",
    "WAITING", "PREFILL", "DECODE", "FINISHED",
]
