"""Per-request sampling, vectorized across heterogeneous pool slots.

One jitted ``sample_tokens`` call handles the whole pool each step: every
slot carries its own temperature / top-k / top-p (temperature 0 = greedy),
and its own counter-based PRNG stream
``fold_in(fold_in(PRNGKey(seed), uid), token_index)`` — so a request's
sampled tokens are reproducible regardless of which slot it lands in or
which co-tenants share the pool (required for the slot-parity guarantee).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

_NEG_INF = float("-inf")


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 => greedy (argmax)
    top_k: int = 0               # 0 or >= vocab => disabled
    top_p: float = 1.0           # >= 1 => disabled
    seed: int = 0


def request_base_key(params: SamplingParams, uid: int):
    """Per-request key root; the engine folds the token index in on-device."""
    return jax.random.fold_in(jax.random.PRNGKey(params.seed), uid)


def request_key(params: SamplingParams, uid: int, token_index: int):
    """Counter-based key: independent of slot placement and co-tenants."""
    return jax.random.fold_in(request_base_key(params, uid), token_index)


@jax.jit
def sample_tokens(keys, logits, temperature, top_k, top_p):
    """keys (B, key); logits (B,V); temperature/top_p (B,) f32; top_k (B,) i32.

    Rows with temperature <= 0 take the argmax of the raw logits; the rest
    are top-k then top-p filtered at their own temperature and sampled from
    their own key. Returns (B,) int32.
    """
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lg, axis=-1)
    scaled = lg / jnp.maximum(temperature, 1e-6)[:, None]
    # per-row top-k: mask everything below the k-th largest logit
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(desc, jnp.clip(top_k - 1, 0, V - 1)[:, None], 1)
    use_k = (top_k > 0) & (top_k < V)
    scaled = jnp.where(use_k[:, None] & (scaled < kth), _NEG_INF, scaled)
    # per-row nucleus: keep the smallest prefix of descending-prob tokens
    # whose exclusive cumulative mass is < top_p (the top-1 always survives)
    order = jnp.argsort(-scaled, axis=-1)
    sorted_lg = jnp.take_along_axis(scaled, order, -1)
    probs = jax.nn.softmax(sorted_lg, axis=-1)
    keep_sorted = (jnp.cumsum(probs, -1) - probs) < top_p[:, None]
    keep = jnp.take_along_axis(keep_sorted, jnp.argsort(order, -1), -1)
    use_p = top_p < 1.0
    scaled = jnp.where(use_p[:, None] & ~keep, _NEG_INF, scaled)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled).astype(jnp.int32)
