"""Engine observability: per-request TTFT, decode throughput, occupancy.

All counters are plain python updated on the host side of the step loop;
``decode_tokens`` counts only *useful* tokens (active slots), so
``decode_tokens_per_s`` is the aggregate goodput number the continuous
batcher is supposed to move versus lock-step batching, and
``tokens_per_step`` is its hardware-independent proxy (each decode step
costs the same jitted call regardless of how many slots are active).

Latency distributions are backed by ``repro.obs`` histograms:
  engine/ttft_s          per-request time to first token
  engine/decode_step_s   wall time of each batched decode dispatch
  engine/itl_s           per-request mean inter-token latency
                         (finish - first token) / (n_generated - 1),
                         recorded at finish for requests with >= 2 tokens
``summary()`` keeps every pre-existing key and adds their p50/p90/p99.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional

from repro.obs import Registry


@dataclass
class RequestStats:
    uid: int
    prompt_len: int
    submit_time: float
    arrival_step: int = 0
    slot: Optional[int] = None
    prefill_step: Optional[int] = None      # engine step of the first token
    first_token_time: Optional[float] = None
    finish_step: Optional[int] = None
    finish_time: Optional[float] = None
    n_generated: int = 0
    parks: int = 0                          # times parked to the KV store
    resumes: int = 0                        # times resumed from it

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def itl_s(self) -> Optional[float]:
        """Mean inter-token latency over this request's decode phase."""
        if (self.finish_time is None or self.first_token_time is None
                or self.n_generated < 2):
            return None
        return ((self.finish_time - self.first_token_time)
                / (self.n_generated - 1))


class EngineMetrics:
    """Counters updated by the engine; ``summary()`` for reporting."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.requests: Dict[int, RequestStats] = {}
        self.decode_steps = 0
        self.decode_tokens = 0          # useful (active-slot) tokens
        self.decode_time_s = 0.0
        self.prefill_tokens = 0
        self.prefill_time_s = 0.0
        self.occupancy_sum = 0          # active slots summed over decode steps
        self.obs = Registry()
        self._ttft = self.obs.histogram("engine/ttft_s")
        self._decode_step = self.obs.histogram("engine/decode_step_s")
        self._itl = self.obs.histogram("engine/itl_s")

    def on_submit(self, uid: int, prompt_len: int, step: int) -> None:
        self.requests[uid] = RequestStats(uid, prompt_len, self.clock(),
                                          arrival_step=step)

    def on_prefill(self, uid: int, slot: int, step: int, n_tokens: int,
                   dt_s: float) -> None:
        r = self.requests[uid]
        r.slot, r.prefill_step = slot, step
        r.first_token_time = self.clock()
        self._ttft.record(r.first_token_time - r.submit_time)
        self.prefill_tokens += n_tokens
        self.prefill_time_s += dt_s

    def on_decode_step(self, n_active: int, dt_s: float) -> None:
        self.decode_steps += 1
        self.decode_tokens += n_active
        self.decode_time_s += dt_s
        self.occupancy_sum += n_active
        self._decode_step.record(dt_s)

    def on_token(self, uid: int) -> None:
        self.requests[uid].n_generated += 1

    def on_park(self, uid: int, step: int) -> None:
        self.requests[uid].parks += 1

    def on_resume(self, uid: int, slot: int, step: int) -> None:
        r = self.requests[uid]
        r.resumes += 1
        r.slot = slot

    def on_finish(self, uid: int, step: int) -> None:
        r = self.requests[uid]
        r.finish_step = step
        r.finish_time = self.clock()
        if r.itl_s is not None:
            self._itl.record(r.itl_s)

    @property
    def decode_tokens_per_s(self) -> float:
        return (self.decode_tokens / self.decode_time_s
                if self.decode_time_s else 0.0)

    @property
    def tokens_per_step(self) -> float:
        return (self.decode_tokens / self.decode_steps
                if self.decode_steps else 0.0)

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.decode_steps
                if self.decode_steps else 0.0)

    def mean_ttft_s(self) -> Optional[float]:
        ts = [r.ttft_s for r in self.requests.values() if r.ttft_s is not None]
        return sum(ts) / len(ts) if ts else None

    def summary(self) -> dict:
        out = {
            "requests": len(self.requests),
            "finished": sum(1 for r in self.requests.values()
                            if r.finish_step is not None),
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "decode_tokens_per_s": self.decode_tokens_per_s,
            "tokens_per_step": self.tokens_per_step,
            "mean_occupancy": self.mean_occupancy,
            "mean_ttft_s": self.mean_ttft_s(),
            "prefill_tokens": self.prefill_tokens,
            "parks": sum(r.parks for r in self.requests.values()),
            "resumes": sum(r.resumes for r in self.requests.values()),
        }
        for hname, h in (("ttft", self._ttft), ("itl", self._itl),
                         ("decode_step", self._decode_step)):
            if h.count:
                for p in (50, 90, 99):
                    out[f"{hname}_p{p}_s"] = h.percentile(p)
        return out
