"""Engine observability: per-request TTFT, decode throughput, occupancy.

All counters are plain python updated on the host side of the step loop;
``decode_tokens`` counts only *useful* tokens (active slots), so
``decode_tokens_per_s`` is the aggregate goodput number the continuous
batcher is supposed to move versus lock-step batching, and
``tokens_per_step`` is its hardware-independent proxy (each decode step
costs the same jitted call regardless of how many slots are active).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional


@dataclass
class RequestStats:
    uid: int
    prompt_len: int
    submit_time: float
    arrival_step: int = 0
    slot: Optional[int] = None
    prefill_step: Optional[int] = None      # engine step of the first token
    first_token_time: Optional[float] = None
    finish_step: Optional[int] = None
    n_generated: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


class EngineMetrics:
    """Counters updated by the engine; ``summary()`` for reporting."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.requests: Dict[int, RequestStats] = {}
        self.decode_steps = 0
        self.decode_tokens = 0          # useful (active-slot) tokens
        self.decode_time_s = 0.0
        self.prefill_tokens = 0
        self.prefill_time_s = 0.0
        self.occupancy_sum = 0          # active slots summed over decode steps

    def on_submit(self, uid: int, prompt_len: int, step: int) -> None:
        self.requests[uid] = RequestStats(uid, prompt_len, self.clock(),
                                          arrival_step=step)

    def on_prefill(self, uid: int, slot: int, step: int, n_tokens: int,
                   dt_s: float) -> None:
        r = self.requests[uid]
        r.slot, r.prefill_step = slot, step
        r.first_token_time = self.clock()
        self.prefill_tokens += n_tokens
        self.prefill_time_s += dt_s

    def on_decode_step(self, n_active: int, dt_s: float) -> None:
        self.decode_steps += 1
        self.decode_tokens += n_active
        self.decode_time_s += dt_s
        self.occupancy_sum += n_active

    def on_token(self, uid: int) -> None:
        self.requests[uid].n_generated += 1

    def on_finish(self, uid: int, step: int) -> None:
        self.requests[uid].finish_step = step

    @property
    def decode_tokens_per_s(self) -> float:
        return (self.decode_tokens / self.decode_time_s
                if self.decode_time_s else 0.0)

    @property
    def tokens_per_step(self) -> float:
        return (self.decode_tokens / self.decode_steps
                if self.decode_steps else 0.0)

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.decode_steps
                if self.decode_steps else 0.0)

    def mean_ttft_s(self) -> Optional[float]:
        ts = [r.ttft_s for r in self.requests.values() if r.ttft_s is not None]
        return sum(ts) / len(ts) if ts else None

    def summary(self) -> dict:
        return {
            "requests": len(self.requests),
            "finished": sum(1 for r in self.requests.values()
                            if r.finish_step is not None),
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "decode_tokens_per_s": self.decode_tokens_per_s,
            "tokens_per_step": self.tokens_per_step,
            "mean_occupancy": self.mean_occupancy,
            "mean_ttft_s": self.mean_ttft_s(),
            "prefill_tokens": self.prefill_tokens,
        }
