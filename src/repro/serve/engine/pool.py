"""Slot-pooled KV cache: a fixed pool of independent cache lanes.

The pool is ``serving.init_cache(cfg, max_slots, max_len)`` — every cache
leaf is laid out (G, B, ...) with the slot (batch) axis at position 1, so a
lane is addressable as ``leaf[:, slot]`` uniformly across cache families
(full append cache, local ring, cluster-paged routing pages, ssd/rglru
state). On top of that layout this module provides jitted lane primitives:

  write_slot(pool, slot, src)  — copy a B=1 cache (one freshly prefilled
                                 request) into lane ``slot``
  reset_slot(pool, slot)       — return lane ``slot`` to its
                                 just-initialized state (zeros; local-ring
                                 positions back to -1; routing cluster
                                 pages emptied via rlen=0) with no
                                 reallocation, so a freed lane is
                                 immediately reusable
  read_slot(pool, slot)        — extract lane ``slot`` as a B=1 cache

write_slot/read_slot validate structure before touching the jitted
update: the src treedef, per-leaf trailing shapes (which encode max_len
and page capacity), and leaf dtypes must all agree with the pool — a
mismatched lane raises instead of being silently cast/resized into the
pool, where it would corrupt decode far from the call site.

Free/busy bookkeeping lives python-side in the engine; the pool itself is a
pure pytree that flows through jit. ``slot`` may be a traced scalar.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import attn as attn_api
from repro.configs.base import ModelConfig
from repro.serve.serving import init_cache


def init_pool(cfg: ModelConfig, max_slots: int, max_len: int, mesh=None):
    """A pool of ``max_slots`` independent cache lanes (one per request).
    ``mesh`` must match the engine's decode steps so the pool layout and
    the decode-resolved backends agree."""
    return init_cache(cfg, max_slots, max_len, mesh=mesh)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return ""


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _check_slot(pool, slot) -> None:
    """Bounds-check a concrete slot index (traced slots pass through)."""
    if isinstance(slot, jax.core.Tracer):
        return
    max_slots = jax.tree_util.tree_leaves(pool)[0].shape[1]
    s = int(slot)
    if not 0 <= s < max_slots:
        raise ValueError(
            f"slot {s} out of range for a pool of {max_slots} lanes")


def _check_lane(pool, src) -> None:
    """Validate a B=1 lane against the pool before the jitted update.

    Catches treedef mismatches, max_len / page-capacity disagreement
    (trailing shapes), wrong batch axis, and leaf-dtype drift — each of
    which ``p.at[:, slot].set(s[:, 0].astype(p.dtype))`` would formerly
    absorb silently (cast) or surface as an opaque broadcast error deep
    inside jit.
    """
    p_paths, p_tree = jax.tree_util.tree_flatten_with_path(pool)
    s_paths, s_tree = jax.tree_util.tree_flatten_with_path(src)
    if p_tree != s_tree:
        raise ValueError(
            f"lane cache structure does not match the pool: pool treedef "
            f"{p_tree} vs src treedef {s_tree}")
    for (path, p), (_, s) in zip(p_paths, s_paths):
        name = _path_str(path)
        if s.ndim != p.ndim:
            raise ValueError(
                f"cache leaf {name}: rank mismatch — pool {p.shape} vs "
                f"src {s.shape}")
        if s.shape[0] != p.shape[0]:
            raise ValueError(
                f"cache leaf {name}: scan-group axis mismatch — pool "
                f"{p.shape[0]} groups vs src {s.shape[0]}")
        if s.shape[1] != 1:
            raise ValueError(
                f"cache leaf {name}: expected a B=1 lane, got batch axis "
                f"{s.shape[1]} (shape {s.shape})")
        if s.shape[2:] != p.shape[2:]:
            raise ValueError(
                f"cache leaf {name}: trailing shape mismatch (max_len / "
                f"page capacity disagreement) — pool {p.shape[2:]} vs src "
                f"{s.shape[2:]}")
        if s.dtype != p.dtype:
            raise ValueError(
                f"cache leaf {name}: dtype mismatch — pool {p.dtype} vs "
                f"src {s.dtype}; build the lane with the pool's dtype "
                f"instead of relying on a silent cast")


@jax.jit
def _write_slot_jit(pool, slot, src):
    return jax.tree.map(lambda p, s: p.at[:, slot].set(s[:, 0]), pool, src)


def write_slot(pool, slot, src):
    """Copy the single-lane cache ``src`` (B=1, same max_len) into ``slot``.

    Raises ValueError on treedef / shape / dtype disagreement before the
    jitted update runs.
    """
    _check_lane(pool, src)
    _check_slot(pool, slot)
    return _write_slot_jit(pool, slot, src)


@jax.jit
def _reset_slot_jit(pool, slot):
    # per-leaf reset values come from each backend's typed CacheLayout;
    # resolved at trace time (python ints), baked into the jitted update
    fills = attn_api.cache_reset_values()
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaf.at[:, slot].set(
            jnp.asarray(fills.get(_leaf_name(path), 0), leaf.dtype)),
        pool)


def reset_slot(pool, slot):
    """Reset lane ``slot`` to its init state (reusable, no reallocation)."""
    _check_slot(pool, slot)
    return _reset_slot_jit(pool, slot)


@jax.jit
def _read_slot_jit(pool, slot):
    return jax.tree.map(
        lambda p: jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=1), pool)


def read_slot(pool, slot):
    """Lane ``slot`` as a B=1 cache (parity tests / park / debugging)."""
    _check_slot(pool, slot)
    return _read_slot_jit(pool, slot)
