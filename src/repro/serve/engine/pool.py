"""Slot-pooled KV cache: a fixed pool of independent cache lanes.

The pool is ``serving.init_cache(cfg, max_slots, max_len)`` — every cache
leaf is laid out (G, B, ...) with the slot (batch) axis at position 1, so a
lane is addressable as ``leaf[:, slot]`` uniformly across cache families
(full append cache, local ring, cluster-paged routing pages, ssd/rglru
state). On top of that layout this module provides jitted lane primitives:

  write_slot(pool, slot, src)  — copy a B=1 cache (one freshly prefilled
                                 request) into lane ``slot``
  reset_slot(pool, slot)       — return lane ``slot`` to its
                                 just-initialized state (zeros; local-ring
                                 positions back to -1; routing cluster
                                 pages emptied via rlen=0) with no
                                 reallocation, so a freed lane is
                                 immediately reusable
  read_slot(pool, slot)        — extract lane ``slot`` as a B=1 cache

Free/busy bookkeeping lives python-side in the engine; the pool itself is a
pure pytree that flows through jit. ``slot`` may be a traced scalar.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.serve.serving import cache_reset_value, init_cache


def init_pool(cfg: ModelConfig, max_slots: int, max_len: int, mesh=None):
    """A pool of ``max_slots`` independent cache lanes (one per request).
    ``mesh`` must match the engine's decode steps so the pool layout and
    the decode-resolved backends agree."""
    return init_cache(cfg, max_slots, max_len, mesh=mesh)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return ""


@jax.jit
def write_slot(pool, slot, src):
    """Copy the single-lane cache ``src`` (B=1, same max_len) into ``slot``."""
    return jax.tree.map(
        lambda p, s: p.at[:, slot].set(s[:, 0].astype(p.dtype)), pool, src)


@jax.jit
def reset_slot(pool, slot):
    """Reset lane ``slot`` to its init state (reusable, no reallocation)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaf.at[:, slot].set(
            jnp.asarray(cache_reset_value(_leaf_name(path)), leaf.dtype)),
        pool)


@jax.jit
def read_slot(pool, slot):
    """Lane ``slot`` as a B=1 cache (parity tests / debugging)."""
    return jax.tree.map(
        lambda p: jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=1), pool)
