"""Tiered KV store: park a slot's cache lane off-device, resume it
bit-exact into any free slot (DESIGN.md §11).

``park(uid, lane)`` takes the B=1 pytree ``read_slot`` extracts and moves
it off-device; ``resume(uid)`` hands back a pytree ``write_slot``
accepts, with every leaf byte-identical to what was parked. Four tiers:

  device   the engine's slot pool (not this module's problem)
  host     parked sessions as numpy pytrees, cluster-paged leaves kept
           compacted — only the occupied ``min(page_len, cap)`` prefix
           of each page (unoccupied slots are zeros by construction:
           fresh lanes are zeroed, prefill writes only kept slots,
           decode appends one slot, reset re-zeros — so dropping them
           and re-zeroing on resume is bit-exact)
  disk     beyond ``host_bytes_limit`` the least-recently parked
           sessions spill to ``spill_dir`` in the checksummed blob
           format (remote/blob.py: versioned header + CRC32, verified
           on load — a corrupted spill file raises instead of resuming
           silent garbage)
  remote   beyond the disk tier (``disk_bytes_limit``, or directly when
           no ``spill_dir`` is set): the same blob pushed through a
           ``Transport`` to a peer host / object store. Remote failure
           after the transport's retries degrades gracefully — the
           session stays on the nearer tier and a
           ``kvstore_remote_degraded`` event is recorded; a parked
           session is never lost.

``async_transfers=True`` moves every tier transfer onto a background
worker thread: ``park()`` launches the device→host copies
(``copy_to_host_async``) and returns immediately with an in-flight
handle, so the engine's admission path overlaps the host transfer with
its next decode step; ``resume()``/``export()`` wait for the in-flight
transfer first, and ``prefetch(uid)`` warms a disk/remote session back
to host on a scheduler hint. ``export``/``import_remote`` move whole
sessions (plus caller metadata) between processes through a transport —
the primitive the disaggregated prefill/decode pools are built on.

Metrics (park/resume latency histograms, background transfer latency,
bytes per tier, spill/remote/degraded counts) live in a
``repro.obs.Registry`` owned by the store; the engine folds ``stats()``
into its ``engine_tick`` records and drains ``drain_events()`` into
JSONL records.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import attn as attn_api
from repro.obs import Registry
from repro.serve.kvstore.remote.blob import decode_session, encode_session
from repro.serve.kvstore.remote.transport import Transport, TransportError
from repro.serve.kvstore.remote.worker import TransferHandle, TransferWorker

SPILL_SUFFIX = ".blob"


@dataclass(frozen=True)
class StoreConfig:
    """Knobs for the tiered store.

    ``spill_dir``         directory for the disk tier (None = no disk
                          tier; with a host byte limit but neither disk
                          nor remote, over-limit parks raise instead of
                          silently growing)
    ``host_bytes_limit``  soft cap on resident parked bytes — exceeding
                          it moves least-recently-parked sessions down a
                          tier (disk first, else remote)
    ``disk_bytes_limit``  soft cap on spilled bytes — exceeding it
                          pushes the oldest disk sessions to the remote
                          tier (requires ``remote``)
    ``remote``            a ``Transport`` to a peer blob store: the tier
                          beyond disk, and the rail ``export`` /
                          ``import_remote`` move sessions over for
                          disaggregated prefill/decode pools
    ``compact_pages``     per-page compaction of cluster-paged leaves
                          (disable only for debugging round-trips)
    ``async_transfers``   run host materialization, tier eviction, and
                          prefetch on a background worker so ``park()``
                          returns without blocking on the host transfer
    """

    spill_dir: Optional[str] = None
    host_bytes_limit: Optional[int] = None
    disk_bytes_limit: Optional[int] = None
    remote: Optional[Transport] = None
    compact_pages: bool = True
    async_transfers: bool = False


@dataclass
class _LeafRec:
    shape: Tuple[int, ...]
    dtype: Any
    data: Optional[np.ndarray]          # None while spilled/remote
    page_len_key: Optional[str] = None  # set => data is the compacted
    #                                     occupied-prefix values


@dataclass
class ParkedSession:
    uid: int
    treedef: Any
    order: List[str]                    # leaf keys in flatten order
    leaves: Dict[str, _LeafRec] = field(default_factory=dict)
    nbytes: int = 0                     # host bytes (compacted)
    parked_at: float = 0.0
    spill_path: Optional[str] = None    # set while on the disk tier
    remote_name: Optional[str] = None   # set while on the remote tier

    @property
    def resident(self) -> bool:
        return self.spill_path is None and self.remote_name is None


class InflightPark:
    """What ``park()`` returns under ``async_transfers``: the session's
    uid plus a completion handle. ``nbytes`` reads 0 until the host
    materialization lands (the engine's park record is emitted before
    the bytes are known — by design, that is the latency being hidden).
    """

    def __init__(self, uid: int, handle: TransferHandle):
        self.uid = uid
        self._handle = handle

    @property
    def done(self) -> bool:
        return self._handle.done

    @property
    def nbytes(self) -> int:
        if not self._handle.done or self._handle._error is not None:
            return 0
        return self._handle._result.nbytes

    def wait(self, timeout: Optional[float] = None) -> ParkedSession:
        return self._handle.wait(timeout)

    def __repr__(self) -> str:
        state = "done" if self.done else "in-flight"
        return f"InflightPark(uid={self.uid}, {state})"


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return ""


def _sibling_key(path, name: str) -> str:
    sib = tuple(path[:-1]) + (jax.tree_util.DictKey(name),)
    return jax.tree_util.keystr(sib)


def _occupied(rlen: np.ndarray, cap: int) -> np.ndarray:
    """(..., cap) bool mask of occupied ring slots per cluster page."""
    return np.arange(cap) < np.minimum(rlen, cap)[..., None]


class KVStore:
    """Tiered (host + optional disk + optional remote) session store."""

    def __init__(self, config: StoreConfig = StoreConfig()):
        self.config = config
        self._sessions: Dict[int, ParkedSession] = {}
        self._inflight: Dict[int, InflightPark] = {}
        self._prefetching: Dict[int, TransferHandle] = {}
        self._events: "deque[dict]" = deque(maxlen=512)
        self._lock = threading.RLock()
        self._worker: Optional[TransferWorker] = None
        self.obs = Registry()
        self._park_s = self.obs.histogram("kvstore/park_s")
        self._resume_s = self.obs.histogram("kvstore/resume_s")
        self._transfer_s = self.obs.histogram("kvstore/park_transfer_s")
        self._parks = self.obs.counter("kvstore/parks")
        self._resumes = self.obs.counter("kvstore/resumes")
        self._to_host = self.obs.counter("kvstore/bytes_to_host")
        self._to_dev = self.obs.counter("kvstore/bytes_to_device")
        self._spilled_b = self.obs.counter("kvstore/bytes_spilled")
        self._spills = self.obs.counter("kvstore/spills")
        self._to_remote = self.obs.counter("kvstore/bytes_to_remote")
        self._from_remote = self.obs.counter("kvstore/bytes_from_remote")
        self._remote_parks = self.obs.counter("kvstore/remote_parks")
        self._remote_resumes = self.obs.counter("kvstore/remote_resumes")
        self._exports = self.obs.counter("kvstore/exports")
        self._imports = self.obs.counter("kvstore/imports")
        self._degraded = self.obs.counter("kvstore/remote_degraded")
        self._prefetches = self.obs.counter("kvstore/prefetches")
        if config.spill_dir:
            os.makedirs(config.spill_dir, exist_ok=True)
        if config.disk_bytes_limit is not None and config.remote is None:
            raise ValueError("disk_bytes_limit needs a remote transport "
                             "(the tier beyond disk) to evict into")

    def _get_worker(self) -> TransferWorker:
        with self._lock:
            if self._worker is None:
                self._worker = TransferWorker()
            return self._worker

    # -- inventory ---------------------------------------------------------
    def __contains__(self, uid: int) -> bool:
        with self._lock:
            return uid in self._sessions or uid in self._inflight

    def __len__(self) -> int:
        with self._lock:
            # union: an async park is briefly in both maps while the
            # worker commits the materialized session
            return len(self._sessions.keys() | self._inflight.keys())

    @property
    def host_bytes(self) -> int:
        with self._lock:
            return sum(s.nbytes for s in self._sessions.values()
                       if s.resident)

    def drop(self, uid: int) -> None:
        self._wait_uid(uid)
        with self._lock:
            s = self._sessions.pop(uid, None)
        if s is None:
            return
        if s.spill_path and os.path.exists(s.spill_path):
            os.remove(s.spill_path)
        if s.remote_name and self.config.remote is not None:
            try:
                self.config.remote.delete(s.remote_name)
            except (TransportError, KeyError):
                pass                    # best-effort remote GC

    def flush(self, timeout: Optional[float] = None) -> None:
        """Wait until every in-flight park/prefetch has settled (their
        errors surface at the dependent resume/export, not here)."""
        with self._lock:
            handles = ([p._handle for p in self._inflight.values()]
                       + list(self._prefetching.values()))
        for h in handles:
            h._event.wait(timeout)
        if self._worker is not None:
            self._worker.flush(timeout)

    def close(self) -> None:
        self.flush()
        if self._worker is not None:
            self._worker.close()
            self._worker = None

    def drain_events(self) -> List[dict]:
        """Pop accumulated tier events (e.g. ``kvstore_remote_degraded``)
        — the engine emits them as JSONL records on its tick."""
        with self._lock:
            out = list(self._events)
            self._events.clear()
        return out

    def _wait_uid(self, uid: int) -> None:
        """Settle any in-flight park/prefetch for ``uid`` (re-raising a
        background park failure at the caller that depends on it)."""
        with self._lock:
            park = self._inflight.get(uid)
            pre = self._prefetching.get(uid)
        if park is not None:
            park.wait()
        if pre is not None:
            pre._event.wait()

    # -- park --------------------------------------------------------------
    def park(self, uid: int, lane):
        """Move the B=1 cache ``lane`` off-device under ``uid``.

        Returns the ``ParkedSession`` (sync) or an ``InflightPark``
        handle (``async_transfers``: the host materialization and any
        tier eviction continue on the worker thread while the caller
        keeps decoding).
        """
        with self._lock:
            if uid in self._sessions or uid in self._inflight:
                raise ValueError(f"session {uid} is already parked")
        t0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten_with_path(lane)
        for _, leaf in flat:                    # overlap device→host
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        if not self.config.async_transfers:
            sess = self._materialize(uid, flat, treedef, t0)
            self._park_s.record(time.perf_counter() - t0)
            self._parks.inc()
            return sess
        handle = TransferHandle(f"park:{uid}")
        inflight = InflightPark(uid, handle)
        with self._lock:
            self._inflight[uid] = inflight
        self._get_worker().submit(
            lambda: self._bg_park(uid, flat, treedef, t0), handle)
        self._park_s.record(time.perf_counter() - t0)
        self._parks.inc()
        return inflight

    def _bg_park(self, uid: int, flat, treedef, t0: float) -> ParkedSession:
        try:
            return self._materialize(uid, flat, treedef, t0)
        finally:
            with self._lock:
                self._inflight.pop(uid, None)

    def _materialize(self, uid: int, flat, treedef,
                     t0: float) -> ParkedSession:
        """Host conversion + page compaction + insert + limit
        enforcement — the body of a park, on whichever thread runs it."""
        host = {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}
        pageable = (attn_api.pageable_cache_leaves()
                    if self.config.compact_pages else {})
        sess = ParkedSession(uid=uid, treedef=treedef,
                             order=[jax.tree_util.keystr(p) for p, _ in flat],
                             parked_at=t0)
        for path, _ in flat:
            key = jax.tree_util.keystr(path)
            arr = host[key]
            name = _leaf_name(path)
            if name in pageable:
                rlen_key = _sibling_key(path, pageable[name])
                if rlen_key in host:
                    occ = _occupied(host[rlen_key], arr.shape[-2])
                    sess.leaves[key] = _LeafRec(arr.shape, arr.dtype,
                                                np.ascontiguousarray(arr[occ]),
                                                page_len_key=rlen_key)
                    continue
            sess.leaves[key] = _LeafRec(arr.shape, arr.dtype,
                                        np.ascontiguousarray(arr))
        sess.nbytes = sum(r.data.nbytes for r in sess.leaves.values())
        with self._lock:
            self._sessions[uid] = sess
        self._enforce_limit()
        self._to_host.inc(sess.nbytes)
        self._transfer_s.record(time.perf_counter() - t0)
        self._update_gauges()
        return sess

    def _update_gauges(self) -> None:
        self.obs.gauge("kvstore/host_bytes").set(self.host_bytes)
        self.obs.gauge("kvstore/sessions").set(len(self))

    # -- resume ------------------------------------------------------------
    def resume(self, uid: int):
        """Rebuild ``uid``'s lane (bit-exact) and remove it from the store.

        Returns a host pytree in the exact structure/dtypes ``write_slot``
        validates against the pool; the jitted write streams it back to
        the device. Waits for an in-flight park/prefetch of the same uid
        first, so async mode never races its own transfers.
        """
        self._wait_uid(uid)
        with self._lock:
            sess = self._sessions.get(uid)
        if sess is None:
            raise KeyError(f"no parked session {uid}")
        t0 = time.perf_counter()
        # a failed load leaves the session record (and whatever tier copy
        # survives) in the store — the uid is only removed after success
        if sess.remote_name is not None:
            self._fetch_remote(sess)
        if sess.spill_path is not None:
            self._load_spill(sess)
        with self._lock:
            del self._sessions[uid]
        # pass 1: full (non-compacted) leaves — includes every page_len
        # leaf the compacted ones need
        full: Dict[str, np.ndarray] = {
            k: r.data for k, r in sess.leaves.items()
            if r.page_len_key is None}
        # pass 2: re-expand compacted cluster pages against their rlen
        for key, rec in sess.leaves.items():
            if rec.page_len_key is None:
                continue
            out = np.zeros(rec.shape, rec.dtype)
            occ = _occupied(full[rec.page_len_key], rec.shape[-2])
            out[occ] = rec.data
            full[key] = out
        lane = jax.tree_util.tree_unflatten(
            sess.treedef, [full[k] for k in sess.order])
        self._resume_s.record(time.perf_counter() - t0)
        self._resumes.inc()
        self._to_dev.inc(sess.nbytes)
        self._update_gauges()
        return lane

    def prefetch(self, uid: int) -> Optional[TransferHandle]:
        """Scheduler hint: warm a disk/remote session back to host in the
        background so the upcoming ``resume`` finds it resident. No-op
        for resident/in-flight/unknown uids."""
        with self._lock:
            if uid in self._inflight or uid in self._prefetching:
                return self._prefetching.get(uid)
            sess = self._sessions.get(uid)
            if sess is None or sess.resident:
                return None
            handle = TransferHandle(f"prefetch:{uid}")
            self._prefetching[uid] = handle
        self._prefetches.inc()
        self._get_worker().submit(lambda: self._bg_prefetch(uid), handle)
        return handle

    def _bg_prefetch(self, uid: int) -> None:
        try:
            with self._lock:
                sess = self._sessions.get(uid)
            if sess is None or sess.resident:
                return
            if sess.remote_name is not None:
                self._fetch_remote(sess)
            if sess.spill_path is not None:
                self._load_spill(sess)
        finally:
            with self._lock:
                self._prefetching.pop(uid, None)

    # -- disk tier ---------------------------------------------------------
    def _enforce_limit(self) -> None:
        limit = self.config.host_bytes_limit
        if limit is None:
            return
        with self._lock:
            resident = sorted(
                (s for s in self._sessions.values() if s.resident),
                key=lambda s: s.parked_at)
            total = sum(s.nbytes for s in resident)
        while total > limit and resident:
            victim = resident.pop(0)
            if not self._evict(victim):
                break                   # degraded: tolerate over-limit
            total -= victim.nbytes
        self._enforce_disk_limit()

    def _evict(self, sess: ParkedSession) -> bool:
        """Move one resident session down a tier. True iff it left the
        host tier; False means every lower tier refused (the session
        stays resident — never lost — and a degradation was recorded or
        an error raised when no tier exists at all)."""
        if self.config.spill_dir is not None:
            self._spill(sess)
            return True
        if self.config.remote is not None:
            return self._push_remote(sess)
        raise RuntimeError(
            f"host tier over host_bytes_limit and no spill_dir or remote "
            f"transport configured (session {sess.uid} has nowhere to go)")

    def _enforce_disk_limit(self) -> None:
        limit = self.config.disk_bytes_limit
        if limit is None:
            return
        with self._lock:
            spilled = sorted(
                (s for s in self._sessions.values()
                 if s.spill_path is not None),
                key=lambda s: s.parked_at)
            total = sum(s.nbytes for s in spilled)
        while total > limit and spilled:
            victim = spilled.pop(0)
            if not self._push_remote(victim):
                break                   # degraded: stays on disk
            total -= victim.nbytes

    def _spill(self, sess: ParkedSession) -> None:
        """Disk tier: one checksummed blob file per session (shared
        codec with the remote tier — remote/blob.py)."""
        path = os.path.join(self.config.spill_dir,
                            f"kv_session_{sess.uid}{SPILL_SUFFIX}")
        blob = encode_session(sess)
        tmp = f"{path}.tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
        with self._lock:
            if sess.uid not in self._sessions:      # dropped concurrently
                os.remove(path)
                return
            for k in sess.order:
                sess.leaves[k].data = None
            sess.spill_path = path
        self._spills.inc()
        self._spilled_b.inc(sess.nbytes)

    def _load_spill(self, sess: ParkedSession) -> None:
        with open(sess.spill_path, "rb") as f:
            data = f.read()
        decoded, _ = decode_session(data)   # CRC verified here
        if decoded.order != sess.order:
            raise ValueError(
                f"spill file {sess.spill_path} does not match session "
                f"{sess.uid}'s leaf order")
        for k in sess.order:
            sess.leaves[k].data = decoded.leaves[k].data
        os.remove(sess.spill_path)
        sess.spill_path = None

    # -- remote tier -------------------------------------------------------
    def _degrade(self, sess: ParkedSession, err: Exception) -> None:
        self._degraded.inc()
        with self._lock:
            self._events.append({
                "kind": "kvstore_remote_degraded", "uid": sess.uid,
                "error": str(err)[:200],
                "kept_tier": "disk" if sess.spill_path else "host"})

    def _push_remote(self, sess: ParkedSession) -> bool:
        """Push one session to the remote tier. On failure (after the
        transport's own retries) the session keeps its current tier copy
        — the disk file is only deleted after a successful put, so a
        degraded push leaves the session exactly where it was, never
        lost. True iff pushed."""
        transport = self.config.remote
        if sess.spill_path is not None:
            # the spill file IS the blob format: forward its bytes as-is
            # (the CRC written at spill time travels to the peer intact)
            with open(sess.spill_path, "rb") as f:
                blob = f.read()
        else:
            blob = encode_session(sess)
        name = f"spill/{sess.uid}"
        try:
            transport.put(name, blob)
        except (TransportError, OSError) as e:
            self._degrade(sess, e)
            return False
        spill_path = None
        with self._lock:
            if sess.uid not in self._sessions:      # dropped concurrently
                try:
                    transport.delete(name)
                except (TransportError, KeyError):
                    pass
                return True
            for k in sess.order:
                sess.leaves[k].data = None
            sess.remote_name = name
            spill_path, sess.spill_path = sess.spill_path, None
        if spill_path and os.path.exists(spill_path):
            os.remove(spill_path)
        self._remote_parks.inc()
        self._to_remote.inc(len(blob))
        return True

    def _fetch_remote(self, sess: ParkedSession) -> None:
        blob = self.config.remote.get(sess.remote_name)
        decoded, _ = decode_session(blob)   # CRC verified here
        if decoded.order != sess.order:
            raise ValueError(
                f"remote blob {sess.remote_name!r} does not match "
                f"session {sess.uid}'s leaf order")
        for k in sess.order:
            sess.leaves[k].data = decoded.leaves[k].data
        try:
            self.config.remote.delete(sess.remote_name)
        except (TransportError, KeyError):
            pass                        # best-effort remote GC
        sess.remote_name = None
        self._remote_resumes.inc()
        self._from_remote.inc(len(blob))

    # -- cross-process session movement (disaggregation rail) --------------
    def export(self, uid: int, *, name: Optional[str] = None,
               meta: Optional[dict] = None,
               transport: Optional[Transport] = None) -> str:
        """Serialize parked session ``uid`` (+ caller ``meta``) into one
        blob and put it on the transport; the local copy is removed —
        ownership moves to whoever imports the name. Returns the name."""
        transport = transport if transport is not None else self.config.remote
        if transport is None:
            raise ValueError("export needs a transport "
                             "(StoreConfig.remote or transport=...)")
        self._wait_uid(uid)
        with self._lock:
            sess = self._sessions.get(uid)
        if sess is None:
            raise KeyError(f"no parked session {uid}")
        if sess.remote_name is not None:
            self._fetch_remote(sess)
        if sess.spill_path is not None:
            self._load_spill(sess)
        name = name if name is not None else f"session/{uid}"
        blob = encode_session(sess, meta=meta)
        transport.put(name, blob)       # failure propagates; session kept
        with self._lock:
            self._sessions.pop(uid, None)
        self._exports.inc()
        self._to_remote.inc(len(blob))
        self._update_gauges()
        return name

    def import_remote(self, name: str, *,
                      transport: Optional[Transport] = None,
                      consume: bool = True) -> Tuple[int, dict]:
        """Fetch blob ``name``, verify it, and adopt the session into
        the host tier. Returns ``(uid, meta)``; ``consume`` deletes the
        blob after a successful import (ownership transferred)."""
        transport = transport if transport is not None else self.config.remote
        if transport is None:
            raise ValueError("import_remote needs a transport "
                             "(StoreConfig.remote or transport=...)")
        blob = transport.get(name)
        sess, meta = decode_session(blob)   # CRC verified here
        self._wait_uid(sess.uid)
        with self._lock:
            if sess.uid in self._sessions:
                raise ValueError(
                    f"session {sess.uid} (blob {name!r}) is already "
                    f"parked here")
            sess.parked_at = time.perf_counter()
            self._sessions[sess.uid] = sess
        if consume:
            try:
                transport.delete(name)
            except (TransportError, KeyError):
                pass
        self._imports.inc()
        self._from_remote.inc(len(blob))
        self._enforce_limit()
        self._update_gauges()
        return sess.uid, meta

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Flat float map for engine_tick metrics."""
        with self._lock:
            inflight = float(len(self._inflight))
        out = {
            "kvstore/sessions": float(len(self)),
            "kvstore/inflight_parks": inflight,
            "kvstore/host_bytes": float(self.host_bytes),
            "kvstore/parks": self._parks.value,
            "kvstore/resumes": self._resumes.value,
            "kvstore/bytes_to_host": self._to_host.value,
            "kvstore/bytes_to_device": self._to_dev.value,
            "kvstore/spills": self._spills.value,
            "kvstore/bytes_spilled": self._spilled_b.value,
            "kvstore/bytes_to_remote": self._to_remote.value,
            "kvstore/bytes_from_remote": self._from_remote.value,
            "kvstore/remote_parks": self._remote_parks.value,
            "kvstore/remote_resumes": self._remote_resumes.value,
            "kvstore/exports": self._exports.value,
            "kvstore/imports": self._imports.value,
            "kvstore/remote_degraded": self._degraded.value,
            "kvstore/prefetches": self._prefetches.value,
        }
        for name, h in (("park", self._park_s), ("resume", self._resume_s),
                        ("park_transfer", self._transfer_s)):
            if h.count:
                out[f"kvstore/{name}_p50_s"] = h.percentile(50)
                out[f"kvstore/{name}_p99_s"] = h.percentile(99)
        remote = self.config.remote
        if remote is not None and hasattr(remote, "stats"):
            out.update(remote.stats())
        return out
