"""Host/disk KV store: park a slot's cache lane off-device, resume it
bit-exact into any free slot (DESIGN.md §11).

``park(uid, lane)`` takes the B=1 pytree ``read_slot`` extracts and moves
it to the host tier; ``resume(uid)`` hands back a pytree ``write_slot``
accepts, with every leaf byte-identical to what was parked. Between the
two, storage is cut two ways:

  per-page compaction   cluster-paged leaves ((G, B, H, kc, cap, dh),
                        declared by each backend CacheLayout's
                        ``pageable_leaves``) keep only the occupied
                        prefix of each page — ``min(page_len, cap)``
                        slots per (head, cluster). Unoccupied page slots
                        are zeros by construction (fresh lanes are
                        zeroed, prefill writes only kept slots, decode
                        appends one slot at a time, reset re-zeros), so
                        dropping them and re-zeroing on resume is
                        bit-exact. Short sessions park at a fraction of
                        the full lane footprint.
  disk spill            beyond ``host_bytes_limit`` the least-recently
                        parked sessions spill to npz under ``spill_dir``
                        as uint8 views (bf16/ml_dtypes round-trip safely
                        through the raw bytes) and are reloaded on
                        resume.

Device→host transfers start async (``copy_to_host_async``) across all
leaves before the first blocking read, so lane leaves overlap on the
interconnect. Metrics (park/resume latency histograms, bytes moved,
spill counts) live in a ``repro.obs.Registry`` owned by the store; the
engine folds ``stats()`` into its ``engine_tick`` records.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import attn as attn_api
from repro.obs import Registry


@dataclass(frozen=True)
class StoreConfig:
    """Knobs for the tiered store.

    ``spill_dir``        directory for the disk tier (None = host only;
                         with a byte limit but no dir, over-limit parks
                         raise instead of silently growing)
    ``host_bytes_limit`` soft cap on resident parked bytes — exceeding
                         it spills least-recently-parked sessions
    ``compact_pages``    per-page compaction of cluster-paged leaves
                         (disable only for debugging round-trips)
    """

    spill_dir: Optional[str] = None
    host_bytes_limit: Optional[int] = None
    compact_pages: bool = True


@dataclass
class _LeafRec:
    shape: Tuple[int, ...]
    dtype: Any
    data: Optional[np.ndarray]          # None while spilled to disk
    page_len_key: Optional[str] = None  # set => data is the compacted
    #                                     occupied-prefix values


@dataclass
class ParkedSession:
    uid: int
    treedef: Any
    order: List[str]                    # leaf keys in flatten order
    leaves: Dict[str, _LeafRec] = field(default_factory=dict)
    nbytes: int = 0                     # host bytes (compacted)
    parked_at: float = 0.0
    spill_path: Optional[str] = None    # set while on the disk tier


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return ""


def _sibling_key(path, name: str) -> str:
    sib = tuple(path[:-1]) + (jax.tree_util.DictKey(name),)
    return jax.tree_util.keystr(sib)


def _occupied(rlen: np.ndarray, cap: int) -> np.ndarray:
    """(..., cap) bool mask of occupied ring slots per cluster page."""
    return np.arange(cap) < np.minimum(rlen, cap)[..., None]


class KVStore:
    """Tiered (host + optional disk) store of parked session lanes."""

    def __init__(self, config: StoreConfig = StoreConfig()):
        self.config = config
        self._sessions: Dict[int, ParkedSession] = {}
        self.obs = Registry()
        self._park_s = self.obs.histogram("kvstore/park_s")
        self._resume_s = self.obs.histogram("kvstore/resume_s")
        self._parks = self.obs.counter("kvstore/parks")
        self._resumes = self.obs.counter("kvstore/resumes")
        self._to_host = self.obs.counter("kvstore/bytes_to_host")
        self._to_dev = self.obs.counter("kvstore/bytes_to_device")
        self._spilled_b = self.obs.counter("kvstore/bytes_spilled")
        self._spills = self.obs.counter("kvstore/spills")
        if config.spill_dir:
            os.makedirs(config.spill_dir, exist_ok=True)

    # -- inventory ---------------------------------------------------------
    def __contains__(self, uid: int) -> bool:
        return uid in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    @property
    def host_bytes(self) -> int:
        return sum(s.nbytes for s in self._sessions.values()
                   if s.spill_path is None)

    def drop(self, uid: int) -> None:
        s = self._sessions.pop(uid, None)
        if s is not None and s.spill_path and os.path.exists(s.spill_path):
            os.remove(s.spill_path)

    # -- park --------------------------------------------------------------
    def park(self, uid: int, lane) -> ParkedSession:
        """Move the B=1 cache ``lane`` to the host tier under ``uid``."""
        if uid in self._sessions:
            raise ValueError(f"session {uid} is already parked")
        t0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten_with_path(lane)
        for _, leaf in flat:                    # overlap device→host
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()
        host = {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}
        pageable = (attn_api.pageable_cache_leaves()
                    if self.config.compact_pages else {})
        sess = ParkedSession(uid=uid, treedef=treedef,
                             order=[jax.tree_util.keystr(p) for p, _ in flat],
                             parked_at=t0)
        for path, _ in flat:
            key = jax.tree_util.keystr(path)
            arr = host[key]
            name = _leaf_name(path)
            if name in pageable:
                rlen_key = _sibling_key(path, pageable[name])
                if rlen_key in host:
                    occ = _occupied(host[rlen_key], arr.shape[-2])
                    sess.leaves[key] = _LeafRec(arr.shape, arr.dtype,
                                                np.ascontiguousarray(arr[occ]),
                                                page_len_key=rlen_key)
                    continue
            sess.leaves[key] = _LeafRec(arr.shape, arr.dtype,
                                        np.ascontiguousarray(arr))
        sess.nbytes = sum(r.data.nbytes for r in sess.leaves.values())
        self._sessions[uid] = sess
        self._enforce_limit()
        dt = time.perf_counter() - t0
        self._park_s.record(dt)
        self._parks.inc()
        self._to_host.inc(sess.nbytes)
        self.obs.gauge("kvstore/host_bytes").set(self.host_bytes)
        self.obs.gauge("kvstore/sessions").set(len(self._sessions))
        return sess

    # -- resume ------------------------------------------------------------
    def resume(self, uid: int):
        """Rebuild ``uid``'s lane (bit-exact) and remove it from the store.

        Returns a host pytree in the exact structure/dtypes ``write_slot``
        validates against the pool; the jitted write streams it back to
        the device.
        """
        sess = self._sessions.get(uid)
        if sess is None:
            raise KeyError(f"no parked session {uid}")
        t0 = time.perf_counter()
        if sess.spill_path is not None:
            self._load_spill(sess)
        # pass 1: full (non-compacted) leaves — includes every page_len
        # leaf the compacted ones need
        full: Dict[str, np.ndarray] = {
            k: r.data for k, r in sess.leaves.items()
            if r.page_len_key is None}
        # pass 2: re-expand compacted cluster pages against their rlen
        for key, rec in sess.leaves.items():
            if rec.page_len_key is None:
                continue
            out = np.zeros(rec.shape, rec.dtype)
            occ = _occupied(full[rec.page_len_key], rec.shape[-2])
            out[occ] = rec.data
            full[key] = out
        lane = jax.tree_util.tree_unflatten(
            sess.treedef, [full[k] for k in sess.order])
        del self._sessions[uid]
        if sess.spill_path and os.path.exists(sess.spill_path):
            os.remove(sess.spill_path)
        dt = time.perf_counter() - t0
        self._resume_s.record(dt)
        self._resumes.inc()
        self._to_dev.inc(sess.nbytes)
        self.obs.gauge("kvstore/host_bytes").set(self.host_bytes)
        self.obs.gauge("kvstore/sessions").set(len(self._sessions))
        return lane

    # -- disk tier ---------------------------------------------------------
    def _enforce_limit(self) -> None:
        limit = self.config.host_bytes_limit
        if limit is None:
            return
        resident = [(s.parked_at, s) for s in self._sessions.values()
                    if s.spill_path is None]
        resident.sort(key=lambda x: x[0])
        total = sum(s.nbytes for _, s in resident)
        while total > limit and resident:
            _, victim = resident.pop(0)
            if self.config.spill_dir is None:
                raise RuntimeError(
                    f"host tier over host_bytes_limit ({total} > {limit} "
                    f"bytes) and no spill_dir configured")
            self._spill(victim)
            total -= victim.nbytes

    def _spill(self, sess: ParkedSession) -> None:
        path = os.path.join(self.config.spill_dir,
                            f"kv_session_{sess.uid}.npz")
        # uint8 views: np.savez would mangle ml_dtypes (bf16) leaves; the
        # true dtype/shape stay in the in-memory _LeafRec metadata
        np.savez(path, **{f"a{i}": sess.leaves[k].data.view(np.uint8)
                          for i, k in enumerate(sess.order)})
        for k in sess.order:
            sess.leaves[k].data = None
        sess.spill_path = path
        self._spills.inc()
        self._spilled_b.inc(sess.nbytes)

    def _load_spill(self, sess: ParkedSession) -> None:
        with np.load(sess.spill_path) as z:
            for i, k in enumerate(sess.order):
                rec = sess.leaves[k]
                raw = z[f"a{i}"]
                flat = raw.view(rec.dtype)
                if rec.page_len_key is None:
                    rec.data = flat.reshape(rec.shape)
                else:           # compacted: (n_occupied, dh)
                    rec.data = flat.reshape(-1, rec.shape[-1])
        os.remove(sess.spill_path)
        sess.spill_path = None

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Flat float map for engine_tick metrics."""
        out = {
            "kvstore/sessions": float(len(self._sessions)),
            "kvstore/host_bytes": float(self.host_bytes),
            "kvstore/parks": self._parks.value,
            "kvstore/resumes": self._resumes.value,
            "kvstore/bytes_to_host": self._to_host.value,
            "kvstore/bytes_to_device": self._to_dev.value,
            "kvstore/spills": self._spills.value,
            "kvstore/bytes_spilled": self._spilled_b.value,
        }
        for name, h in (("park", self._park_s), ("resume", self._resume_s)):
            if h.count:
                out[f"kvstore/{name}_p50_s"] = h.percentile(50)
                out[f"kvstore/{name}_p99_s"] = h.percentile(99)
        return out
