"""Hash-keyed prefix cache: shared prompts fill their cache lane once.

Keys are the SHA-1 of the token prompt. Two lookup modes:

  exact    (default) the full prompt must match byte-for-byte — every
           hit is byte-identical to a miss by construction, which is
           what the engine's bit-parity contract requires for *all*
           attention variants.
  partial  longest-prefix match: the longest cached entry whose prompt
           is a prefix of the query is returned with ``matched`` set to
           the prefix length, and the caller teacher-forces the
           remaining ``prompt[matched:]`` tokens through decode steps.

Partial reuse is only bit-exact for cache layouts whose prefill and
decode write identical state for identical token streams — append
(full attention k/v) and ring (local windows): a decode step at
position p writes exactly the row/slot prefill would have. Routing
caches break this — prefill fills cluster pages with *balanced top-k*
membership while decode routes each token to its argmax page only, so
teacher-forcing a tail over a shorter cached prefix produces different
pages (and different logits) than prefilling the whole prompt
(DESIGN.md §11). The engine therefore gates ``partial=True`` on the
model's cache layouts (serving.decode_cache_layouts ⊆ {append, ring});
cluster-page layouts keep exact full-prompt keying.

An entry is the prefilled B=1 lane plus the last-position logits row
(so an exact hit samples the first output token without running the
model), both held as read-only numpy (``writeable=False``) — entries
are shared by reference across sessions, and ``write_slot`` copies them
into the pool, so a hit never aliases device state.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import NamedTuple, Optional, Sequence

import jax
import numpy as np

from repro.obs import Registry


class PrefixHit(NamedTuple):
    """A cache hit: ``lane`` prefilled over ``prompt[:matched]`` and the
    logits row at position ``matched - 1``. ``matched == len(prompt)``
    for exact hits; shorter only under ``get(..., partial=True)``."""

    lane: object
    last_logits: np.ndarray
    matched: int


def _freeze(x: np.ndarray) -> np.ndarray:
    x = np.ascontiguousarray(x)
    x.setflags(write=False)
    return x


def _as_tokens(prompt: Sequence[int]) -> np.ndarray:
    return np.asarray(prompt, np.int64)


class PrefixCache:
    """LRU map: SHA-1(prompt tokens) -> PrefixHit, with optional
    longest-prefix partial lookup."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError("PrefixCache capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[str, PrefixHit]" = OrderedDict()
        self.obs = Registry()
        self._hits = self.obs.counter("kvstore/prefix_hits")
        self._partial = self.obs.counter("kvstore/prefix_partial_hits")
        self._misses = self.obs.counter("kvstore/prefix_misses")

    @staticmethod
    def key(prompt: Sequence[int]) -> str:
        return hashlib.sha1(_as_tokens(prompt).tobytes()).hexdigest()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, prompt: Sequence[int],
            partial: bool = False) -> Optional[PrefixHit]:
        """The entry for ``prompt`` (exact), or — under ``partial`` —
        the entry for the *longest cached strict prefix* of ``prompt``
        (``matched < len(prompt)``; the caller owns teacher-forcing the
        tail and the layout gate that makes that bit-exact). None on
        miss."""
        toks = _as_tokens(prompt)
        k = hashlib.sha1(toks.tobytes()).hexdigest()
        hit = self._entries.get(k)
        if hit is not None:
            self._entries.move_to_end(k)
            self._hits.inc()
            return hit
        if partial:
            # one incremental SHA-1 sweep: hash every proper prefix,
            # remember the longest that names an entry
            best_key = None
            h = hashlib.sha1()
            raw = toks.tobytes()
            for n in range(1, len(toks)):
                h.update(raw[(n - 1) * 8:n * 8])
                pk = h.hexdigest()
                if pk in self._entries:
                    best_key = pk
            if best_key is not None:
                self._entries.move_to_end(best_key)
                self._partial.inc()
                return self._entries[best_key]
        self._misses.inc()
        return None

    def put(self, prompt: Sequence[int], lane, last_logits) -> None:
        """Store the prefilled ``lane`` + ``last_logits`` (1, V) row."""
        k = self.key(prompt)
        if k in self._entries:
            self._entries.move_to_end(k)
            return
        host_lane = jax.tree.map(lambda x: _freeze(np.asarray(x)), lane)
        self._entries[k] = PrefixHit(host_lane,
                                     _freeze(np.asarray(last_logits)),
                                     len(_as_tokens(prompt)))
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self._hits.value + self._partial.value + self._misses.value
        return (self._hits.value + self._partial.value) / n if n else 0.0

    def stats(self) -> dict:
        return {
            "kvstore/prefix_entries": float(len(self._entries)),
            "kvstore/prefix_hits": self._hits.value,
            "kvstore/prefix_partial_hits": self._partial.value,
            "kvstore/prefix_misses": self._misses.value,
            "kvstore/prefix_hit_rate": self.hit_rate,
        }
