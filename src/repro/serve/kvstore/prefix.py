"""Hash-keyed prefix cache: shared prompts fill their cache lane once.

Keys are the SHA-1 of the *full* token prompt. This is deliberate — for
routing caches a partial-prefix continuation is not bit-exact: prefill
fills cluster pages with balanced top-k membership while decode routes
each token to its argmax page only, so teacher-forcing the tail of a
prompt over a shorter cached prefix produces different hidden states
than prefilling the whole prompt (DESIGN.md §11). Exact full-prompt
keying keeps every hit byte-identical to a miss, which is what the
engine's bit-parity contract requires; the win is the common serving
shape where many sessions share one system/task prompt verbatim.

An entry is the prefilled B=1 lane plus the last-position logits row
(so the hit path samples the first output token without running the
model), both held as read-only numpy (``writeable=False``) — entries
are shared by reference across sessions, and ``write_slot`` copies them
into the pool, so a hit never aliases device state.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.obs import Registry


def _freeze(x: np.ndarray) -> np.ndarray:
    x = np.ascontiguousarray(x)
    x.setflags(write=False)
    return x


class PrefixCache:
    """LRU map: SHA-1(prompt tokens) -> (read-only lane, last logits row)."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError("PrefixCache capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[str, Tuple[object, np.ndarray]]" = \
            OrderedDict()
        self.obs = Registry()
        self._hits = self.obs.counter("kvstore/prefix_hits")
        self._misses = self.obs.counter("kvstore/prefix_misses")

    @staticmethod
    def key(prompt: Sequence[int]) -> str:
        return hashlib.sha1(
            np.asarray(prompt, np.int64).tobytes()).hexdigest()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, prompt: Sequence[int]
            ) -> Optional[Tuple[object, np.ndarray]]:
        """(lane, last_logits_row) for an exact prompt match, else None."""
        k = self.key(prompt)
        hit = self._entries.get(k)
        if hit is None:
            self._misses.inc()
            return None
        self._entries.move_to_end(k)
        self._hits.inc()
        return hit

    def put(self, prompt: Sequence[int], lane, last_logits) -> None:
        """Store the prefilled ``lane`` + ``last_logits`` (1, V) row."""
        k = self.key(prompt)
        if k in self._entries:
            self._entries.move_to_end(k)
            return
        host_lane = jax.tree.map(lambda x: _freeze(np.asarray(x)), lane)
        self._entries[k] = (host_lane, _freeze(np.asarray(last_logits)))
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self._hits.value + self._misses.value
        return self._hits.value / n if n else 0.0

    def stats(self) -> dict:
        return {
            "kvstore/prefix_entries": float(len(self._entries)),
            "kvstore/prefix_hits": self._hits.value,
            "kvstore/prefix_misses": self._misses.value,
            "kvstore/prefix_hit_rate": self.hit_rate,
        }
