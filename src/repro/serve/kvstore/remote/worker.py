"""Background transfer worker: the thread that makes park/resume async.

One daemon thread, one FIFO queue. Every off-device byte movement the
KV store does asynchronously — host materialization of a parking lane,
tier eviction (disk write / transport put), resume prefetch — runs here
in submission order, so tier state changes are serialized without
holding the store lock across IO. The engine's admission path only
*enqueues*: ``park()`` under ``async_transfers`` returns as soon as the
device→host copies are launched, and the decode step it would have
blocked overlaps with the transfer.

``TransferHandle`` is the rendezvous: ``wait()`` blocks until the job
ran and re-raises the job's exception in the waiter (so a failed
background park surfaces at the resume/export/flush that depends on
it, never silently).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional


class TransferHandle:
    """Completion handle for one background transfer job."""

    def __init__(self, label: str = ""):
        self.label = label
        self._event = threading.Event()
        self._error: Optional[BaseException] = None
        self._result = None

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None):
        """Block until the job finished; re-raise its error, return its
        result. Raises TimeoutError if ``timeout`` elapses first."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"transfer {self.label or '<unnamed>'} did not complete "
                f"within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


class TransferWorker:
    """One daemon thread draining transfer jobs FIFO."""

    def __init__(self, name: str = "kvstore-transfer"):
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    def submit(self, fn: Callable[[], object],
               handle: Optional[TransferHandle] = None) -> TransferHandle:
        if handle is None:
            handle = TransferHandle(getattr(fn, "__name__", "job"))
        self._q.put((fn, handle))
        return handle

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, handle = item
            try:
                handle._result = fn()
            except BaseException as e:          # surfaced via wait()
                handle._error = e
            finally:
                handle._event.set()

    def flush(self, timeout: Optional[float] = None) -> None:
        """Wait until every job enqueued so far has run (errors in those
        jobs surface at their own handles, not here)."""
        marker = self.submit(lambda: None)
        if not marker._event.wait(timeout):
            raise TimeoutError("transfer worker did not drain in time")

    def close(self, timeout: float = 10.0) -> None:
        self._q.put(None)
        self._thread.join(timeout)
