"""repro.serve.kvstore.remote — the distributed KV plane (§11.5).

Three layers under one roof:

  blob       the shared codec: versioned header (treedef skeleton, leaf
             dtypes/shapes, compacted-page lengths) + CRC32, verified on
             every decode — disk spill and remote transfer speak the
             same format
  transport  named-blob put/get/delete/exists: LoopbackTransport
             (in-process), TCPTransport + TCPStoreServer (peer host,
             framed sockets, timeouts + bounded backoff retries),
             FileTransport (shared directory / object-store mount),
             FaultInjectionTransport (deterministic failure drills)
  worker     the background transfer thread that makes park/resume
             async (device→host copies and transport puts overlap the
             next decode steps)

``KVStore`` consumes all of this via ``StoreConfig(remote=...,
async_transfers=...)`` — see repro.serve.kvstore.store.
"""
from repro.serve.kvstore.remote.blob import (BLOB_VERSION, BlobChecksumError,
                                             BlobError, decode_session,
                                             encode_session)
from repro.serve.kvstore.remote.tcp import TCPStoreServer, TCPTransport
from repro.serve.kvstore.remote.transport import (BlobNotFound,
                                                  FaultInjectionTransport,
                                                  FileTransport,
                                                  InstrumentedTransport,
                                                  LoopbackTransport,
                                                  RetryPolicy, Transport,
                                                  TransportError,
                                                  with_retries)
from repro.serve.kvstore.remote.worker import TransferHandle, TransferWorker

__all__ = [
    "BLOB_VERSION", "BlobChecksumError", "BlobError", "BlobNotFound",
    "FaultInjectionTransport", "FileTransport", "InstrumentedTransport",
    "LoopbackTransport", "RetryPolicy", "TCPStoreServer", "TCPTransport",
    "Transport", "TransferHandle", "TransferWorker", "TransportError",
    "decode_session", "encode_session", "with_retries",
]
