"""One codec for every off-device tier: versioned blob header + CRC32.

A blob is the wire/file form of a ``ParkedSession`` — the *same*
compacted cluster-page representation the host tier keeps (only the
occupied ``min(rlen, cap)`` prefix of each page travels), so a remote
round trip is bit-exact to the logit for exactly the reason the local
one is. Layout::

    offset 0   magic  b"RKVB"
           4   u8     version (== BLOB_VERSION)
           5   u32    header length (big-endian)
           9   header JSON (utf-8)
           ..  leaf payload bytes, concatenated in header order
        last 4 u32    CRC32 of everything before it (big-endian)

The header carries the pytree *skeleton* (the nested list/dict
structure with leaf indices at the leaves — cache lanes are plain
JSON-able containers, which ``encode_session`` enforces loudly), and
per-leaf metadata: key path, logical shape, dtype name, stored shape
(compacted leaves store fewer rows than their logical shape), byte
length, and the page-length sibling key compacted leaves re-expand
against. Plus an arbitrary JSON ``meta`` dict for the caller (the
engine rides session/request state through it for disaggregation).

The CRC is verified on decode: a truncated or corrupted blob — on disk
*or* fetched over a transport — raises ``BlobChecksumError`` instead of
resuming silent garbage. The local disk spill writes this same format
(``KVStore._spill``), which is what closed PR 7's unchecksummed-npz
hole: local and remote tiers share one codec and one failure mode.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MAGIC = b"RKVB"
BLOB_VERSION = 1
_HEAD = struct.Struct(">4sBI")          # magic, version, header_len
_CRC = struct.Struct(">I")


class BlobError(ValueError):
    """Malformed blob (bad magic/version/header, truncated payload)."""


class BlobChecksumError(BlobError):
    """CRC32 mismatch — the blob was corrupted in storage or transit."""


def _dtype_name(dt) -> str:
    return np.dtype(dt).name


def _dtype_from_name(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        # ml_dtypes names (bfloat16, float8_*) resolve once jax's dtype
        # extensions are imported; jnp carries them as attributes
        import jax.numpy as jnp
        dt = getattr(jnp, name, None)
        if dt is None:
            raise BlobError(f"unknown leaf dtype {name!r}")
        return np.dtype(dt)


def _skeleton(treedef, n_leaves: int):
    """The container structure with leaf *indices* as leaves — must be
    JSON-able (cache lanes are lists/dicts all the way down)."""
    skel = jax.tree_util.tree_unflatten(treedef, list(range(n_leaves)))
    try:
        json.dumps(skel)
    except TypeError as e:
        raise BlobError(
            f"cache tree contains non-JSON-able containers ({e}); the "
            f"blob codec supports list/dict pytrees only") from None
    return skel


def _rebuild(skel, leaves: List[np.ndarray]):
    if isinstance(skel, int):
        return leaves[skel]
    if isinstance(skel, list):
        return [_rebuild(s, leaves) for s in skel]
    if isinstance(skel, dict):
        return {k: _rebuild(v, leaves) for k, v in skel.items()}
    raise BlobError(f"unsupported skeleton node {type(skel).__name__}")


def encode_session(sess, meta: Optional[Dict[str, Any]] = None) -> bytes:
    """Serialize a resident ``ParkedSession`` (leaves must be in host
    memory, not spilled) plus an optional JSON ``meta`` dict."""
    leaves_meta = []
    payloads = []
    for key in sess.order:
        rec = sess.leaves[key]
        if rec.data is None:
            raise BlobError(
                f"leaf {key!r} of session {sess.uid} is not resident "
                f"(spilled?); load it before encoding")
        raw = np.ascontiguousarray(rec.data).view(np.uint8).reshape(-1)
        leaves_meta.append({
            "key": key,
            "shape": list(rec.shape),
            "dtype": _dtype_name(rec.dtype),
            "stored_shape": list(rec.data.shape),
            "nbytes": int(raw.nbytes),
            "page_len_key": rec.page_len_key,
        })
        payloads.append(raw.tobytes())
    header = {
        "uid": sess.uid,
        "skeleton": _skeleton(sess.treedef, len(sess.order)),
        "leaves": leaves_meta,
        "meta": meta if meta is not None else {},
    }
    hdr = json.dumps(header, separators=(",", ":")).encode()
    body = b"".join([_HEAD.pack(MAGIC, BLOB_VERSION, len(hdr)), hdr,
                     *payloads])
    return body + _CRC.pack(zlib.crc32(body) & 0xFFFFFFFF)


def decode_session(data: bytes) -> Tuple[object, Dict[str, Any]]:
    """Rebuild a ``ParkedSession`` (host tier, fully resident) and the
    caller ``meta`` dict from ``encode_session`` output. Verifies the
    CRC32 before trusting a single byte of the payload."""
    from repro.serve.kvstore.store import ParkedSession, _LeafRec

    if len(data) < _HEAD.size + _CRC.size:
        raise BlobError(f"blob truncated: {len(data)} bytes")
    (crc_stored,) = _CRC.unpack_from(data, len(data) - _CRC.size)
    if zlib.crc32(data[:-_CRC.size]) & 0xFFFFFFFF != crc_stored:
        raise BlobChecksumError(
            "blob CRC32 mismatch — corrupted in storage or transit")
    magic, version, hdr_len = _HEAD.unpack_from(data, 0)
    if magic != MAGIC:
        raise BlobError(f"bad blob magic {magic!r}")
    if version != BLOB_VERSION:
        raise BlobError(f"unsupported blob version {version} "
                        f"(this codec reads {BLOB_VERSION})")
    off = _HEAD.size
    try:
        header = json.loads(data[off:off + hdr_len])
    except ValueError as e:
        raise BlobError(f"unreadable blob header ({e})")
    off += hdr_len
    leaves: List[np.ndarray] = []
    recs: Dict[str, _LeafRec] = {}
    order: List[str] = []
    for lm in header["leaves"]:
        n = int(lm["nbytes"])
        if off + n > len(data) - _CRC.size:
            raise BlobError(f"blob payload truncated at leaf {lm['key']!r}")
        dt = _dtype_from_name(lm["dtype"])
        arr = (np.frombuffer(data, np.uint8, count=n, offset=off)
               .view(dt).reshape(lm["stored_shape"]).copy())
        off += n
        order.append(lm["key"])
        recs[lm["key"]] = _LeafRec(tuple(lm["shape"]), dt, arr,
                                   page_len_key=lm["page_len_key"])
        leaves.append(arr)
    # recover the treedef from the JSON skeleton (leaf order under
    # tree_flatten matches encode's: dict keys flatten sorted, and JSON
    # round-trips key strings unchanged)
    tree = _rebuild(header["skeleton"], list(range(len(leaves))))
    idx, treedef = jax.tree_util.tree_flatten(tree)
    if idx != sorted(idx):
        raise BlobError("blob skeleton leaf order disagrees with "
                        "flatten order")
    sess = ParkedSession(uid=int(header["uid"]), treedef=treedef,
                         order=order, leaves=recs)
    sess.nbytes = sum(r.data.nbytes for r in recs.values())
    return sess, header.get("meta", {})
