"""TCP blob peer: length-prefixed framing, timeouts, retried client.

Wire protocol (all integers big-endian)::

    request   u64 frame_len | u8 op | u32 name_len | name utf-8 | payload
    response  u64 frame_len | u8 status | payload

``frame_len`` counts everything after itself, so both sides read
exactly one length then exactly one frame — no delimiters, no
ambiguity at any blob size. Ops: PUT(payload=blob), GET, DELETE,
EXISTS (payload ``\\x01``/``\\x00`` back), LIST (payload=prefix, JSON
list back), PING (liveness probe for ``wait_until_ready``). Status:
OK / NOT_FOUND / ERROR (payload = utf-8 message).

``TCPStoreServer`` is the peer host's side: an accept loop + one
handler thread per connection (connections are long-lived; each serves
many requests), blobs in an in-memory dict. It is deliberately dumb —
the KV store on the *client* side owns tiering, checksums, and retry
policy; the server just holds named bytes. ``TCPTransport`` is the
client: one connection per op (reconnect == retry unit), connect/read
timeouts, and bounded exponential-backoff retries via ``RetryPolicy``
for transient socket errors (a NOT_FOUND answer is deterministic and
never retried).
"""
from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Dict, List, Optional, Tuple

from repro.serve.kvstore.remote.transport import (BlobNotFound,
                                                  InstrumentedTransport,
                                                  RetryPolicy,
                                                  TransportError,
                                                  with_retries)

OP_PUT, OP_GET, OP_DELETE, OP_EXISTS, OP_LIST, OP_PING = 1, 2, 3, 4, 5, 6
OK, NOT_FOUND, ERROR = 0, 1, 2

_LEN = struct.Struct(">Q")
_REQ = struct.Struct(">BI")             # op, name_len
_STATUS = struct.Struct(">B")
MAX_FRAME = 1 << 34                     # 16 GiB: sanity bound on frames


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise TransportError("peer closed the connection mid-frame")
        buf += chunk
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if n > MAX_FRAME:
        raise TransportError(f"frame length {n} exceeds bound {MAX_FRAME}")
    return _recv_exact(sock, n)


def _send_frame(sock: socket.socket, *parts: bytes) -> None:
    body = b"".join(parts)
    sock.sendall(_LEN.pack(len(body)) + body)


class TCPStoreServer:
    """In-memory blob store serving the wire protocol above.

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    Runs its accept loop on a daemon thread; ``close()`` (or the
    context manager) shuts it down and drops every live connection.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._blobs: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(32)
        self.host, self.port = self._sock.getsockname()[:2]
        self._closing = False
        self._thread = threading.Thread(target=self._accept_loop,
                                        name="kv-blob-server", daemon=True)
        self._thread.start()

    @property
    def address(self) -> Tuple[str, int]:
        return (self.host, self.port)

    def __len__(self) -> int:
        with self._lock:
            return len(self._blobs)

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return                  # socket closed by close()
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            conn.settimeout(300.0)
            while not self._closing:
                try:
                    frame = _recv_frame(conn)
                except (TransportError, OSError):
                    return              # client went away
                try:
                    status, payload = self._handle(frame)
                except Exception as e:  # never kill the connection loop
                    status, payload = ERROR, str(e).encode()
                try:
                    _send_frame(conn, _STATUS.pack(status), payload)
                except OSError:
                    return

    def _handle(self, frame: bytes) -> Tuple[int, bytes]:
        op, name_len = _REQ.unpack_from(frame, 0)
        off = _REQ.size
        name = frame[off:off + name_len].decode()
        payload = frame[off + name_len:]
        if op == OP_PUT:
            with self._lock:
                self._blobs[name] = payload
            return OK, b""
        if op == OP_GET:
            with self._lock:
                data = self._blobs.get(name)
            return (NOT_FOUND, b"") if data is None else (OK, data)
        if op == OP_DELETE:
            with self._lock:
                had = self._blobs.pop(name, None) is not None
            return (OK, b"") if had else (NOT_FOUND, b"")
        if op == OP_EXISTS:
            with self._lock:
                return OK, (b"\x01" if name in self._blobs else b"\x00")
        if op == OP_LIST:
            prefix = payload.decode()
            with self._lock:
                names = [n for n in self._blobs if n.startswith(prefix)]
            return OK, json.dumps(sorted(names)).encode()
        if op == OP_PING:
            return OK, b""
        return ERROR, f"unknown op {op}".encode()

    def close(self) -> None:
        self._closing = True
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class TCPTransport(InstrumentedTransport):
    """Client to a ``TCPStoreServer`` peer, with timeouts + retries."""

    def __init__(self, host: str, port: int, *,
                 connect_timeout_s: float = 5.0,
                 io_timeout_s: float = 60.0,
                 retry: RetryPolicy = RetryPolicy()):
        super().__init__()
        self.host, self.port = host, int(port)
        self.connect_timeout_s = connect_timeout_s
        self.io_timeout_s = io_timeout_s
        self.retry = retry

    def __repr__(self) -> str:
        return f"TCPTransport({self.host}:{self.port})"

    def _rpc_once(self, op: int, name: str,
                  payload: bytes = b"") -> Tuple[int, bytes]:
        nb = name.encode()
        with socket.create_connection(
                (self.host, self.port),
                timeout=self.connect_timeout_s) as sock:
            sock.settimeout(self.io_timeout_s)
            _send_frame(sock, _REQ.pack(op, len(nb)), nb, payload)
            resp = _recv_frame(sock)
        (status,) = _STATUS.unpack_from(resp, 0)
        body = resp[_STATUS.size:]
        if status == ERROR:
            raise TransportError(
                f"peer {self.host}:{self.port} errored: {body.decode()}")
        return status, body

    def _rpc(self, op: int, name: str,
             payload: bytes = b"") -> Tuple[int, bytes]:
        return with_retries(
            lambda: self._rpc_once(op, name, payload), self.retry,
            retry_on=(OSError, TransportError),
            on_retry=lambda i, e: self._retries.inc())

    def wait_until_ready(self, timeout_s: float = 30.0) -> None:
        """Block until the peer answers a PING (process rendezvous for
        the two-pool harness); raises TransportError on timeout."""
        policy = RetryPolicy(attempts=max(int(timeout_s / 0.25), 1),
                             base_delay_s=0.25, factor=1.0,
                             max_delay_s=0.25)
        with_retries(lambda: self._rpc_once(OP_PING, ""), policy,
                     retry_on=(OSError, TransportError),
                     on_retry=lambda i, e: self._retries.inc())

    def _put(self, name, data):
        self._rpc(OP_PUT, name, data)

    def _get(self, name):
        status, body = self._rpc(OP_GET, name)
        if status == NOT_FOUND:
            raise BlobNotFound(f"no blob named {name!r} on "
                               f"{self.host}:{self.port}")
        return body

    def _delete(self, name):
        status, _ = self._rpc(OP_DELETE, name)
        if status == NOT_FOUND:
            raise BlobNotFound(f"no blob named {name!r} on "
                               f"{self.host}:{self.port}")

    def _exists(self, name):
        _, body = self._rpc(OP_EXISTS, name)
        return body == b"\x01"

    def _list(self, prefix):
        _, body = self._rpc(OP_LIST, "", prefix.encode())
        return json.loads(body.decode())
