"""Blob transports: named put/get/delete/exists over a remote store.

``Transport`` is the protocol the KV store's remote tier speaks —
whole blobs under string names, nothing smarter. Three implementations:

  LoopbackTransport  in-process dict. Deterministic, zero IO — what the
                     tests and the single-process disaggregation harness
                     use so every failure is reproducible.
  FileTransport      a shared directory (NFS / fuse-mounted object
                     store): one file per blob, written atomically
                     (tmp + rename) so a concurrent reader never sees a
                     half-written blob.
  TCPTransport       sockets to a peer ``TCPStoreServer`` (remote/tcp.py)
                     with connect/read timeouts and bounded
                     exponential-backoff retries.

All of them extend ``InstrumentedTransport``: every op is counted and
timed into a ``repro.obs`` Registry (`transport/puts`, bytes in/out,
put/get latency histograms, retries, failures) whose ``stats()`` the
KV store folds into the engine's ``engine_tick`` records.

``FaultInjectionTransport`` wraps any of them and injects the failure
menagerie the fault suite needs — dropped puts, truncated/corrupted
gets, transient errors that exercise the retry path — deterministically
(counted, not random).
"""
from __future__ import annotations

import os
import threading
import time
import urllib.parse
from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Protocol, Tuple

from repro.obs import Registry


class TransportError(RuntimeError):
    """Transport-level failure (connection, framing, server error)."""


class BlobNotFound(TransportError, KeyError):
    """``get``/``delete`` of a name that holds no blob."""

    def __str__(self) -> str:        # KeyError quotes its arg; keep msg
        return RuntimeError.__str__(self)


class Transport(Protocol):
    """What the KV store's remote tier needs from a peer blob store."""

    def put(self, name: str, data: bytes) -> None: ...
    def get(self, name: str) -> bytes: ...
    def delete(self, name: str) -> None: ...
    def exists(self, name: str) -> bool: ...
    def list_blobs(self, prefix: str = "") -> List[str]: ...


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff: ``attempts`` total tries, sleeping
    ``min(base_delay_s * factor**i, max_delay_s)`` between them."""

    attempts: int = 4
    base_delay_s: float = 0.05
    factor: float = 2.0
    max_delay_s: float = 2.0

    def delays(self) -> Iterable[float]:
        d = self.base_delay_s
        for _ in range(max(self.attempts - 1, 0)):
            yield min(d, self.max_delay_s)
            d *= self.factor


def with_retries(fn: Callable[[], object], policy: RetryPolicy, *,
                 retry_on: Tuple[type, ...] = (TransportError, OSError),
                 no_retry: Tuple[type, ...] = (BlobNotFound,),
                 on_retry: Optional[Callable[[int, Exception], None]] = None):
    """Run ``fn`` under ``policy``. ``no_retry`` exceptions (a missing
    blob is a deterministic answer, not a transient fault) propagate
    immediately; the last transient error propagates after the final
    attempt."""
    delays = list(policy.delays()) + [None]
    last: Optional[Exception] = None
    for attempt, delay in enumerate(delays):
        try:
            return fn()
        except no_retry:
            raise
        except retry_on as e:
            last = e
            if delay is None:
                break
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(delay)
    raise TransportError(
        f"gave up after {policy.attempts} attempts: {last}") from last


class InstrumentedTransport:
    """Base class: public ops wrap subclass ``_put``/``_get``/... with
    counters + latency histograms; ``stats()`` is engine_tick food."""

    def __init__(self):
        self.obs = Registry()
        self._puts = self.obs.counter("transport/puts")
        self._gets = self.obs.counter("transport/gets")
        self._deletes = self.obs.counter("transport/deletes")
        self._bytes_out = self.obs.counter("transport/bytes_out")
        self._bytes_in = self.obs.counter("transport/bytes_in")
        self._retries = self.obs.counter("transport/retries")
        self._failures = self.obs.counter("transport/failures")
        self._put_s = self.obs.histogram("transport/put_s")
        self._get_s = self.obs.histogram("transport/get_s")

    # subclasses implement these
    def _put(self, name: str, data: bytes) -> None:
        raise NotImplementedError

    def _get(self, name: str) -> bytes:
        raise NotImplementedError

    def _delete(self, name: str) -> None:
        raise NotImplementedError

    def _exists(self, name: str) -> bool:
        raise NotImplementedError

    def _list(self, prefix: str) -> List[str]:
        raise NotImplementedError

    def put(self, name: str, data: bytes) -> None:
        t0 = time.perf_counter()
        try:
            self._put(name, bytes(data))
        except Exception:
            self._failures.inc()
            raise
        self._put_s.record(time.perf_counter() - t0)
        self._puts.inc()
        self._bytes_out.inc(len(data))

    def get(self, name: str) -> bytes:
        t0 = time.perf_counter()
        try:
            data = self._get(name)
        except BlobNotFound:
            raise
        except Exception:
            self._failures.inc()
            raise
        self._get_s.record(time.perf_counter() - t0)
        self._gets.inc()
        self._bytes_in.inc(len(data))
        return data

    def delete(self, name: str) -> None:
        try:
            self._delete(name)
        except BlobNotFound:
            raise
        except Exception:
            self._failures.inc()
            raise
        self._deletes.inc()

    def exists(self, name: str) -> bool:
        return self._exists(name)

    def list_blobs(self, prefix: str = "") -> List[str]:
        return sorted(self._list(prefix))

    def stats(self) -> dict:
        out = {
            "transport/puts": self._puts.value,
            "transport/gets": self._gets.value,
            "transport/deletes": self._deletes.value,
            "transport/bytes_out": self._bytes_out.value,
            "transport/bytes_in": self._bytes_in.value,
            "transport/retries": self._retries.value,
            "transport/failures": self._failures.value,
        }
        for name, h in (("put", self._put_s), ("get", self._get_s)):
            if h.count:
                out[f"transport/{name}_p50_s"] = h.percentile(50)
                out[f"transport/{name}_p99_s"] = h.percentile(99)
        return out


class LoopbackTransport(InstrumentedTransport):
    """In-process blob store — the deterministic test/bench transport.
    Thread-safe: the KV store's transfer worker and the main thread may
    hit it concurrently."""

    def __init__(self):
        super().__init__()
        self._blobs = {}
        self._lock = threading.RLock()

    def _put(self, name, data):
        with self._lock:
            self._blobs[name] = data

    def _get(self, name):
        with self._lock:
            try:
                return self._blobs[name]
            except KeyError:
                raise BlobNotFound(f"no blob named {name!r}") from None

    def _delete(self, name):
        with self._lock:
            if self._blobs.pop(name, None) is None:
                raise BlobNotFound(f"no blob named {name!r}")

    def _exists(self, name):
        with self._lock:
            return name in self._blobs

    def _list(self, prefix):
        with self._lock:
            return [n for n in self._blobs if n.startswith(prefix)]


class FileTransport(InstrumentedTransport):
    """Shared-directory transport (object-store semantics over a mount).

    Blob names are percent-encoded into flat filenames (no directory
    traversal, arbitrary name characters survive the round trip) and
    writes go through tmp + ``os.replace`` so a concurrent ``get`` on a
    peer host never reads a torn blob.
    """

    _SUFFIX = ".blob"

    def __init__(self, root: str):
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, name: str) -> str:
        return os.path.join(self.root,
                            urllib.parse.quote(name, safe="") + self._SUFFIX)

    def _put(self, name, data):
        path = self._path(name)
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        try:
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    def _get(self, name):
        try:
            with open(self._path(name), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise BlobNotFound(f"no blob named {name!r}") from None

    def _delete(self, name):
        try:
            os.remove(self._path(name))
        except FileNotFoundError:
            raise BlobNotFound(f"no blob named {name!r}") from None

    def _exists(self, name):
        return os.path.exists(self._path(name))

    def _list(self, prefix):
        out = []
        for fn in os.listdir(self.root):
            if not fn.endswith(self._SUFFIX):
                continue
            name = urllib.parse.unquote(fn[:-len(self._SUFFIX)])
            if name.startswith(prefix):
                out.append(name)
        return out


class FaultInjectionTransport(InstrumentedTransport):
    """Deterministic failure wrapper for the fault suite and benches.

    Counters, not randomness: the first ``fail_puts`` puts / ``fail_gets``
    gets raise a transient ``TransportError`` (retry fodder); the first
    ``drop_puts`` puts report success without storing (a lost blob —
    later gets see ``BlobNotFound``); the first ``corrupt_gets`` /
    ``truncate_gets`` gets return damaged bytes (the blob CRC must
    catch both); ``duplicate_puts`` puts every blob twice (idempotence).
    Each counter decrements as it fires, so a wrapped transport heals —
    letting one test drive fail → retry → recover end to end.
    """

    def __init__(self, inner, *, fail_puts: int = 0, fail_gets: int = 0,
                 drop_puts: int = 0, corrupt_gets: int = 0,
                 truncate_gets: int = 0, duplicate_puts: bool = False):
        super().__init__()
        self.inner = inner
        self.fail_puts = fail_puts
        self.fail_gets = fail_gets
        self.drop_puts = drop_puts
        self.corrupt_gets = corrupt_gets
        self.truncate_gets = truncate_gets
        self.duplicate_puts = duplicate_puts
        self.injected = {"fail_put": 0, "fail_get": 0, "drop_put": 0,
                         "corrupt_get": 0, "truncate_get": 0}

    def _put(self, name, data):
        if self.fail_puts > 0:
            self.fail_puts -= 1
            self.injected["fail_put"] += 1
            raise TransportError(f"injected put failure for {name!r}")
        if self.drop_puts > 0:
            self.drop_puts -= 1
            self.injected["drop_put"] += 1
            return                      # blob silently lost
        self.inner.put(name, data)
        if self.duplicate_puts:
            self.inner.put(name, data)

    def _get(self, name):
        if self.fail_gets > 0:
            self.fail_gets -= 1
            self.injected["fail_get"] += 1
            raise TransportError(f"injected get failure for {name!r}")
        data = self.inner.get(name)
        if self.truncate_gets > 0:
            self.truncate_gets -= 1
            self.injected["truncate_get"] += 1
            return data[:max(len(data) // 2, 1)]
        if self.corrupt_gets > 0:
            self.corrupt_gets -= 1
            self.injected["corrupt_get"] += 1
            i = len(data) // 2
            return data[:i] + bytes([data[i] ^ 0xFF]) + data[i + 1:]
        return data

    def _delete(self, name):
        self.inner.delete(name)

    def _exists(self, name):
        return self.inner.exists(name)

    def _list(self, prefix):
        return self.inner.list_blobs(prefix)
