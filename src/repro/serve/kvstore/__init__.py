"""repro.serve.kvstore — tiered KV store behind the slot pool (§11).

Two tiers below the device pool:

  host    parked sessions live as numpy pytrees (cluster pages stored
          compacted: only the occupied prefix of each page, per the
          backend CacheLayout's pageable_leaves/page_len_leaf)
  disk    optional npz spill once the host tier exceeds its byte limit
          (dtype-proof uint8 views, so bf16 lanes round-trip bit-exact)

Public surface:
  KVStore, StoreConfig, ParkedSession — park(uid, lane) / resume(uid)
  PrefixCache                         — hash-keyed shared prompt pages
"""
from repro.serve.kvstore.prefix import PrefixCache
from repro.serve.kvstore.store import KVStore, ParkedSession, StoreConfig

__all__ = ["KVStore", "StoreConfig", "ParkedSession", "PrefixCache"]
