"""repro.serve.kvstore — tiered KV store behind the slot pool (§11).

Three tiers below the device pool:

  host    parked sessions live as numpy pytrees (cluster pages stored
          compacted: only the occupied prefix of each page, per the
          backend CacheLayout's pageable_leaves/page_len_leaf)
  disk    optional spill once the host tier exceeds its byte limit —
          one checksummed blob file per session (versioned header +
          CRC32, verified on load)
  remote  optional ``Transport`` to a peer blob store beyond the disk
          tier; also the rail sessions move over between disaggregated
          prefill/decode pools (``export`` / ``import_remote``)

Public surface:
  KVStore, StoreConfig, ParkedSession — park(uid, lane) / resume(uid)
  InflightPark                        — async park completion handle
  PrefixCache, PrefixHit              — shared prompt pages, longest-
                                        prefix partial reuse
  repro.serve.kvstore.remote          — blob codec + transports + worker
"""
from repro.serve.kvstore.prefix import PrefixCache, PrefixHit
from repro.serve.kvstore.store import (InflightPark, KVStore, ParkedSession,
                                       StoreConfig)

__all__ = ["KVStore", "StoreConfig", "ParkedSession", "InflightPark",
           "PrefixCache", "PrefixHit"]
