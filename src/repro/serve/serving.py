"""Serving: KV caches, prefill, and single-token decode for every family.

Cache layouts (per segment, stacked over scan groups G):
  full attention    k/v: (G,B,Hkv,S,dh) append-at-position
  local attention   ring of 2W slots + stored absolute positions — decode
                    reproduces the *blocked* training semantics exactly
                    (query attends blocks b, b-1)
  routing heads     cluster-paged cache (beyond-paper serving design):
                    pages (G,B,Hr,kc,cap,dh) hold the normalized shared-QK
                    routing vectors + values per centroid; a decoded token is
                    routed to its argmax centroid and attends only that page
                    — O(cap . d) per step. Ring-overwrite per page bounds
                    memory for 500k-token decode. On TPU, decode resolves to
                    the routing/pallas_paged kernel, which scalar-prefetches
                    the cluster-page table and DMAs only the selected page
                    into VMEM (no HBM gather); elsewhere it lands on
                    routing/xla's take-along-cluster reference. Both share
                    one cache layout and bit-identical cache trajectories
                    (asserted in tests; see docs/attention-backends.md).
  ssd / rglru       recurrent state (+ causal-conv tail)
  cross             static image K/V computed at prefill

Decode-vs-train semantics: full/local/ssd/rglru decode match teacher-forced
training exactly (tested); routing decode uses argmax-cluster membership
(training uses balanced per-centroid top-k), the designed serving adaptation
— see DESIGN.md §3.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import attn as attn_api
from repro.attn.spec import spec_for_layer
from repro.configs.base import ModelConfig
from repro.core.attention import full_attention
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.transformer import build_segments, where_active


# Per-leaf reset values now live on each backend's typed CacheLayout
# (attn.cache_reset_values() aggregates them); the old free function
# serving.cache_reset_value was removed with the stringly cache API.

# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------
def _slot_cache(spec, cfg: ModelConfig, B: int, max_len: int, dt,
                mesh=None):
    dh, Hkv = cfg.head_dim_, cfg.num_kv_heads
    if spec.kind == "ssd":
        s = ssm_mod.ssm_spec(cfg)
        conv_ch = s.d_inner + 2 * s.nstate
        return {"conv": jnp.zeros((B, s.conv - 1, conv_ch), dt),
                "state": jnp.zeros((B, s.nheads, s.nstate, s.headdim),
                                   jnp.float32)}
    if spec.kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return {"conv": jnp.zeros((B, 3, w), dt),
                "h": jnp.zeros((B, w), jnp.float32)}
    if spec.kind == "cross":
        M = cfg.num_image_tokens
        return {"k": jnp.zeros((B, Hkv, M, dh), dt),
                "v": jnp.zeros((B, Hkv, M, dh), dt)}
    # self-attention: the registered decode backend declares the layout
    return attn_api.init_decode_cache(spec_for_layer(cfg, spec.attn), B,
                                      max_len, dt, mesh=mesh)


def init_cache(cfg: ModelConfig, B: int, max_len: int, mesh=None):
    dt = jnp.dtype(cfg.dtype)
    segs = build_segments(cfg)
    out = []
    for pattern, G in segs:
        slot = {str(i): _slot_cache(s, cfg, B, max_len, dt, mesh=mesh)
                for i, s in enumerate(pattern)}
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), slot))
    return out


def decode_backends(cfg: ModelConfig, mesh=None) -> Dict[str, str]:
    """variant -> "variant/impl(cache_layout)" for every attention
    variant in the stack, as resolved by the registry (engine
    observability; also how the engine's pool layout is decided)."""
    out: Dict[str, str] = {}
    for pattern, _ in build_segments(cfg):
        for s in pattern:
            if s.kind in ("attn", "moe"):
                b = attn_api.decode_backend(spec_for_layer(cfg, s.attn),
                                            mesh=mesh)
                layout = b.layout.name if b.layout is not None else "-"
                out[s.attn] = f"{b.name}({layout})"
    return out


def decode_cache_layouts(cfg: ModelConfig, mesh=None) -> set:
    """The set of cache-layout names the decode stack uses (e.g.
    {"append"}, {"ring", "pages"}). The engine's partial-prefix gate
    keys off this: teacher-forcing a prompt tail over a cached prefix
    is only bit-exact when every layout is in {"append", "ring"} —
    cluster-page layouts route prefill (balanced top-k) and decode
    (argmax) differently, so partial reuse would break the hit≡miss
    byte-identity contract (DESIGN.md §11)."""
    out = set()
    for pattern, _ in build_segments(cfg):
        for s in pattern:
            if s.kind in ("attn", "moe"):
                b = attn_api.decode_backend(spec_for_layer(cfg, s.attn),
                                            mesh=mesh)
                if b.layout is not None:
                    out.add(b.layout.name)
    return out


# ---------------------------------------------------------------------------
# Decode attention: one registry call per layer — the backend owns the
# cache update semantics (append / ring / cluster pages)
# ---------------------------------------------------------------------------
def _decode_self_attn(p, h, cfg, mode, kmu, cache, pos, mesh=None):
    """h: (B,1,d) -> (out (B,1,d), new_cache)."""
    q, k, v = L.qkv_project(p, h, cfg, rope=False)
    out = attn_api.attend(spec_for_layer(cfg, mode), q, k, v, state=kmu,
                          cache=cache, pos=pos, mesh=mesh)
    return L.out_project(p, out.out), out.cache


def _decode_layer(spec, p, kmu, cache, x, cfg, pos, image_embeds=None,
                  mesh=None):
    if spec.kind in ("attn", "moe", "cross"):
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        if spec.kind == "cross":
            q, _, _ = L.qkv_project(p["attn"], h, cfg, rope=False)
            o = full_attention(q, cache["k"], cache["v"], causal=False)
            a = L.out_project(p["attn"], o)
            a = a * jnp.tanh(p["xgate_attn"]).astype(a.dtype)
        else:
            a, cache = _decode_self_attn(p["attn"], h, cfg, spec.attn, kmu,
                                         cache, pos, mesh=mesh)
        x = x + a
        h2 = L.apply_norm(p["ln2"], x, cfg.norm)
        if spec.kind == "moe":
            ff, _ = moe_mod.apply_moe(p["ffn"], h2, cfg, impl="scatter")
        else:
            ff = L.apply_mlp(p["ffn"], h2, cfg.act)
            if spec.kind == "cross":
                ff = ff * jnp.tanh(p["xgate_ffn"]).astype(ff.dtype)
        x = x + ff
    elif spec.kind == "ssd":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, (nc, ns) = ssm_mod.apply_ssd(p["mixer"], h, cfg,
                                        conv_state=cache["conv"],
                                        ssm_state=cache["state"],
                                        decode=True)
        cache = {"conv": nc, "state": ns}
        x = x + y
    elif spec.kind == "rglru":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, (nc, nh) = rglru_mod.apply_rglru(p["mixer"], h, cfg,
                                            conv_state=cache["conv"],
                                            h_state=cache["h"], decode=True)
        cache = {"conv": nc, "h": nh}
        x = x + y
        h2 = L.apply_norm(p["ln2"], x, cfg.norm)
        x = x + L.apply_mlp(p["ffn"], h2, cfg.act)
    return x, cache


# ---------------------------------------------------------------------------
# serve_step: one token for the whole stack
# ---------------------------------------------------------------------------
def make_serve_step(cfg: ModelConfig, mesh=None):
    segments = build_segments(cfg)

    def serve_step(params, kstate, cache, tokens, pos, active=None):
        """tokens: (B,) int32; pos: (B,) int32 -> (logits (B,V), new_cache).

        ``active`` (B,) bool, optional: rows where it is False are decoded
        as no-ops — their cache lanes come back bit-identical (the
        continuous-batching engine uses this for free/finished slots; their
        logits are garbage and must be ignored by the caller).
        """
        x = L.embed(params["embed"], tokens[:, None])
        new_cache = []
        for si, (pattern, G) in enumerate(segments):
            def group_fn(x, xs, pattern=pattern):
                p_group, k_group, c_group = xs
                new_c = {}
                for i, spec in enumerate(pattern):
                    x, nc = _decode_layer(spec, p_group[i],
                                          k_group.get(str(i)),
                                          c_group[str(i)], x, cfg, pos,
                                          mesh=mesh)
                    new_c[str(i)] = nc
                return x, new_c

            xs = (params["stack"][si], kstate[si], cache[si])
            x, nc = jax.lax.scan(lambda c, xs: group_fn(c, xs), x, xs)
            new_cache.append(nc)
        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.logits_out(params["embed"], x, cfg.tie_embeddings,
                              cfg.logit_softcap)
        if active is not None:
            new_cache = where_active(active, new_cache, cache, batch_axis=1)
        from repro.models.model import mask_vocab_pad
        return mask_vocab_pad(logits, cfg)[:, 0], new_cache

    return serve_step


# ---------------------------------------------------------------------------
# Prefill: forward pass that also fills the caches. The fill itself is
# cache-layout math, so the registered decode backend owns it
# (CacheLayout.fill via attn.prefill_cache).
#
# Prefill is built from resumable depth stages: embed -> one stage per
# slice of each segment's scan-group axis -> head. Composing every stage
# in order IS the monolithic forward (prefill() below does exactly that,
# with whole-segment stages, so its traced program is unchanged); the
# serve engine instead jits each stage and advances a few per step, so a
# long prompt's prefill interleaves with active decodes instead of
# head-of-line-blocking them. Chunking over *depth* rather than over the
# sequence is deliberate: routing membership is balanced top-k over the
# whole prompt (DESIGN.md §3), so splitting the sequence would change
# which pages a later decode attends; splitting over depth keeps every
# stage bit-identical to the uninterrupted forward.
# ---------------------------------------------------------------------------
def _fill_from_prefix(spec, cfg, cache, h, p, kmu, positions, mesh=None):
    """Build one layer's cache from prefix activations h (B,N,d)."""
    q, k, v = L.qkv_project(p["attn"], h, cfg, rope=False)
    return attn_api.prefill_cache(spec_for_layer(cfg, spec.attn), cache,
                                  q, k, v, positions=positions, state=kmu,
                                  mesh=mesh)


class PrefillStage(NamedTuple):
    """One resumable prefill stage: scan groups [g0, g1) of segment si.

    ``fn(params, kstate, cache_chunk, x, positions, batch)`` returns
    ``(x, new_cache_chunk, stats_chunk)`` where ``cache_chunk`` holds the
    segment's cache leaves sliced to rows g0:g1 of the scan-group axis.
    """
    si: int
    g0: int
    g1: int
    fn: Callable


def make_prefill_stages(cfg: ModelConfig, mesh=None,
                        groups_per_stage: Optional[int] = None):
    """The staged prefill: ``(embed_stage, stages, head_stage)``.

    ``embed_stage(params, batch) -> (x, positions)``;
    ``head_stage(params, x) -> logits`` (vocab-pad masked);
    ``stages`` is a list of PrefillStage covering every segment's scan
    groups in order. ``groups_per_stage=None`` gives one whole-segment
    stage per segment (what ``prefill`` composes); ``groups_per_stage=k``
    slices each segment's group axis into ceil(G/k) stages — the engine
    uses k=1 so even a uniform dense stack (one segment, G=num_layers)
    chunks per layer group.
    """
    from repro.models.transformer import apply_layer
    segments = build_segments(cfg)

    def embed_stage(params, batch):
        B, N = batch["tokens"].shape
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N)))
        return L.embed(params["embed"], batch["tokens"]), positions

    def _make_stage(si, pattern, g0, g1, G):
        def stage(params, kstate, cache_chunk, x, positions, batch):
            B = x.shape[0]

            def group_fn(x, xs):
                p_group, k_group, c_group = xs
                new_c = {}
                stats_g = {}
                for i, spec in enumerate(pattern):
                    c_i, p_i = c_group[str(i)], p_group[i]
                    if spec.kind in ("attn", "moe"):
                        h = L.apply_norm(p_i["ln1"], x, cfg.norm)
                        c_i = _fill_from_prefix(spec, cfg, c_i, h, p_i,
                                                k_group.get(str(i)),
                                                positions, mesh=mesh)
                    elif spec.kind == "cross":
                        img = batch["image_embeds"]
                        dh, Hkv = cfg.head_dim_, cfg.num_kv_heads
                        M = img.shape[1]
                        c_i = {
                            "k": (img @ p_i["attn"]["wk"]).reshape(
                                B, M, Hkv, dh).transpose(0, 2, 1, 3),
                            "v": (img @ p_i["attn"]["wv"]).reshape(
                                B, M, Hkv, dh).transpose(0, 2, 1, 3)}
                    if spec.kind in ("ssd", "rglru"):
                        h = L.apply_norm(p_i["ln1"], x, cfg.norm)
                        if spec.kind == "ssd":
                            y, (nc_, ns) = ssm_mod.apply_ssd(
                                p_i["mixer"], h, cfg)
                            c_i = {"conv": nc_, "state": ns}
                        else:
                            y, (nc_, nh) = rglru_mod.apply_rglru(
                                p_i["mixer"], h, cfg)
                            c_i = {"conv": nc_, "h": nh}
                        x = x + y
                        if spec.kind == "rglru":
                            h2 = L.apply_norm(p_i["ln2"], x, cfg.norm)
                            x = x + L.apply_mlp(p_i["ffn"], h2, cfg.act)
                    else:
                        x, _, aux_i = apply_layer(
                            spec, p_i, k_group.get(str(i)), x, cfg,
                            positions=positions,
                            pad_mask=batch.get("pad_mask"),
                            image_embeds=batch.get("image_embeds"),
                            update_state=False)
                        st = aux_i.pop("routing_stats", None)
                        if st is not None:
                            stats_g[str(i)] = st
                    new_c[str(i)] = c_i
                return x, (new_c, stats_g)

            p_seg, k_seg = params["stack"][si], kstate[si]
            if (g0, g1) != (0, G):
                p_seg = jax.tree.map(lambda a: a[g0:g1], p_seg)
                k_seg = jax.tree.map(lambda a: a[g0:g1], k_seg)
            x, (nc, st_g) = jax.lax.scan(group_fn, x,
                                         (p_seg, k_seg, cache_chunk))
            return x, nc, st_g

        return PrefillStage(si, g0, g1, stage)

    stages = []
    for si, (pattern, G) in enumerate(segments):
        gps = G if groups_per_stage is None else max(1, groups_per_stage)
        for g0 in range(0, G, gps):
            stages.append(_make_stage(si, pattern, g0, min(g0 + gps, G), G))

    def head_stage(params, x):
        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.logits_out(params["embed"], x, cfg.tie_embeddings,
                              cfg.logit_softcap)
        from repro.models.model import mask_vocab_pad
        return mask_vocab_pad(logits, cfg)

    return embed_stage, stages, head_stage


def slice_cache_groups(seg_cache, g0: int, g1: int):
    """Rows [g0, g1) of a segment cache's scan-group axis (stage input)."""
    return jax.tree.map(lambda a: a[g0:g1], seg_cache)


def assemble_prefill_cache(stages, chunks):
    """Stitch per-stage cache chunks back into the per-segment cache list
    (the inverse of feeding each stage ``slice_cache_groups`` of its
    segment). ``chunks`` must align with ``stages`` in order."""
    by_seg: Dict[int, list] = {}
    for st, nc in zip(stages, chunks):
        by_seg.setdefault(st.si, []).append(nc)
    return [cs[0] if len(cs) == 1
            else jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *cs)
            for _, cs in sorted(by_seg.items())]


def prefill(params, kstate, cache, batch, cfg: ModelConfig, mesh=None,
            return_stats: bool = False):
    """Forward over the prefix, returning (logits, filled_cache).

    Composes the whole-segment prefill stages in order — the standard
    stack forward with caches filled per layer from the layer inputs
    (python loop over segments, scan over groups).

    ``return_stats`` (static): with RoutingConfig.stats enabled, also
    return the routing-health stats of the prefix forward as a third
    element — a list over segments of {layer: obs.RoutingStats} with
    leaves stacked over scan groups (same structure the train stack puts
    in its aux). Existing 2-tuple call sites are unchanged.
    """
    embed_stage, stages, head_stage = make_prefill_stages(cfg, mesh=mesh)
    x, positions = embed_stage(params, batch)
    new_cache = []
    seg_stats = []
    for st in stages:                   # one whole-segment stage each
        x, nc, st_g = st.fn(params, kstate, cache[st.si], x, positions,
                            batch)
        new_cache.append(nc)
        seg_stats.append(st_g)
    logits = head_stage(params, x)
    if return_stats:
        return logits, new_cache, seg_stats
    return logits, new_cache
