"""Serving: KV caches, prefill, and single-token decode for every family.

Cache layouts (per segment, stacked over scan groups G):
  full attention    k/v: (G,B,Hkv,S,dh) append-at-position
  local attention   ring of 2W slots + stored absolute positions — decode
                    reproduces the *blocked* training semantics exactly
                    (query attends blocks b, b-1)
  routing heads     cluster-paged cache (beyond-paper serving design):
                    pages (G,B,Hr,kc,cap,dh) hold the normalized shared-QK
                    routing vectors + values per centroid; a decoded token is
                    routed to its argmax centroid and attends only that page
                    via take-along-cluster — O(cap . d) per step, no dynamic
                    gather over the full context. Ring-overwrite per page
                    bounds memory for 500k-token decode.
  ssd / rglru       recurrent state (+ causal-conv tail)
  cross             static image K/V computed at prefill

Decode-vs-train semantics: full/local/ssd/rglru decode match teacher-forced
training exactly (tested); routing decode uses argmax-cluster membership
(training uses balanced per-centroid top-k), the designed serving adaptation
— see DESIGN.md §3.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import full_attention
from repro.core.kmeans import normalize_routing
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.transformer import (build_segments, head_split,
                                      _expand_kv, _routing_cfg, where_active)

_BIG_NEG = -1e9

# Fill values for cache leaves; every leaf not listed resets to 0. The slot
# pool (serve/engine/pool.py) uses this to return a freed lane to its
# just-initialized state without reallocation.
CACHE_FILL_VALUES = {"lpos": -1}


def cache_reset_value(leaf_name: str) -> int:
    """Initial/reset fill value for a named cache leaf."""
    return CACHE_FILL_VALUES.get(leaf_name, 0)


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------
def _routing_dims(cfg: ModelConfig, max_len: int) -> Tuple[int, int]:
    kc = cfg.routing.num_clusters
    cap = cfg.routing.window or max(1, max_len // kc)
    return kc, cap


def _slot_cache(spec, cfg: ModelConfig, B: int, max_len: int, dt):
    dh, Hkv = cfg.head_dim_, cfg.num_kv_heads
    if spec.kind == "ssd":
        s = ssm_mod.ssm_spec(cfg)
        conv_ch = s.d_inner + 2 * s.nstate
        return {"conv": jnp.zeros((B, s.conv - 1, conv_ch), dt),
                "state": jnp.zeros((B, s.nheads, s.nstate, s.headdim),
                                   jnp.float32)}
    if spec.kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return {"conv": jnp.zeros((B, 3, w), dt),
                "h": jnp.zeros((B, w), jnp.float32)}
    if spec.kind == "cross":
        M = cfg.num_image_tokens
        return {"k": jnp.zeros((B, Hkv, M, dh), dt),
                "v": jnp.zeros((B, Hkv, M, dh), dt)}
    # self-attention caches
    c: Dict[str, Any] = {}
    mode = spec.attn
    if mode == "full":
        c["k"] = jnp.zeros((B, Hkv, max_len, dh), dt)
        c["v"] = jnp.zeros((B, Hkv, max_len, dh), dt)
    elif mode in ("local", "local+routing"):
        W = (cfg.routing.local_window if mode == "local+routing"
             else cfg.attn_window)
        kvl = head_split(cfg)[2] if mode == "local+routing" else Hkv
        c["lk"] = jnp.zeros((B, kvl, 2 * W, dh), dt)
        c["lv"] = jnp.zeros((B, kvl, 2 * W, dh), dt)
        c["lpos"] = jnp.full((B, 2 * W), cache_reset_value("lpos"), jnp.int32)
    if mode in ("routing", "local+routing"):
        Hr = cfg.num_heads if mode == "routing" else head_split(cfg)[1]
        kc, cap = _routing_dims(cfg, max_len)
        c["rk"] = jnp.zeros((B, Hr, kc, cap, dh), dt)
        c["rv"] = jnp.zeros((B, Hr, kc, cap, dh), dt)
        c["rlen"] = jnp.zeros((B, Hr, kc), jnp.int32)
    return c


def init_cache(cfg: ModelConfig, B: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    segs = build_segments(cfg)
    out = []
    for pattern, G in segs:
        slot = {str(i): _slot_cache(s, cfg, B, max_len, dt)
                for i, s in enumerate(pattern)}
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), slot))
    return out


# ---------------------------------------------------------------------------
# Decode attention primitives
# ---------------------------------------------------------------------------
def _decode_full(cache, q, k_new, v_new, pos):
    """q:(B,H,1,dh) roped; k/v_new:(B,Hkv,1,dh); pos:(B,) write index."""
    B, Hkv = k_new.shape[0], k_new.shape[1]
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(Hkv)[None, :]
    ck = cache["k"].at[bi, hi, pos[:, None]].set(k_new[:, :, 0])
    cv = cache["v"].at[bi, hi, pos[:, None]].set(v_new[:, :, 0])
    o = full_attention(q, ck, cv, causal=True, positions=pos[:, None])
    return o, {**cache, "k": ck, "v": cv}


def _decode_local(cache, q, k_new, v_new, pos, window):
    """Blocked-local decode: attend keys with kpos in blocks b-1, b."""
    B, Hkv = k_new.shape[0], k_new.shape[1]
    S2 = cache["lk"].shape[2]              # 2W ring
    slot = pos % S2
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(Hkv)[None, :]
    ck = cache["lk"].at[bi, hi, slot[:, None]].set(k_new[:, :, 0])
    cv = cache["lv"].at[bi, hi, slot[:, None]].set(v_new[:, :, 0])
    cp = cache["lpos"].at[jnp.arange(B), slot].set(pos)
    lo = (pos // window - 1) * window      # start of block b-1
    valid = (cp >= jnp.maximum(lo, 0)[:, None]) & (cp >= 0) & \
            (cp <= pos[:, None])
    o = full_attention(q, ck, cv, causal=False, pad_mask=valid)
    return o, {**cache, "lk": ck, "lv": cv, "lpos": cp}


def _decode_routing(cache, q, v_new, pos, cfg):
    """Cluster-paged routing decode. q:(B,Hr,1,dh) unroped; v:(B,Hr,1,dh)."""
    mu = cache["_mu"]                      # (Hr,kc,dh) injected by caller
    B, Hr, _, dh = q.shape
    kc, cap = cache["rk"].shape[2], cache["rk"].shape[3]
    r = normalize_routing(q)[:, :, 0]      # (B,Hr,dh)
    scores = jnp.einsum("bhd,hkd->bhk", r.astype(jnp.float32),
                        mu.astype(jnp.float32))
    c = jnp.argmax(scores, axis=-1)        # (B,Hr)
    sel = c[:, :, None, None, None]
    page_k = jnp.take_along_axis(cache["rk"], sel, axis=2)[:, :, 0]
    page_v = jnp.take_along_axis(cache["rv"], sel, axis=2)[:, :, 0]
    plen = jnp.take_along_axis(cache["rlen"], c[:, :, None], axis=2)[..., 0]
    nvalid = jnp.minimum(plen, cap)        # (B,Hr)
    logits = jnp.einsum("bhd,bhcd->bhc", r, page_k).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh)
    slot_ok = jnp.arange(cap)[None, None, :] < nvalid[..., None]
    logits = jnp.where(slot_ok, logits, _BIG_NEG)
    self_logit = (jnp.einsum("bhd,bhd->bh", r, r) /
                  jnp.sqrt(dh)).astype(jnp.float32)
    all_logits = jnp.concatenate([logits, self_logit[..., None]], -1)
    attn = jax.nn.softmax(all_logits, axis=-1)
    vals = jnp.concatenate([page_v, v_new[:, :, 0][:, :, None, :]], 2)
    o = jnp.einsum("bhc,bhcd->bhd", attn.astype(vals.dtype), vals)
    # write r, v into the ring slot of page c
    wslot = plen % cap
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(Hr)[None, :]
    ck = cache["rk"].at[bi, hi, c, wslot].set(r.astype(cache["rk"].dtype))
    cv = cache["rv"].at[bi, hi, c, wslot].set(
        v_new[:, :, 0].astype(cache["rv"].dtype))
    cl = cache["rlen"].at[bi, hi, c].set(plen + 1)
    out = {k: v for k, v in cache.items() if k != "_mu"}
    return o[:, :, None, :], {**out, "rk": ck, "rv": cv, "rlen": cl}


def _decode_self_attn(p, h, cfg, mode, kmu, cache, pos):
    """h: (B,1,d) -> (out (B,1,d), new_cache)."""
    B = h.shape[0]
    q, k, v = L.qkv_project(p, h, cfg, rope=False)
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    g = H // Hkv

    def roped(qq, kk):
        if cfg.position != "rope":
            return qq, kk
        return (L.apply_rope(qq, pos[:, None], cfg.rope_theta),
                L.apply_rope(kk, pos[:, None], cfg.rope_theta))

    if mode == "full":
        qr, kr = roped(q, k)
        o, cache = _decode_full(cache, qr, kr, v, pos)
    elif mode == "local":
        qr, kr = roped(q, k)
        o, cache = _decode_local(cache, qr, kr, v, pos, cfg.attn_window)
    elif mode == "routing":
        v_e = _expand_kv(v, g)
        o, cache = _decode_routing({**cache, "_mu": kmu}, q, v_e, pos, cfg)
    elif mode == "local+routing":
        Hl, Hr, kvl, kvr = head_split(cfg)
        if Hkv == 1:
            kl, vl, vr_ = k, v, v
        else:
            kl, vl, vr_ = k[:, :kvl], v[:, :kvl], v[:, kvl:]
        ql, klr = roped(q[:, :Hl], kl)
        o_l, lc = _decode_local(
            {"lk": cache["lk"], "lv": cache["lv"], "lpos": cache["lpos"]},
            ql, klr, vl, pos, cfg.routing.local_window)
        v_e = _expand_kv(vr_, Hr // vr_.shape[1])
        rc_in = {k2: cache[k2] for k2 in ("rk", "rv", "rlen")}
        o_r, rc = _decode_routing({**rc_in, "_mu": kmu}, q[:, Hl:], v_e,
                                  pos, cfg)
        o = jnp.concatenate([o_l, o_r], axis=1)
        cache = {**lc, **rc}
    else:
        raise ValueError(mode)
    return L.out_project(p, o), cache


def _decode_layer(spec, p, kmu, cache, x, cfg, pos, image_embeds=None):
    if spec.kind in ("attn", "moe", "cross"):
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        if spec.kind == "cross":
            q, _, _ = L.qkv_project(p["attn"], h, cfg, rope=False)
            o = full_attention(q, cache["k"], cache["v"], causal=False)
            a = L.out_project(p["attn"], o)
            a = a * jnp.tanh(p["xgate_attn"]).astype(a.dtype)
        else:
            a, cache = _decode_self_attn(p["attn"], h, cfg, spec.attn, kmu,
                                         cache, pos)
        x = x + a
        h2 = L.apply_norm(p["ln2"], x, cfg.norm)
        if spec.kind == "moe":
            ff, _ = moe_mod.apply_moe(p["ffn"], h2, cfg, impl="scatter")
        else:
            ff = L.apply_mlp(p["ffn"], h2, cfg.act)
            if spec.kind == "cross":
                ff = ff * jnp.tanh(p["xgate_ffn"]).astype(ff.dtype)
        x = x + ff
    elif spec.kind == "ssd":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, (nc, ns) = ssm_mod.apply_ssd(p["mixer"], h, cfg,
                                        conv_state=cache["conv"],
                                        ssm_state=cache["state"],
                                        decode=True)
        cache = {"conv": nc, "state": ns}
        x = x + y
    elif spec.kind == "rglru":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, (nc, nh) = rglru_mod.apply_rglru(p["mixer"], h, cfg,
                                            conv_state=cache["conv"],
                                            h_state=cache["h"], decode=True)
        cache = {"conv": nc, "h": nh}
        x = x + y
        h2 = L.apply_norm(p["ln2"], x, cfg.norm)
        x = x + L.apply_mlp(p["ffn"], h2, cfg.act)
    return x, cache


# ---------------------------------------------------------------------------
# serve_step: one token for the whole stack
# ---------------------------------------------------------------------------
def make_serve_step(cfg: ModelConfig):
    segments = build_segments(cfg)

    def serve_step(params, kstate, cache, tokens, pos, active=None):
        """tokens: (B,) int32; pos: (B,) int32 -> (logits (B,V), new_cache).

        ``active`` (B,) bool, optional: rows where it is False are decoded
        as no-ops — their cache lanes come back bit-identical (the
        continuous-batching engine uses this for free/finished slots; their
        logits are garbage and must be ignored by the caller).
        """
        x = L.embed(params["embed"], tokens[:, None])
        new_cache = []
        for si, (pattern, G) in enumerate(segments):
            def group_fn(x, xs, pattern=pattern):
                p_group, k_group, c_group = xs
                new_c = {}
                for i, spec in enumerate(pattern):
                    x, nc = _decode_layer(spec, p_group[i],
                                          k_group.get(str(i)),
                                          c_group[str(i)], x, cfg, pos)
                    new_c[str(i)] = nc
                return x, new_c

            xs = (params["stack"][si], kstate[si], cache[si])
            x, nc = jax.lax.scan(lambda c, xs: group_fn(c, xs), x, xs)
            new_cache.append(nc)
        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.logits_out(params["embed"], x, cfg.tie_embeddings,
                              cfg.logit_softcap)
        if active is not None:
            new_cache = where_active(active, new_cache, cache, batch_axis=1)
        from repro.models.model import mask_vocab_pad
        return mask_vocab_pad(logits, cfg)[:, 0], new_cache

    return serve_step


# ---------------------------------------------------------------------------
# Prefill: forward pass that also fills the caches
# ---------------------------------------------------------------------------
def _fill_from_prefix(spec, cfg, cache, h, p, kmu, positions):
    """Build one layer's cache from prefix activations h (B,N,d)."""
    B, N, _ = h.shape
    q, k, v = L.qkv_project(p["attn"], h, cfg, rope=False)
    mode = spec.attn
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    g = H // Hkv

    def roped_k(kk):
        if cfg.position != "rope":
            return kk
        return L.apply_rope(kk, positions, cfg.rope_theta)

    out = dict(cache)
    if mode == "full":
        kr = roped_k(k)
        out["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kr.astype(cache["k"].dtype), (0, 0, 0, 0))
        out["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        return out
    if mode in ("local", "local+routing"):
        W = (cfg.routing.local_window if mode == "local+routing"
             else cfg.attn_window)
        kvl = head_split(cfg)[2] if mode == "local+routing" else Hkv
        kl = roped_k(k[:, :kvl] if (mode == "local+routing" and Hkv > 1)
                     else k)
        vl = v[:, :kvl] if (mode == "local+routing" and Hkv > 1) else v
        S2 = 2 * W
        # place token t at ring slot t % S2; keep the last S2 tokens
        take = min(N, S2)
        tail_k = kl[:, :, -take:]
        tail_v = vl[:, :, -take:]
        tail_pos = positions[:, -take:]
        slots = tail_pos % S2                              # (B,take)
        bi = jnp.arange(B)[:, None, None]
        hi = jnp.arange(tail_k.shape[1])[None, :, None]
        si = slots[:, None, :]
        out["lk"] = cache["lk"].at[bi, hi, si].set(
            tail_k.astype(cache["lk"].dtype))
        out["lv"] = cache["lv"].at[bi, hi, si].set(
            tail_v.astype(cache["lv"].dtype))
        out["lpos"] = cache["lpos"].at[jnp.arange(B)[:, None], slots].set(
            tail_pos)
    if mode in ("routing", "local+routing"):
        Hr = cfg.num_heads if mode == "routing" else head_split(cfg)[1]
        qr = q if mode == "routing" else q[:, -Hr:]
        if mode == "routing":
            vr = _expand_kv(v, g)
        else:
            kvl = head_split(cfg)[2]
            vr_kv = v if Hkv == 1 else v[:, kvl:]
            vr = _expand_kv(vr_kv, Hr // vr_kv.shape[1])
        r = normalize_routing(qr)                          # (B,Hr,N,dh)
        kc, cap = cache["rk"].shape[2], cache["rk"].shape[3]
        scores = jnp.einsum("bhnd,hkd->bhnk", r.astype(jnp.float32),
                            kmu.astype(jnp.float32))
        assign = jnp.argmax(scores, -1)                    # (B,Hr,N)
        # keep the most recent `cap` tokens per cluster
        memb = jax.nn.one_hot(assign, kc, dtype=jnp.int32)   # (B,Hr,N,kc)
        rank_from_end = jnp.cumsum(memb[:, :, ::-1], axis=2)[:, :, ::-1]
        rank_from_end = (rank_from_end * memb).max(-1)     # (B,Hr,N) 1-based
        keep = (rank_from_end >= 1) & (rank_from_end <= cap)
        slot_of_tok = jnp.where(keep, (rank_from_end - 1), 0)
        counts = memb.sum(2)                               # (B,Hr,kc)
        # scatter kept tokens into pages; slot = (count - rank) % cap, the
        # slot sequential decode would have used (ring continuity)
        sel_cluster = assign
        write_slot = jnp.where(
            keep,
            (jnp.take_along_axis(counts, sel_cluster, axis=2) % cap
             - rank_from_end) % cap,
            cap)                                           # cap = trash
        bi = jnp.arange(B)[:, None, None]
        hi = jnp.arange(Hr)[None, :, None]
        rk_pad = jnp.concatenate(
            [cache["rk"], jnp.zeros_like(cache["rk"][:, :, :, :1])], 3)
        rv_pad = jnp.concatenate(
            [cache["rv"], jnp.zeros_like(cache["rv"][:, :, :, :1])], 3)
        rk_pad = rk_pad.at[bi, hi, sel_cluster, write_slot].set(
            r.astype(rk_pad.dtype))
        rv_pad = rv_pad.at[bi, hi, sel_cluster, write_slot].set(
            vr.astype(rv_pad.dtype))
        out["rk"] = rk_pad[:, :, :, :cap]
        out["rv"] = rv_pad[:, :, :, :cap]
        out["rlen"] = counts
    return out


def prefill(params, kstate, cache, batch, cfg: ModelConfig):
    """Forward over the prefix, returning (logits, filled_cache).

    Runs the standard stack forward; caches are filled per layer from the
    layer inputs (python loop over segments, scan over groups).
    """
    from repro.models.transformer import apply_layer
    segments = build_segments(cfg)
    B, N = batch["tokens"].shape
    positions = batch.get(
        "positions", jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N)))
    x = L.embed(params["embed"], batch["tokens"])
    new_cache = []
    for si, (pattern, G) in enumerate(segments):
        def group_fn(x, xs, pattern=pattern):
            p_group, k_group, c_group = xs
            new_c = {}
            for i, spec in enumerate(pattern):
                c_i, p_i = c_group[str(i)], p_group[i]
                if spec.kind in ("attn", "moe"):
                    h = L.apply_norm(p_i["ln1"], x, cfg.norm)
                    c_i = _fill_from_prefix(spec, cfg, c_i, h, p_i,
                                            k_group.get(str(i)), positions)
                elif spec.kind == "cross":
                    img = batch["image_embeds"]
                    dh, Hkv = cfg.head_dim_, cfg.num_kv_heads
                    M = img.shape[1]
                    c_i = {
                        "k": (img @ p_i["attn"]["wk"]).reshape(
                            B, M, Hkv, dh).transpose(0, 2, 1, 3),
                        "v": (img @ p_i["attn"]["wv"]).reshape(
                            B, M, Hkv, dh).transpose(0, 2, 1, 3)}
                if spec.kind in ("ssd", "rglru"):
                    h = L.apply_norm(p_i["ln1"], x, cfg.norm)
                    if spec.kind == "ssd":
                        y, (nc_, ns) = ssm_mod.apply_ssd(
                            p_i["mixer"], h, cfg)
                        c_i = {"conv": nc_, "state": ns}
                    else:
                        y, (nc_, nh) = rglru_mod.apply_rglru(
                            p_i["mixer"], h, cfg)
                        c_i = {"conv": nc_, "h": nh}
                    x = x + y
                    if spec.kind == "rglru":
                        h2 = L.apply_norm(p_i["ln2"], x, cfg.norm)
                        x = x + L.apply_mlp(p_i["ffn"], h2, cfg.act)
                else:
                    x, _, _ = apply_layer(
                        spec, p_i, k_group.get(str(i)), x, cfg,
                        positions=positions, pad_mask=batch.get("pad_mask"),
                        image_embeds=batch.get("image_embeds"),
                        update_state=False)
                new_c[str(i)] = c_i
            return x, new_c

        xs = (params["stack"][si], kstate[si], cache[si])
        x, nc = jax.lax.scan(lambda c, xs: group_fn(c, xs), x, xs)
        new_cache.append(nc)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.logits_out(params["embed"], x, cfg.tie_embeddings,
                          cfg.logit_softcap)
    from repro.models.model import mask_vocab_pad
    return mask_vocab_pad(logits, cfg), new_cache
