"""Online mini-batch spherical k-means for routing attention.

Implements the centroid machinery of Roy et al. 2020 (Section 4.1):

* routing vectors are projected onto the (scaled) unit ball with a
  scale/bias-free LayerNorm (`normalize_routing`) — this makes MIPS
  equivalent to nearest-centroid search;
* centroids are *state*, not parameters-with-gradients: they are updated by
  an exponential moving average of the vectors assigned to them
  (Algorithm 1, line 31), with padding excluded;
* assignment for the EMA uses argmax over centroid affinities; membership
  for attention uses balanced per-centroid top-w (in routing.py).

State layout: centroids `mu` have shape (num_heads, k, head_dim) per routing
layer; the framework threads a dict {layer_name: KMeansState} through the
train step functionally.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class KMeansState(NamedTuple):
    mu: jax.Array          # (H_r, k, dh) float32


def init_kmeans(key: jax.Array, num_heads: int, num_clusters: int,
                head_dim: int) -> KMeansState:
    """Random unit-ball init, scaled like the routing vectors (sqrt(d))."""
    mu = jax.random.normal(key, (num_heads, num_clusters, head_dim),
                           dtype=jnp.float32)
    mu = mu / (jnp.linalg.norm(mu, axis=-1, keepdims=True) + 1e-6)
    return KMeansState(mu=mu * jnp.sqrt(head_dim).astype(jnp.float32))


def normalize_routing(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """LayerNorm with scale/bias disabled (paper Section 4.1).

    Output has exact norm sqrt(d): equivalent to projecting onto the
    d-ball scaled by sqrt(d), which keeps entries O(1) (paper's stated
    motivation for LN over plain l2 normalization).
    """
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
    return ((x32 - mean) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def cluster_scores(r: jax.Array, mu: jax.Array) -> jax.Array:
    """Affinity of each routing vector to each centroid.

    r: (B, H, N, dh), mu: (H, k, dh) -> (B, H, N, k). fp32 for stable top-k.
    """
    return jnp.einsum("bhnd,hkd->bhnk", r.astype(jnp.float32),
                      mu.astype(jnp.float32))


def nearest_onehot(scores: jax.Array,
                   mask: Optional[jax.Array] = None) -> jax.Array:
    """Nearest-centroid (argmax) assignment as a masked fp32 one-hot.

    scores: (B, H, N, k) centroid affinities; mask: (B, N) bool, True =
    real token -> (B, H, N, k). The building block shared by the EMA
    update and by occupancy accounting (repro.obs routing-health stats
    recompute the same assignment from the same scores, so the two views
    of "which centroid owns this token" can never drift apart).
    """
    k = scores.shape[-1]
    onehot = jax.nn.one_hot(jnp.argmax(scores, axis=-1), k,
                            dtype=jnp.float32)
    if mask is not None:
        onehot = onehot * mask[:, None, :, None].astype(jnp.float32)
    return onehot


def ema_update(state: KMeansState, r_q: jax.Array,
               r_k: Optional[jax.Array] = None,
               mask: Optional[jax.Array] = None,
               decay: float = 0.999) -> KMeansState:
    """EMA centroid update (Algorithm 1 line 31), scatter-mean variant.

    r_q / r_k: (B, H, N, dh) routing vectors (already normalized).
    mask: (B, N) bool, True for real (non-pad) tokens.
    With shared QK (causal LM) pass r_k=None: the Q and K sums coincide and
    the (1-lambda)/2 + (1-lambda)/2 split collapses to a single mean.

    We use the *mean* of assigned vectors rather than the paper's raw sum:
    the sum makes the update magnitude depend on cluster occupancy (and
    explodes for large batches); the mean is the standard mini-batch k-means
    step (Bottou & Bengio 1995) and keeps centroid norms at the sqrt(d)
    scale of the routing vectors. Flagged in DESIGN.md §3.
    """
    def one_side(r):
        scores = cluster_scores(r, state.mu)              # (B,H,N,k)
        onehot = nearest_onehot(scores, mask)             # (B,H,N,k)
        # sum of members and member counts per (head, centroid)
        sums = jnp.einsum("bhnk,bhnd->hkd", onehot, r.astype(jnp.float32))
        cnts = jnp.einsum("bhnk->hk", onehot)
        return sums, cnts

    sums, cnts = one_side(r_q)
    if r_k is not None:
        s2, c2 = one_side(r_k)
        sums, cnts = sums + s2, cnts + c2
    means = sums / jnp.maximum(cnts, 1.0)[..., None]
    # empty clusters keep their previous centroid (no decay toward zero)
    occupied = (cnts > 0)[..., None]
    new_mu = jnp.where(occupied, decay * state.mu + (1.0 - decay) * means,
                       state.mu)
    return KMeansState(mu=jax.lax.stop_gradient(new_mu))
