"""Blocked local (sliding-window) attention — the paper's local heads.

Faithful to the Routing Transformer TF implementation: the sequence is cut
into blocks of `window` tokens; a query block attends to itself and the
previous block (plus the next block in encoder mode), causally masked on
absolute positions. Effective receptive field per layer is in [w, 2w).
GQA-native, fp32 softmax, O(N * w) memory.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_BIG_NEG = -1e9


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: int,
                    causal: bool = True,
                    pad_mask: Optional[jax.Array] = None) -> jax.Array:
    """q: (B,H,N,dh); k,v: (B,Hkv,N,dh) -> (B,H,N,dh)."""
    B, H, N, dh = q.shape
    Hkv = k.shape[1]
    w = min(window, N)
    nb = -(-N // w)
    Np = nb * w
    pm = jnp.ones((B, N), bool) if pad_mask is None else pad_mask
    if Np != N:
        padseq = [(0, 0), (0, 0), (0, Np - N), (0, 0)]
        q = jnp.pad(q, padseq)
        k = jnp.pad(k, padseq)
        v = jnp.pad(v, padseq)
        pm = jnp.pad(pm, [(0, 0), (0, Np - N)])

    qb = q.reshape(B, Hkv, H // Hkv, nb, w, dh)
    kb = k.reshape(B, Hkv, nb, w, dh)
    vb = v.reshape(B, Hkv, nb, w, dh)
    pmb = pm.reshape(B, nb, w)

    def shifted(x, direction):
        zeros = jnp.zeros_like(x[:, :, :1]) if x.ndim == 5 else \
            jnp.zeros_like(x[:, :1])
        if direction == -1:   # previous block
            body = x[:, :, :-1] if x.ndim == 5 else x[:, :-1]
            return jnp.concatenate([zeros, body], axis=-3 if x.ndim == 5 else 1)
        body = x[:, :, 1:] if x.ndim == 5 else x[:, 1:]
        return jnp.concatenate([body, zeros], axis=-3 if x.ndim == 5 else 1)

    k_cat = [shifted(kb, -1), kb]
    v_cat = [shifted(vb, -1), vb]
    pm_cat = [shifted(pmb, -1), pmb]
    # key absolute positions per block: prev block then own block
    pos_own = (jnp.arange(nb)[:, None] * w + jnp.arange(w)[None, :])
    pos_cat = [pos_own - w, pos_own]
    if not causal:
        k_cat.append(shifted(kb, +1))
        v_cat.append(shifted(vb, +1))
        pm_cat.append(shifted(pmb, +1))
        pos_cat.append(pos_own + w)
    kc = jnp.concatenate(k_cat, axis=-2)                 # (B,Hkv,nb,cw,dh)
    vc = jnp.concatenate(v_cat, axis=-2)
    pmc = jnp.concatenate(pm_cat, axis=-1)               # (B,nb,cw)
    pos_k = jnp.concatenate(pos_cat, axis=-1)            # (nb,cw)

    logits = jnp.einsum("bhgnwd,bhnud->bhgnwu", qb, kc).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh).astype(jnp.float32)
    pos_q = pos_own                                      # (nb,w)
    keep = (pos_k[:, None, :] >= 0) & (pos_k[:, None, :] < Np)
    if causal:
        keep &= pos_q[:, :, None] >= pos_k[:, None, :]
    keep = keep[None, None, None] & pmc[:, None, None, :, None, :]
    logits = jnp.where(keep, logits, _BIG_NEG)
    attn = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (pad queries in encoder mode) -> zero output
    any_keep = keep.any(-1, keepdims=True)
    attn = jnp.where(any_keep, attn, 0.0)
    out = jnp.einsum("bhgnwu,bhnud->bhgnwd", attn.astype(vc.dtype), vc)
    out = out.reshape(B, H, Np, dh)
    return out[:, :, :N]
