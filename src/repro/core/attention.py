"""Dense (full) causal/bidirectional GQA attention + memory-efficient path.

Two implementations with identical math:
  * `full_attention(..., chunk=0)` — one-shot einsum softmax (small N).
  * `full_attention(..., chunk=c)` — lax.scan over KV chunks with a running
    online-softmax accumulator (flash-attention recurrence); peak memory
    O(N*c) instead of O(N^2). Used for the 32k/500k shape cells.

GQA-native: q has H heads, k/v have Hkv heads; no materialized repeat.
Softmax statistics are fp32 regardless of activation dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_BIG_NEG = -1e9


def _split_gqa(q: jax.Array, num_kv: int) -> jax.Array:
    B, H, N, dh = q.shape
    return q.reshape(B, num_kv, H // num_kv, N, dh)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool = True,
                   pad_mask: Optional[jax.Array] = None,
                   positions: Optional[jax.Array] = None,
                   chunk: int = 0,
                   logit_scale: Optional[float] = None) -> jax.Array:
    """q: (B,H,N,dh); k,v: (B,Hkv,M,dh); returns (B,H,N,dh).

    pad_mask: (B, M) bool over keys. positions: (B, N) query positions for
    the causal mask when N != M (decode: N=1 vs cache M).
    """
    if chunk:
        return _chunked_attention(q, k, v, causal, pad_mask, positions,
                                  chunk, logit_scale)
    B, H, N, dh = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    scale = logit_scale if logit_scale is not None else 1.0 / jnp.sqrt(dh)
    qg = _split_gqa(q, Hkv)
    logits = jnp.einsum("bhgnd,bhmd->bhgnm", qg, k).astype(jnp.float32)
    logits = logits * jnp.float32(scale)
    if causal:
        pos_q = (positions if positions is not None
                 else jnp.broadcast_to(jnp.arange(N), (B, N)))
        pos_k = jnp.arange(M)
        cm = pos_q[:, None, None, :, None] >= pos_k[None, None, None, None, :]
        logits = jnp.where(cm, logits, _BIG_NEG)
    if pad_mask is not None:
        logits = jnp.where(pad_mask[:, None, None, None, :], logits, _BIG_NEG)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgnm,bhmd->bhgnd", attn.astype(v.dtype), v)
    return out.reshape(B, H, N, dh)


def _chunked_attention(q, k, v, causal, pad_mask, positions, chunk,
                       logit_scale):
    """Online-softmax scan over KV chunks (flash recurrence, XLA version)."""
    B, H, N, dh = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    scale = logit_scale if logit_scale is not None else 1.0 / jnp.sqrt(dh)
    nc = -(-M // chunk)
    Mp = nc * chunk
    if Mp != M:
        pad = [(0, 0), (0, 0), (0, Mp - M), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        pm = jnp.ones((B, M), bool) if pad_mask is None else pad_mask
        pad_mask = jnp.pad(pm, [(0, 0), (0, Mp - M)])
    kc = k.reshape(B, Hkv, nc, chunk, dh)
    vc = v.reshape(B, Hkv, nc, chunk, dh)
    pmc = (pad_mask.reshape(B, nc, chunk) if pad_mask is not None else None)
    pos_q = (positions if positions is not None
             else jnp.broadcast_to(jnp.arange(N), (B, N)))
    qg = _split_gqa(q, Hkv)                             # (B,Hkv,g,N,dh)

    def step(carry, ci):
        m, l, acc = carry
        kb = kc[:, :, ci]                               # (B,Hkv,c,dh)
        vb = vc[:, :, ci]
        logits = jnp.einsum("bhgnd,bhcd->bhgnc", qg, kb).astype(jnp.float32)
        logits = logits * jnp.float32(scale)
        pos_k = ci * chunk + jnp.arange(chunk)
        keep = jnp.ones((B, 1, 1, N, chunk), bool)
        if causal:
            keep &= (pos_q[:, None, None, :, None]
                     >= pos_k[None, None, None, None, :])
        if pmc is not None:
            keep &= pmc[:, ci][:, None, None, None, :]
        logits = jnp.where(keep, logits, _BIG_NEG)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None]) * keep
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgnc,bhcd->bhgnd", p,
                                vb.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    g = qg.shape[2]
    m0 = jnp.full((B, Hkv, g, N), _BIG_NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, N), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, N, dh), jnp.float32)
    # checkpoint the chunk body: the scan then saves only the (m, l, acc)
    # carry chain instead of per-chunk fp32 logits/probs — without this the
    # stacked residuals equal the full (N x M) score matrix and training
    # memory explodes (flash-attention recomputes in bwd for the same
    # reason). Measured: granite-8b train_4k 16.8 -> 6.7 GiB/chip (§Perf).
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  jnp.arange(nc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, N, dh).astype(q.dtype)
