"""Routing attention — Algorithm 1 of Roy et al. 2020, batched + multi-head.

Pipeline (per head):
  1. routing vectors r = LN_no-scale-bias(q) (and r_k for the non-shared
     case);   shared-QK in the causal/LM setting (paper Section 4.1).
  2. affinities  S = r @ mu^T                      (B, H, N, k)
  3. balanced membership: per-centroid top-w over tokens, indices sorted
     ascending to preserve temporal order          (B, H, k, w)
  4. gather q/k/v rows, intra-cluster attention with a causal mask on
     *original positions*, fp32 softmax            (B, H, k, w, w)
  5. scatter back to sequence order (scatter-mean over duplicate
     memberships; tokens selected by no cluster output 0)
  6. EMA centroid update (k-means state is returned, not mutated).

Complexity: O(nkd) for step 2 + O(k w^2 d) = O(n^2 d / k) for step 4;
k = sqrt(n) gives the paper's O(n^1.5 d).

The O(k w^2 d) attention (step 4) is the compute hot-spot and has two
Pallas TPU kernels (`repro.kernels.routing_attention`); this module is the
pure-JAX reference and the default on CPU. `impl="pallas"` runs the
*gathered* kernel (XLA materializes the (B,H,k,w,dh) blocks, the kernel
streams them); `impl="pallas_fused"` runs the *gather-free* kernel: q/k/v
stay in sequence layout, the membership indices ride in via scalar
prefetch, and steps 4's gathers never touch HBM (DESIGN.md §9). Both
kernel paths are differentiable (custom flash-style VJPs).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RoutingConfig, with_overrides
from repro.core.kmeans import (KMeansState, cluster_scores, ema_update,
                               normalize_routing)

_BIG_NEG = -1e9

# fused-kernel impl names -> the `paged` argument of the kernel entry
# point (None = auto-switch on the VMEM residency budget)
_FUSED_IMPLS = {"pallas_fused": None,
                "pallas_fused_paged": True,
                "pallas_fused_unpaged": False}


class RoutingOutput(NamedTuple):
    out: jax.Array                      # (B, H, N, dh)
    state: KMeansState                  # updated centroids
    attn: Optional[jax.Array] = None    # (B,H,k,w,w) if return_attn
    q_idx: Optional[jax.Array] = None   # (B,H,k,w) if return_attn
    stats: Optional[Any] = None         # obs.RoutingStats if cfg.stats


def balanced_topk(scores: jax.Array, window: int,
                  valid: Optional[jax.Array] = None) -> jax.Array:
    """Per-centroid balanced top-w membership (Algorithm 1 lines 12-18).

    scores: (B, H, N, k) centroid affinities.
    valid:  (B, N) bool; padding is pushed to -inf so it is only selected
            once every real token is taken.
    Returns sorted indices (B, H, k, w).
    """
    if valid is not None:
        scores = jnp.where(valid[:, None, :, None], scores, _BIG_NEG)
    per_centroid = jnp.swapaxes(scores, -1, -2)          # (B,H,k,N)
    _, idx = jax.lax.top_k(per_centroid, window)         # (B,H,k,w)
    return jnp.sort(idx, axis=-1)                        # preserve order


def _gather_rows(x: jax.Array, idx: jax.Array) -> jax.Array:
    """x: (B,H,N,d), idx: (B,H,k,w) -> (B,H,k,w,d)."""
    B, H, N, d = x.shape
    _, _, k, w = idx.shape
    flat = jnp.take_along_axis(x, idx.reshape(B, H, k * w, 1), axis=2)
    return flat.reshape(B, H, k, w, d)


def _scatter_rows(og: jax.Array, idx: jax.Array, n: int,
                  mode: str) -> jax.Array:
    """Scatter per-cluster outputs back to the sequence.

    og: (B,H,k,w,d), idx: (B,H,k,w) -> (B,H,n,d).
    mode="mean": scatter-add + divide by membership count (default).
    mode="last": plain scatter, later clusters win (Alg. 1 line 27 verbatim).
    """
    B, H, k, w, d = og.shape
    flat_og = og.reshape(B, H, k * w, d)
    flat_idx = idx.reshape(B, H, k * w)
    bi = jnp.arange(B)[:, None, None]
    hi = jnp.arange(H)[None, :, None]
    if mode == "last":
        out = jnp.zeros((B, H, n, d), og.dtype)
        return out.at[bi, hi, flat_idx].set(flat_og)
    out = jnp.zeros((B, H, n, d), jnp.float32)
    out = out.at[bi, hi, flat_idx].add(flat_og.astype(jnp.float32))
    cnt = jnp.zeros((B, H, n), jnp.float32)
    cnt = cnt.at[bi, hi, flat_idx].add(1.0)
    return (out / jnp.maximum(cnt, 1.0)[..., None]).astype(og.dtype)


def routed_attention(q: jax.Array,
                     k: Optional[jax.Array],
                     v: jax.Array,
                     state: KMeansState,
                     cfg: RoutingConfig,
                     positions: Optional[jax.Array] = None,
                     pad_mask: Optional[jax.Array] = None,
                     update_state: bool = True,
                     return_attn: bool = False,
                     impl: str = "xla",
                     interpret: Optional[bool] = None) -> RoutingOutput:
    """Content-routed sparse attention.

    q, v: (B, H, N, dh); k: same or None (shared-QK causal mode).
    positions: (B, N) int32 original positions (defaults to arange) — the
        causal mask is evaluated on these, which is what makes gathered
        blocks order-correct.
    pad_mask: (B, N) bool, True = real token. Padding is excluded from
        top-k selection, attention, and centroid updates (paper Section 4.1).
    impl: "xla" reference | "pallas" gathered kernel | "pallas_fused"
        gather-free kernel (sequence-layout q/k/v, scalar-prefetch
        membership — no (B,H,k,w,dh) q/k/v intermediates in HBM; the
        memory plan auto-switches to double-buffered VMEM paging past the
        residency budget) | "pallas_fused_paged" / "pallas_fused_unpaged"
        force that plan.
    interpret: Pallas interpret mode for the kernel impls; None derives
        from the platform (compiled on TPU, interpret elsewhere).
    """
    B, H, N, dh = q.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))

    # --- segmented (shard-local) routing: fold sequence chunks into the
    # batch so assignment/top-k/gather never cross segment boundaries.
    # Causality is preserved (the mask uses original positions and every
    # segment only holds a contiguous span). Centroids are shared across
    # segments; with segments == TP width the fold aligns with the
    # model-axis seq sharding and routing becomes collective-free.
    ns = cfg.segments
    if ns > 1 and N % ns == 0 and N // ns >= cfg.num_clusters:
        Nl = N // ns

        def fold(x):          # (B,H,N,d) -> (B*ns,H,Nl,d)
            return x.reshape(B, H, ns, Nl, -1).transpose(0, 2, 1, 3, 4) \
                    .reshape(B * ns, H, Nl, -1)

        def fold2(x):         # (B,N) -> (B*ns,Nl)
            return x.reshape(B * ns, Nl)

        sub = with_overrides(cfg, segments=1)
        out = routed_attention(
            fold(q), None if k is None else fold(k), fold(v), state, sub,
            positions=fold2(positions),
            pad_mask=None if pad_mask is None else fold2(pad_mask),
            update_state=update_state, return_attn=False, impl=impl,
            interpret=interpret)
        o = out.out.reshape(B, ns, H, Nl, dh).transpose(0, 2, 1, 3, 4) \
                   .reshape(B, H, N, dh)
        # stats were computed on the folded (B*ns) batch: per-head means
        # over segments, which is exactly the shard-local health signal
        return RoutingOutput(out=o, state=out.state, stats=out.stats)

    w = min(cfg.window or max(1, N // cfg.num_clusters), N)
    shared = cfg.share_qk and cfg.causal

    r_q = normalize_routing(q)
    if shared:
        r_k, k_attn = r_q, r_q
    else:
        r_k = normalize_routing(k if k is not None else q)
        k_attn = r_k

    scores_q = cluster_scores(r_q, state.mu)             # (B,H,N,k)
    q_idx = balanced_topk(scores_q, w, pad_mask)         # (B,H,k,w)
    if shared:
        k_idx = q_idx
    else:
        scores_k = cluster_scores(r_k, state.mu)
        k_idx = balanced_topk(scores_k, w, pad_mask)

    if impl in _FUSED_IMPLS:
        # gather-free: q/k/v stay in sequence layout; the kernel pulls
        # member rows through the scalar-prefetched indices and the mask
        # reads the (B,N) position/validity arrays directly. The paged
        # suffix forces the kernel's memory plan; bare "pallas_fused"
        # auto-switches on the VMEM residency budget.
        from repro.kernels import ops as kops
        og = kops.routed_attention_fused(
            r_q, None if shared else k_attn, v, q_idx, k_idx,
            positions.astype(jnp.int32), causal=cfg.causal,
            kvalid=pad_mask, interpret=interpret,
            paged=_FUSED_IMPLS[impl])
        attn = None
    else:
        qg = _gather_rows(r_q, q_idx)                    # (B,H,k,w,dh)
        # shared-QK causal: k_attn is r_q and k_idx is q_idx, so the key
        # gather is identical to the query gather — reuse it
        kg = qg if shared else _gather_rows(k_attn, k_idx)
        vg = _gather_rows(v, k_idx)
        pos = positions[:, None, :].astype(jnp.int32)
        pos_q = jnp.take_along_axis(
            jnp.broadcast_to(pos, (B, H, N)), q_idx.reshape(B, H, -1),
            axis=2).reshape(B, H, q_idx.shape[2], w)
        pos_k = pos_q if shared else jnp.take_along_axis(
            jnp.broadcast_to(pos, (B, H, N)), k_idx.reshape(B, H, -1),
            axis=2).reshape(B, H, k_idx.shape[2], w)

        valid_k = None
        if pad_mask is not None:
            vm = jnp.broadcast_to(pad_mask[:, None, :], (B, H, N))
            valid_k = jnp.take_along_axis(
                vm, k_idx.reshape(B, H, -1), axis=2).reshape(pos_k.shape)

        if impl == "pallas":
            from repro.kernels import ops as kops
            og = kops.routed_attention_blocks(
                qg, kg, vg, pos_q, pos_k, causal=cfg.causal,
                valid_k=valid_k, interpret=interpret)
            attn = None
        else:
            og, attn = _block_attention(qg, kg, vg, pos_q, pos_k,
                                        cfg.causal, valid_k, return_attn)

    out = _scatter_rows(og, q_idx, N, cfg.scatter_mode)
    new_state = state
    if update_state:
        new_state = ema_update(
            state, r_q, None if shared else r_k, pad_mask, cfg.decay)
    stats = None
    if cfg.stats:
        # routing-health telemetry (repro.obs, DESIGN.md §10): reuses the
        # scores/membership computed above; the static `if` keeps the
        # stats-off HLO byte-identical to a build without the flag
        from repro.obs.routing_stats import compute_routing_stats
        stats = compute_routing_stats(
            r_q, k_attn, state.mu, new_state.mu, scores_q, q_idx, k_idx,
            positions, pad_mask, cfg.causal, probes=cfg.stats_probes)
    return RoutingOutput(out=out, state=new_state,
                         attn=attn if return_attn else None,
                         q_idx=q_idx if return_attn else None,
                         stats=stats)


def _block_attention(qg, kg, vg, pos_q, pos_k, causal, valid_k, return_attn):
    """Intra-cluster attention on gathered blocks (pure-JAX reference)."""
    dh = qg.shape[-1]
    logits = jnp.einsum("bhkwd,bhkud->bhkwu", qg, kg).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh).astype(jnp.float32)
    keep = jnp.ones(logits.shape, bool)
    if causal:
        keep &= pos_q[..., :, None] >= pos_k[..., None, :]
    if valid_k is not None:
        keep &= valid_k[..., None, :]
    logits = jnp.where(keep, logits, _BIG_NEG)
    attn = jax.nn.softmax(logits, axis=-1)
    # queries whose cluster holds no attendable key (separate-QK causal
    # case: all keys in the future) output 0, not a uniform average
    attn = jnp.where(keep.any(-1, keepdims=True), attn, 0.0)
    og = jnp.einsum("bhkwu,bhkud->bhkwd", attn.astype(vg.dtype), vg)
    return og, (attn if return_attn else None)


def routing_attention_dense_oracle(q, k, v, state, cfg, positions=None,
                                   pad_mask=None):
    """O(n^2) oracle: dense attention masked to same-cluster pairs.

    Used by tests: builds the (n x n) mask implied by the balanced top-k
    membership and checks `routed_attention` against dense masked softmax.
    Only supports scatter_mode="mean".
    """
    B, H, N, dh = q.shape
    w = min(cfg.window or max(1, N // cfg.num_clusters), N)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))
    r_q = normalize_routing(q)
    if cfg.share_qk and cfg.causal:
        r_k, k_attn = r_q, r_q
    else:
        r_k = normalize_routing(k if k is not None else q)
        k_attn = r_k
    scores_q = cluster_scores(r_q, state.mu)
    q_idx = balanced_topk(scores_q, w, pad_mask)
    if cfg.share_qk and cfg.causal:
        k_idx = q_idx
    else:
        k_idx = balanced_topk(cluster_scores(r_k, state.mu), w, pad_mask)

    # membership[b,h,c,n] = token n belongs to cluster c (as query / as key)
    memb_q = jax.nn.one_hot(q_idx, N, dtype=jnp.float32).sum(3) > 0
    memb_k = jax.nn.one_hot(k_idx, N, dtype=jnp.float32).sum(3) > 0
    out = jnp.zeros((B, H, N, dh), jnp.float32)
    cnt = jnp.zeros((B, H, N), jnp.float32)
    nclusters = q_idx.shape[2]
    for c in range(nclusters):   # oracle: loop is fine for test sizes
        pair = memb_q[:, :, c, :, None] & memb_k[:, :, c, None, :]
        logits = jnp.einsum("bhnd,bhmd->bhnm", r_q, k_attn) / jnp.sqrt(dh)
        keep = pair
        if cfg.causal:
            keep &= (positions[:, None, :, None]
                     >= positions[:, None, None, :])
        if pad_mask is not None:
            keep &= pad_mask[:, None, None, :]
        logits = jnp.where(keep, logits.astype(jnp.float32), _BIG_NEG)
        attn = jax.nn.softmax(logits, axis=-1)
        attn = jnp.where(keep.any(-1, keepdims=True), attn, 0.0)
        o_c = jnp.einsum("bhnm,bhmd->bhnd", attn, v.astype(jnp.float32))
        sel = memb_q[:, :, c, :]
        out = out + jnp.where(sel[..., None], o_c, 0.0)
        cnt = cnt + sel.astype(jnp.float32)
    out = out / jnp.maximum(cnt, 1.0)[..., None]
    return out.astype(q.dtype)
