"""llama-3.2-vision-11b [vlm] — cross-attn image layers
(hf:meta-llama/Llama-3.2-11B-Vision).

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th layer
is a gated cross-attention layer over 1601 vision tokens. The vision
tower is a STUB per spec: input_specs() provides patch embeddings.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm", num_layers=40,
        d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
        vocab_size=128256, attention="full", position="rope",
        norm="rmsnorm", act="swiglu", num_image_tokens=1601,
        max_seq_len=131072)
