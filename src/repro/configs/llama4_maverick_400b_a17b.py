"""llama4-maverick-400b-a17b [moe] — 128 experts, top-1, MoE every other
layer (hf:meta-llama/Llama-4-Maverick-17B-128E pattern).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048. ~400B total /
~17B active. Trains with Adafactor (fp32-factored stats) so optimizer
state fits v5e HBM — see EXPERIMENTS.md §Dry-run.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
        d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
        vocab_size=202048, moe_experts=128, moe_top_k=1, moe_interleave=2,
        moe_shared_expert=True, attention="full", position="rope",
        norm="rmsnorm", act="swiglu", max_seq_len=131072)
