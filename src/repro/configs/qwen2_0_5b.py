"""qwen2-0.5b [dense] — GQA + QKV bias (arXiv:2407.10671; hf).

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, tied embeddings.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense", num_layers=24, d_model=896,
        num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151936,
        attention="full", qkv_bias=True, tie_embeddings=True,
        position="rope", norm="rmsnorm", act="swiglu", max_seq_len=32768,
        rope_theta=1_000_000.0)
