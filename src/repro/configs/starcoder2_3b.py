"""starcoder2-3b [dense] — GQA + RoPE code model (arXiv:2402.19173; hf).

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152; LayerNorm + GeLU
MLP (starcoder2 keeps the GPT-style block).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense", num_layers=30, d_model=3072,
        num_heads=24, num_kv_heads=2, d_ff=12288, vocab_size=49152,
        attention="full", position="rope", norm="layernorm", act="gelu",
        qkv_bias=True, max_seq_len=16384)
