"""mamba2-780m [ssm] — SSD state-space duality (arXiv:2405.21060).

48L d_model=1536, attention-free (d_ff=0: pure mixer stack), vocab 50280,
ssm_state N=128, expand 2 (d_inner 3072, 48 SSD heads of dim 64).
Routing attention is INAPPLICABLE (no attention) — DESIGN.md §4.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
        num_heads=24, num_kv_heads=24, d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_chunk=256, ssm_conv=4,
        position="none", norm="rmsnorm", tie_embeddings=True,
        max_seq_len=1_048_576)
