"""Config registry: `--arch <id>` resolution, smoke reductions, input specs.

`get_config(arch)` returns the full published config; `reduced_config(arch)`
returns a same-family miniature for CPU smoke tests; `input_specs(cfg, cell)`
returns ShapeDtypeStruct stand-ins for every model input of a shape cell
(no allocation — the dry-run lowers against these).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, RoutingConfig, ShapeCell,
                                SHAPE_CELLS, with_overrides)
from repro.configs import (granite_8b, hubert_xlarge,
                           llama4_maverick_400b_a17b, llama4_scout_17b_a16e,
                           llama_3_2_vision_11b, mamba2_780m, paper,
                           phi4_mini_3_8b, qwen2_0_5b, recurrentgemma_9b,
                           starcoder2_3b)

ARCHS = {
    "mamba2-780m": mamba2_780m.config,
    "granite-8b": granite_8b.config,
    "qwen2-0.5b": qwen2_0_5b.config,
    "starcoder2-3b": starcoder2_3b.config,
    "phi4-mini-3.8b": phi4_mini_3_8b.config,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.config,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b.config,
    "recurrentgemma-9b": recurrentgemma_9b.config,
    "hubert-xlarge": hubert_xlarge.config,
    "llama-3.2-vision-11b": llama_3_2_vision_11b.config,
    # the paper's own models
    "rt-wikitext103": paper.wikitext103,
    "rt-enwik8": paper.enwik8,
    "rt-imagenet64": paper.imagenet64,
    "rt-pg19": paper.pg19,
    "rt-cifar10": paper.cifar10,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]()


def _pow2_round(x: int) -> int:
    return 2 ** max(0, round(math.log2(max(x, 1))))


def routing_for_seq(cfg: ModelConfig, seq_len: int,
                    segments: int = 0) -> ModelConfig:
    """Scale k ~ sqrt(n) (paper's optimal choice) for a shape cell.

    segments=0 -> auto: shard-local routing (segments=16, the TP width)
    for seq >= 32k training/prefill shapes — the beyond-paper fix for the
    global-top-k collective bottleneck (EXPERIMENTS.md §Perf). Decode
    cells ignore segments (the cluster-paged cache is already local)."""
    if segments == 0:
        segments = 16 if seq_len >= 32768 else 1
    n_local = max(seq_len // max(segments, 1), 1)
    k = min(_pow2_round(int(math.sqrt(n_local))), max(n_local // 16, 1))
    return with_overrides(cfg, routing=with_overrides(
        cfg.routing, num_clusters=max(k, 1), window=0, segments=segments))


def with_routing(cfg: ModelConfig) -> ModelConfig:
    """Enable the paper's technique on a dense/moe/vlm arch (half heads
    local, half routing — the paper's default split)."""
    if cfg.family in ("ssm",):
        return cfg                                  # inapplicable
    attn = "local+routing"
    return with_overrides(cfg, attention=attn)


def reduced_config(arch: str) -> ModelConfig:
    """Same-family miniature: few layers/width, tiny vocab, small experts."""
    cfg = get_config(arch)
    pat = {"moe": max(2, cfg.moe_interleave), "vlm": 5,
           "hybrid": len(cfg.hybrid_pattern or ("r", "r", "a"))}
    L = pat.get(cfg.family, 2)
    if cfg.family == "hybrid":
        L = L + 1                                    # exercise the tail path
    H = 4
    Hkv = max(1, (cfg.num_kv_heads * H) // cfg.num_heads)
    over = dict(
        num_layers=L, d_model=64, num_heads=H, num_kv_heads=Hkv,
        head_dim=16, d_ff=0 if cfg.family == "ssm" else 128,
        vocab_size=128, dtype="float32", max_seq_len=512,
        routing=with_overrides(cfg.routing, num_clusters=4, local_window=32,
                               routing_layers=(), routing_heads=0),
        attn_window=32, dropout=0.0)
    if cfg.family == "moe":
        over.update(moe_experts=4)
    if cfg.family == "ssm":
        over.update(ssm_state=16, ssm_chunk=32)
    if cfg.family == "hybrid":
        over.update(lru_width=64)
    if cfg.family == "vlm":
        over.update(num_image_tokens=17)
    return with_overrides(cfg, **over)


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                dtype: str = "bfloat16") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the model inputs of one shape cell."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    if cell.kind in ("train", "prefill"):
        # +1 for the next-token shift — not for encoders (masked prediction
        # has no shift; an odd 4097 also breaks SP seq sharding)
        extra = 1 if (cell.kind == "train" and cfg.family != "encoder") else 0
        specs = {"tokens": jax.ShapeDtypeStruct((B, S + extra), i32)}
        if cfg.family == "encoder":
            specs["features"] = jax.ShapeDtypeStruct(
                (B, S + extra, cfg.d_model), act)
            specs["mask_spans"] = jax.ShapeDtypeStruct(
                (B, S + extra), jnp.bool_)
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg.d_model), act)
        return specs
    # decode: one token + positions; the cache is built separately
    specs = {"tokens": jax.ShapeDtypeStruct((B,), i32),
             "pos": jax.ShapeDtypeStruct((B,), i32)}
    return specs


def cell_by_name(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)
