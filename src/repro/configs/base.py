"""Config system for the repro framework.

Plain dataclasses (no pydantic dependency in the hot path) with:
  * `ModelConfig`   — architecture definition (one per assigned arch).
  * `RoutingConfig` — the paper's technique knobs (Section 4.1 / Algorithm 1).
  * `TrainConfig`   — optimizer / schedule / batch.
  * `MeshConfig`    — parallelism layout.
  * `RunConfig`     — the composed, launchable unit.

Configs are immutable; use `dataclasses.replace` (re-exported as
`with_overrides`) to derive variants (smoke-test reductions, dry-run shapes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


def with_overrides(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Routing attention (the paper's contribution)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RoutingConfig:
    """Knobs for content-based sparse attention (Roy et al. 2020, Alg. 1)."""

    num_clusters: int = 16          # k; paper uses k ~ sqrt(n)
    window: int = 0                 # w tokens per cluster; 0 => n // k
    decay: float = 0.999            # lambda, EMA decay for centroids
    share_qk: bool = True           # causal LM: K <- Q (paper Section 4.1)
    scatter_mode: str = "mean"      # {"mean", "last"}: duplicate resolution
    # Fraction of heads doing routing (rest local). Paper: 0.5 everywhere
    # except PG-19 (2 heads, last 2 layers only).
    routing_heads: int = 0          # 0 => heads // 2
    routing_layers: Tuple[int, ...] = ()  # () => all layers
    local_window: int = 256         # window of the local-attention heads
    causal: bool = True             # encoder mode uses False
    # Beyond-paper: route within `segments` sequence chunks instead of
    # globally. With segments == TP width, the segment dim aligns with the
    # model-axis sequence sharding and balanced top-k becomes shard-LOCAL
    # (no seq re-gathers -- the measured collective bottleneck of naive
    # GSPMD routing, EXPERIMENTS.md SPerf). Global receptive field is
    # restored across layers by the local heads + depth (hierarchical
    # routing). segments=1 == the paper's global routing.
    segments: int = 1
    # Routing-health telemetry (repro.obs): compute the RoutingStats aux
    # pytree (occupancy entropy, dead clusters, centroid drift, balanced-
    # vs-nearest mismatch, sampled attention recall) inside the jitted
    # step. Off by default and a true no-op when off: the stats branch is
    # a static python conditional, so the compiled HLO is byte-identical
    # to a build without the flag (asserted in tests/test_obs.py).
    stats: bool = False
    stats_probes: int = 8           # probe queries for the recall estimate


# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense|moe|ssm|hybrid|encoder|vlm
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4           # GQA
    head_dim: int = 0               # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 8192
    # attention backend: full | local | routing | local+routing
    attention: str = "full"
    routing: RoutingConfig = field(default_factory=RoutingConfig)
    # positional encoding: rope | none (encoder conv-pos stubbed as learned)
    position: str = "rope"
    rope_theta: float = 10000.0
    qkv_bias: bool = False          # qwen2 uses True
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu | relu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"         # activation/param dtype
    # --- MoE ---
    moe_experts: int = 0            # 0 => dense FFN
    moe_top_k: int = 1
    moe_interleave: int = 1         # MoE every Nth layer (1 => all layers)
    moe_capacity_factor: float = 1.25
    moe_shared_expert: bool = True  # llama4-style shared expert
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0              # N, state dim per head (mamba2: 128)
    ssm_heads: int = 0              # SSD heads (d_inner // headdim)
    ssm_expand: int = 2
    ssm_chunk: int = 256            # SSD chunk length
    ssm_conv: int = 4               # depthwise conv width
    # --- hybrid (recurrentgemma) ---
    hybrid_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","attn")
    lru_width: int = 0              # rg-lru hidden width (0 => d_model)
    attn_window: int = 2048         # local attention window of hybrid/enc
    # --- encoder (hubert) ---
    is_causal: bool = True          # encoder => False
    mask_prob: float = 0.08         # hubert masked prediction
    # --- vlm ---
    cross_attn_layers: Tuple[int, ...] = ()  # layer idxs with cross-attn
    num_image_tokens: int = 1601    # stub vision frontend tokens
    # --- logits ---
    logit_softcap: float = 0.0
    dropout: float = 0.0
    # KV chunk of the full-attention reference: None => auto (the
    # AttentionSpec resolves a chunk when N > 4096), 0 => force one-shot
    # softmax even for long N, c > 0 => force chunk c
    attn_chunk: Optional[int] = None

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 256 so vocab-TP
        shards cleanly on any mesh (Megatron-style). Logits above
        `vocab_size` are masked to -1e9 in apply_model."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline term)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        dh, H, Hkv = self.head_dim_, self.num_heads, self.num_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            # in_proj (z,x,B,C,dt) + out_proj + conv + norms
            nheads = self.ssm_heads or max(1, d_in // 64)
            per = d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d + d
            return emb + L * per
        attn = d * (H * dh) + d * (2 * Hkv * dh) + (H * dh) * d
        ffn_dense = 3 * d * f if self.act == "swiglu" else 2 * d * f
        if self.family == "moe":
            n_moe = len([i for i in range(L) if i % self.moe_interleave == 0])
            n_dense = L - n_moe
            ffn = n_moe * (self.moe_experts * ffn_dense
                           + (ffn_dense if self.moe_shared_expert else 0)
                           + d * self.moe_experts)  # router
            ffn += n_dense * ffn_dense
            return emb + L * attn + ffn + L * 2 * d
        if self.family == "hybrid":
            pat = self.hybrid_pattern or ("rglru",)
            w = self.lru_width or d
            n_lru = sum(1 for i in range(L) if pat[i % len(pat)] == "rglru")
            n_att = L - n_lru
            lru = d * w * 3 + w * d + 2 * w * 4   # gates approx
            return emb + n_att * attn + L * ffn_dense + n_lru * lru + L * 2 * d
        return emb + L * (attn + ffn_dense + 2 * d)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        dense_like = with_overrides(
            self, family="dense",
            d_ff=self.d_ff * (self.moe_top_k + (1 if self.moe_shared_expert else 0)))
        return dense_like.param_count()


# ---------------------------------------------------------------------------
# Training / parallelism / run
# ---------------------------------------------------------------------------
GRAD_COMPRESSION_MODES = ("none", "int8_ef")


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 512
    optimizer: str = "adam"         # adam | adafactor
    lr: float = 2e-4                # paper: 2e-4 Adam (PG19: adafactor 0.01)
    betas: Tuple[float, float] = (0.9, 0.98)
    eps: float = 1e-9
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    schedule: str = "vaswani"       # vaswani rsqrt | linear_warmup_rsqrt | const
    warmup_steps: int = 1000
    steps: int = 100
    grad_accum: int = 1             # microbatch accumulation
    accum_dtype: str = "float32"    # grad accumulation dtype (400B: bf16)
    remat: str = "full"             # none | full | save_dots
    seed: int = 0
    grad_compression: str = "none"  # GRAD_COMPRESSION_MODES
    z_loss: float = 0.0

    def __post_init__(self):
        # fail at construction, not as a KeyError deep inside the jitted
        # train step after minutes of compilation
        if self.grad_compression not in GRAD_COMPRESSION_MODES:
            raise ValueError(
                f"grad_compression must be one of {GRAD_COMPRESSION_MODES}, "
                f"got {self.grad_compression!r}")


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (1,)
    axes: Tuple[str, ...] = ("data",)
    fsdp: bool = True               # shard params over "data" too (zero-3)
    seq_parallel: bool = False      # Megatron-SP on residual stream


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    mode: str = "train"             # train | prefill | decode


# ---------------------------------------------------------------------------
# Assigned input-shape cells (applies to every LM arch)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)
