"""The paper's own Routing Transformer configs (Tables 1-5, 7).

These drive the benchmark harnesses 1:1. Quality numbers in the paper come
from multi-week TPUv3-128 runs; here the configs define the exact
architectures, the benchmarks measure their step mechanics + roofline.
"""
from repro.configs.base import ModelConfig, RoutingConfig


def wikitext103() -> ModelConfig:
    """Table 2: 10L, 16 heads, k=16, window 256, test ppl 15.8."""
    return ModelConfig(
        name="rt-wikitext103", family="dense", num_layers=10, d_model=1024,
        num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=267735,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=16, local_window=256),
        attn_window=256, position="rope", norm="layernorm", act="relu",
        dropout=0.3, max_seq_len=4096)


def enwik8() -> ModelConfig:
    """Table 3: 12L, 8 heads, k=32, window 256, seq 8192, 0.99 bpb."""
    return ModelConfig(
        name="rt-enwik8", family="dense", num_layers=12, d_model=1024,
        num_heads=8, num_kv_heads=8, d_ff=4096, vocab_size=256,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=32, local_window=256),
        attn_window=256, position="rope", norm="layernorm", act="relu",
        dropout=0.4, max_seq_len=8192)


def imagenet64() -> ModelConfig:
    """Table 4: 24L, 16 heads, k=8, window 2048, seq 12288, 3.43 b/d."""
    return ModelConfig(
        name="rt-imagenet64", family="dense", num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=256,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=8, window=2048,
                              local_window=2048),
        attn_window=2048, position="rope", norm="layernorm", act="relu",
        max_seq_len=12288)


def pg19() -> ModelConfig:
    """Table 5: 22L, 8 heads, d=1032, seq 8192, 2 routing heads in the
    last two layers only, Adafactor — test ppl 33.2 (SOTA)."""
    return ModelConfig(
        name="rt-pg19", family="dense", num_layers=22, d_model=1032,
        num_heads=8, num_kv_heads=8, d_ff=4128, vocab_size=98000,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=16, local_window=512,
                              routing_heads=2, routing_layers=(20, 21)),
        attn_window=512, position="rope", norm="layernorm", act="relu",
        max_seq_len=8192)


def cifar10(routing_heads: int = 4, routing_layers: int = 4,
            window: int = 512) -> ModelConfig:
    """Table 1 ablation grid: 12L, 8 heads total, routing heads/layers and
    attention window varied; k=6."""
    L = 12
    rl = tuple(range(L - routing_layers, L)) if routing_layers < L else ()
    return ModelConfig(
        name=f"rt-cifar10-r{routing_heads}x{routing_layers}w{window}",
        family="dense", num_layers=L, d_model=512, num_heads=8,
        num_kv_heads=8, d_ff=2048, vocab_size=256,
        attention="local+routing" if routing_heads else "local",
        routing=RoutingConfig(num_clusters=6, window=window,
                              local_window=window,
                              routing_heads=routing_heads,
                              routing_layers=rl),
        attn_window=window, position="rope", norm="layernorm", act="relu",
        max_seq_len=3072)
