"""llama4-scout-17b-a16e [moe] — 16 experts, top-1, every layer MoE
(hf:meta-llama/Llama-4-Scout-17B-16E).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; shared expert +
top-1 routed expert per token (llama4 style).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", num_layers=48,
        d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
        vocab_size=202048, moe_experts=16, moe_top_k=1, moe_interleave=1,
        moe_shared_expert=True, attention="full", position="rope",
        norm="rmsnorm", act="swiglu", max_seq_len=131072)
