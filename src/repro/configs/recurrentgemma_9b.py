"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2
(arXiv:2402.19427, Griffin).

38L d_model=4096 16H (kv=1, head_dim 256) d_ff=12288 vocab=256000; block
pattern (rglru, rglru, local-attn), attention window 2048.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid", num_layers=38,
        d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
        d_ff=12288, vocab_size=256000, attention="local",
        hybrid_pattern=("rglru", "rglru", "attn"), attn_window=2048,
        lru_width=4096, position="rope", norm="rmsnorm", act="gelu",
        max_seq_len=1_048_576)
