"""hubert-xlarge [audio] — encoder-only masked prediction
(arXiv:2106.07447).

48L d_model=1280 16H d_ff=5120 vocab=504 (codebook targets). The conv
waveform frontend is a STUB per spec: input_specs() feeds precomputed
frame embeddings (B, S, d). Encoder => no decode shapes (skip noted).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder", num_layers=48, d_model=1280,
        num_heads=16, num_kv_heads=16, head_dim=80, d_ff=5120,
        vocab_size=504, attention="full", is_causal=False, position="none",
        norm="layernorm", act="gelu", mask_prob=0.08, max_seq_len=32768)
