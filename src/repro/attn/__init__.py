"""repro.attn — the unified attention-backend API.

One entry point for every attention site in the system::

    from repro import attn
    spec = attn.spec_for_layer(cfg, "local+routing")
    out = attn.attend(spec, q, k, v, state=kmu, positions=pos,
                      pad_mask=pm)                    # train / prefill
    out = attn.attend(spec, q, k, v, state=kmu, cache=cache,
                      pos=pos)                        # decode, one token

``attend`` resolves the best registered backend for the current platform
(Pallas kernels on TPU, chunked/online-softmax references elsewhere);
``impl=`` forces a specific backend and raises a loud
``BackendResolutionError`` when its declared capabilities don't cover
the call. The registry (``repro.attn.registry``) is where new variants
and backends plug in; every registered backend must pass the parity
matrix in tests/test_attn_registry.py. See DESIGN.md §8.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional

import jax

from repro.attn import backends as _backends           # noqa: F401 (registers)
from repro.attn import registry
from repro.attn.registry import (Backend, BackendResolutionError,  # noqa
                                 CacheLayout, Capabilities, backends_for,
                                 cache_head_axes, cache_reset_values,
                                 get, pageable_cache_leaves, registered,
                                 resolve, unregister)
from repro.attn.spec import (AttentionSpec, head_split,  # noqa: F401
                             resolve_chunk, seq_shardable, spec_for_layer,
                             specs_for_model, variant_for_layer)
from repro.kernels.common import default_interpret as _default_interpret


class AttnOutput(NamedTuple):
    out: jax.Array                  # (B, H, N, dh)
    state: Optional[jax.Array]      # updated centroids (routing variants)
    cache: Optional[dict] = None    # updated decode cache (decode calls)
    stats: Optional[object] = None  # obs.RoutingStats (routing variants
    #                                 with RoutingConfig.stats=True)


def _platform(platform: Optional[str]) -> str:
    """Resolution platform: explicit arg > REPRO_ATTN_PLATFORM env >
    detected backend. The env override (paired with
    REPRO_FORCE_INTERPRET=1, see kernels.common.default_interpret) lets
    tests exercise TPU auto-selection — fused apply, paged decode — end
    to end on a CPU host."""
    return (platform or os.environ.get("REPRO_ATTN_PLATFORM")
            or jax.default_backend())


def _grad_guard(out, name):
    """Identity in the forward; the backward raises the registry error.

    jax.grad can reach an attend call that never announced needs_grad
    (eval code reused inside a loss, a forced impl on the train path).
    Without this, differentiating a non-VJP Pallas backend dies deep in
    tracing with an opaque missing-transpose error; with it, the failure
    is a BackendResolutionError naming the backend and the fix.
    """
    @jax.custom_vjp
    def guard(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, g):
        raise BackendResolutionError(
            f"backend {name} is not differentiable (supports_grad=False);"
            f" jax.grad through attn.attend needs a supports_grad backend"
            f" — use impl='xla', a kernel with a custom VJP, or pass"
            f" needs_grad=True to resolve one automatically")

    guard.defvjp(fwd, bwd)
    return guard(out)


def attend(spec: AttentionSpec, q, k, v, *, state=None, positions=None,
           pad_mask=None, update_state: bool = True, cache=None, pos=None,
           mesh=None, impl: Optional[str] = None,
           needs_grad: bool = False,
           platform: Optional[str] = None) -> AttnOutput:
    """Run the attention ``spec`` describes on q/k/v (un-roped, GQA head
    counts), through the best registered backend.

    Train/prefill mode (``cache=None``): returns (out, new_state).
    Decode mode (``cache`` given): q/k/v are one token (N=1), ``pos``
    (B,) is its position; returns the updated cache. ``state`` carries
    the layer's k-means centroids for routing variants in both modes.
    ``needs_grad``: the caller will differentiate through ``out`` (train
    paths announce this); resolution then excludes — or, forced, loudly
    refuses — backends without a VJP. Even without the announcement, a
    non-differentiable backend's output is guarded so jax.grad raises a
    clear BackendResolutionError instead of an opaque tracing failure.
    """
    plat = _platform(platform)
    interpret = _default_interpret(None, plat)
    if cache is not None:
        if pad_mask is not None:
            # decode validity lives in the cache (ring positions, page
            # lengths); accepting a pad_mask here and ignoring it would be
            # exactly the silent-wrong-math failure the registry exists
            # to kill
            raise ValueError("attend(cache=...) is single-token decode; "
                             "pad_mask is not meaningful there (validity "
                             "is tracked inside the cache)")
        backend = resolve(spec, decode=True, mesh=mesh, impl=impl,
                          platform=plat)
        out, new_cache = backend.decode(spec, q, k, v, cache=cache, pos=pos,
                                        state=state, interpret=interpret)
        return AttnOutput(out=out, state=state, cache=new_cache)
    backend = resolve(spec, padded=pad_mask is not None,
                      positioned=positions is not None,
                      needs_grad=needs_grad, seq_len=q.shape[2],
                      mesh=mesh, impl=impl, platform=plat)
    res = backend.apply(spec, q, k, v, state=state,
                        positions=positions, pad_mask=pad_mask,
                        update_state=update_state,
                        interpret=interpret)
    # 2-tuple (out, new_state) or 3-tuple (out, new_state, stats):
    # routing backends surface the RoutingStats aux; everyone else
    # (including externally registered backends) stays on the 2-tuple
    out, new_state = res[0], res[1]
    stats = res[2] if len(res) > 2 else None
    if not backend.caps.supports_grad:
        out = _grad_guard(out, backend.name)
    return AttnOutput(out=out, state=new_state, stats=stats)


def decode_backend(spec: AttentionSpec, *, mesh=None,
                   impl: Optional[str] = None,
                   platform: Optional[str] = None) -> Backend:
    """The backend decode calls for ``spec`` will resolve to (the serve
    engine uses this to build cache layouts and for observability)."""
    return resolve(spec, decode=True, mesh=mesh, impl=impl,
                   platform=_platform(platform))


def init_decode_cache(spec: AttentionSpec, B: int, max_len: int, dtype, *,
                      mesh=None, impl: Optional[str] = None):
    """The cache-leaf dict declared by the resolved decode backend."""
    return decode_backend(spec, mesh=mesh, impl=impl).layout.init(
        spec, B, max_len, dtype)


def prefill_cache(spec: AttentionSpec, cache, q, k, v, *, positions,
                  state=None, mesh=None, impl: Optional[str] = None):
    """Fill the decode cache from prefix q/k/v, per the resolved decode
    backend's layout."""
    return decode_backend(spec, mesh=mesh, impl=impl).layout.fill(
        spec, cache, q, k, v, positions=positions, state=state)
