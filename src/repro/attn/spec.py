"""AttentionSpec — the declarative description of one attention site.

A spec says *what* attention a layer computes (variant, window/cluster
geometry, causality, GQA split, rope, logit scale, chunking); the registry
(`repro.attn.registry`) says *how* (which backend implements it on the
current platform). Everything a backend needs is on the spec — backends
never reach back into ``ModelConfig``.

Specs are frozen dataclasses registered as *static* pytrees: they hash,
compare by value, and pass through ``jax.jit`` closures/arguments without
contributing tracers. ``spec_for_layer(cfg, variant)`` is the single
place config fields are interpreted (and is cached, so a spec is built
once per (config, variant) pair).

Chunking contract (`chunk`): ``None`` = auto — the full-attention
reference picks an online-softmax KV chunk when the sequence is long
(N > 4096); ``0`` = force one-shot softmax; ``c > 0`` = force chunk c.
This is resolved at call time by ``resolve_chunk`` because the auto rule
depends on the runtime sequence length (an explicit 0 used to be
un-settable for long N when the config field doubled as the sentinel).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax

from repro.configs.base import ModelConfig, RoutingConfig, with_overrides

VARIANTS = ("full", "local", "routing", "local+routing")

# Non-routing layers of a routing_layers-suffix config fall back to the
# cheapest variant that preserves the paper's locality prior.
_DOWNGRADE = {"local+routing": "local", "routing": "local"}

AUTO_CHUNK_THRESHOLD = 4096
AUTO_CHUNK = 1024


@dataclass(frozen=True)
class AttentionSpec:
    """One attention site, fully described.

    variant        full | local | routing | local+routing
    num_heads      query heads H
    num_kv_heads   key/value heads (GQA; == H for MHA)
    head_dim       per-head dim
    causal         causal mask on original positions
    window         local-attention window (variants with a local part)
    rope_theta     rotary base, or None for no rope (routing heads are
                   never roped — routing vectors are content, not position)
    logit_scale    softmax scale override (None = 1/sqrt(head_dim))
    chunk          KV chunk for the full variant: None=auto, 0=one-shot
    routing        RoutingConfig (variants with a routing part), already
                   normalized against the model's causality
    routing_heads  Hr of the local+routing head split (0 elsewhere)
    """

    variant: str
    num_heads: int
    num_kv_heads: int
    head_dim: int
    causal: bool = True
    window: int = 0
    rope_theta: Optional[float] = None
    logit_scale: Optional[float] = None
    chunk: Optional[int] = None
    routing: Optional[RoutingConfig] = None
    routing_heads: int = 0

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown attention variant {self.variant!r}; "
                f"expected one of {VARIANTS}")
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(
                f"num_heads {self.num_heads} not divisible by num_kv_heads "
                f"{self.num_kv_heads}")
        if "local" in self.variant and self.window <= 0:
            raise ValueError(f"variant {self.variant!r} needs window > 0")
        if "routing" in self.variant and self.routing is None:
            raise ValueError(f"variant {self.variant!r} needs a "
                             f"RoutingConfig")
        if self.variant == "local+routing":
            head_split(self)    # raises on GQA-misaligned splits

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


jax.tree_util.register_static(AttentionSpec)


def head_split(spec) -> Tuple[int, int, int, int]:
    """(H_local, H_routing, Hkv_local, Hkv_routing) of a local+routing
    split. ``spec`` may be an AttentionSpec (its ``routing_heads`` field
    is authoritative when set) or a ModelConfig (Hr comes from
    ``routing.routing_heads``; 0 = half the heads)."""
    H, Hkv = spec.num_heads, spec.num_kv_heads
    g = H // Hkv
    rh = getattr(spec, "routing_heads", 0) or spec.routing.routing_heads
    Hr = min(rh or H // 2, H)
    Hl = H - Hr
    if Hkv == 1:
        return Hl, Hr, 1, 1
    if Hr % g or Hl % g:
        raise AssertionError(
            f"routing head split {Hl}/{Hr} must align with GQA groups "
            f"g={g}")
    return Hl, Hr, Hl // g, Hr // g


def variant_for_layer(cfg: ModelConfig, layer_idx: int) -> str:
    """The attention variant layer ``layer_idx`` runs: the config's
    variant on routing layers (or everywhere when routing_layers is
    empty), the downgraded variant elsewhere."""
    rl = set(cfg.routing.routing_layers)
    if not rl or layer_idx in rl:
        return cfg.attention
    return _DOWNGRADE.get(cfg.attention, cfg.attention)


def _normalized_routing(cfg: ModelConfig) -> RoutingConfig:
    rc = cfg.routing
    if rc.causal != cfg.is_causal:
        rc = with_overrides(rc, causal=cfg.is_causal)
    if not cfg.is_causal and rc.share_qk:
        rc = with_overrides(rc, share_qk=False)
    return rc


@functools.lru_cache(maxsize=None)
def spec_for_layer(cfg: ModelConfig, variant: str) -> AttentionSpec:
    """Build the (normalized) AttentionSpec a layer with attention mode
    ``variant`` runs under ``cfg``. Degenerate local+routing head splits
    collapse to the surviving variant here, so backends and cache layouts
    never see an empty head group."""
    rope = cfg.rope_theta if cfg.position == "rope" else None
    common = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                  head_dim=cfg.head_dim_, causal=cfg.is_causal,
                  rope_theta=rope, chunk=cfg.attn_chunk)
    if variant == "full":
        return AttentionSpec(variant="full", **common)
    if variant == "local":
        return AttentionSpec(variant="local", window=cfg.attn_window,
                             **common)
    rc = _normalized_routing(cfg)
    if variant == "routing":
        return AttentionSpec(variant="routing", routing=rc, **common)
    if variant == "local+routing":
        spec = AttentionSpec(variant="local+routing", routing=rc,
                             window=rc.local_window,
                             routing_heads=head_split(
                                 with_overrides(cfg, routing=rc))[1],
                             **common)
        Hl, Hr, _, _ = head_split(spec)
        if Hr == 0:         # Table-1 edge: no routing heads left
            return replace(spec, variant="local", routing=None,
                           routing_heads=0)
        if Hl == 0:         # all heads route
            return replace(spec, variant="routing", window=0,
                           routing_heads=0)
        return spec
    raise ValueError(f"unknown attention variant {variant!r}")


def specs_for_model(cfg: ModelConfig) -> Tuple[AttentionSpec, ...]:
    """The distinct AttentionSpecs appearing anywhere in the model's
    stack (consumed by dist.sharding.make_constrain_fn for layout
    validation)."""
    if cfg.family == "ssm":
        return ()
    out = []
    for i in range(cfg.num_layers):
        s = spec_for_layer(cfg, variant_for_layer(cfg, i))
        if s not in out:
            out.append(s)
    return tuple(out)


def resolve_chunk(spec: AttentionSpec, seq_len: int) -> int:
    """Runtime KV-chunk resolution: explicit values win (0 = one-shot),
    None auto-chunks long sequences."""
    if spec.chunk is not None:
        return spec.chunk
    return AUTO_CHUNK if seq_len > AUTO_CHUNK_THRESHOLD else 0


def seq_shardable(spec: AttentionSpec, tp: int) -> bool:
    """Whether sequence-sharding the residual stream over a ``tp``-way
    model axis is collective-free for this spec. full/local attention
    re-gather inside the attention op (XLA inserts the collectives);
    routing's balanced top-k is only shard-local when its segment fold
    aligns with the model axis (RoutingConfig.segments % tp == 0)."""
    if tp <= 1 or spec.routing is None:
        return True
    return spec.routing.segments % tp == 0
