"""The built-in attention backends: XLA references + Pallas kernels.

Registered pairs (variant, impl):

  full/xla           dense or online-softmax chunked reference
                     (core.attention), append-cache decode
  full/pallas        flash-attention kernel (kernels.flash_attention)
  local/xla          blocked sliding-window reference (core.local),
                     ring-cache decode
  local/pallas       blocked local kernel (kernels.local_attention)
  routing/xla        Algorithm-1 reference (core.routing),
                     cluster-paged decode
  routing/pallas     gathered-block attention on the Pallas kernel
                     (core.routing impl="pallas")
  routing/pallas_fused   gather-free fused kernel: sequence-layout q/k/v,
                     membership via scalar prefetch — no (B,H,k,w,dh)
                     q/k/v intermediates in HBM (DESIGN.md §9); preferred
                     over routing/pallas on TPU (priority 20 vs 10). The
                     kernel's memory plan auto-switches past the VMEM
                     residency budget to double-buffered per-row DMA
                     paging, so there is no seq-length registration cliff
  routing/pallas_fused_paged / _unpaged   forced memory plans of the same
                     kernel (priority 0 — explicit ``impl=`` only); the
                     unpaged one keeps the old ``max_seq_elems`` cap
                     because whole-plane residency genuinely overflows
                     VMEM past it
  routing/pallas_paged   fused apply + the paged-decode kernel
                     (kernels.routing_decode): single-token decode DMAs
                     only the selected cluster page into VMEM via
                     scalar-prefetched page tables — decode is gather-
                     free too, and resolves here on TPU (priority 20)
  local+routing/xla      paper head split, both halves reference
  local+routing/pallas   local half on the Pallas window kernel, routing
                     blocks on the gathered Pallas kernel
  local+routing/pallas_fused  both halves Pallas: window kernel + fused
                     routing (plus the forced _paged/_unpaged variants)
  local+routing/pallas_paged  fused apply; decode = ring-local reference
                     + paged routing kernel

Every Pallas backend is differentiable (the kernels carry flash-style
custom VJPs), so ``impl="pallas"``/``"pallas_fused"`` are legal on the
train path. Decode: the routing variants resolve to ``pallas_paged`` on
TPU — token- and cache-trajectory bit-parity with the xla cluster-paged
reference (the kernel shares the reference's routing + cache-write code
and mirrors its attention op sequence; per-step outputs agree to float
ulps, see kernels.routing_decode); full/local decode stays on the xla
append/ring references (already gather-free).

Rope is applied *here*, per variant: full/local heads are roped, routing
heads are not (their routing vectors and shared-QK attention keys are
content, and the paper's causal mask runs on original positions), and
the local+routing split ropes only its local half. Callers hand in raw
(un-roped) q/k/v plus positions.

Every backend with a decode path also owns its cache layout as a typed
``CacheLayout`` object: how the leaf dict is built (``init``), how
prefill fills it (``fill``), which leaf axes carry heads (sharding
hints), per-leaf reset fill values, and which leaves are cluster-paged
(``pageable_leaves`` + ``page_len_leaf``, consumed by the tiered KV
store for per-page compaction). The slot-pooled serving engine and the
KV store consume all of it through the registry.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.attn import registry
from repro.attn.registry import Backend, CacheLayout, Capabilities
from repro.attn.spec import AttentionSpec, head_split, resolve_chunk
from repro.core.attention import full_attention
from repro.core.kmeans import KMeansState, normalize_routing
from repro.core.local import local_attention
from repro.core.routing import routed_attention
from repro.kernels.common import FUSED_RESIDENT_ELEMS
from repro.models import layers as L

_BIG_NEG = -1e9


# ---------------------------------------------------------------------------
# Shared glue
# ---------------------------------------------------------------------------
def _rope_qk(spec: AttentionSpec, q, k, positions):
    """Rope q (and k when given) at ``positions`` (default arange)."""
    if spec.rope_theta is None:
        return q, k
    B, _, N, _ = q.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))
    q = L.apply_rope(q, positions, spec.rope_theta)
    if k is not None:
        k = L.apply_rope(k, positions, spec.rope_theta)
    return q, k


def _expand_kv(x: jax.Array, reps: int) -> jax.Array:
    return jnp.repeat(x, reps, axis=1) if reps > 1 else x


def _split_heads(spec: AttentionSpec, q, k, v):
    """Slice q/k/v into the (local, routing) halves of a local+routing
    split, mirroring the paper's layout: local heads first."""
    Hl, Hr, kvl, kvr = head_split(spec)
    if spec.num_kv_heads == 1:
        kl = kr = k
        vl = vr = v
    else:
        kl, kr = (None, None) if k is None else (k[:, :kvl], k[:, kvl:])
        vl, vr = v[:, :kvl], v[:, kvl:]
    return (q[:, :Hl], kl, vl), (q[:, Hl:], kr, vr)


def _local_subspec(spec: AttentionSpec) -> AttentionSpec:
    Hl, _, kvl, _ = head_split(spec)
    return replace(spec, variant="local", num_heads=Hl, num_kv_heads=kvl,
                   routing=None, routing_heads=0)


def _routing_subspec(spec: AttentionSpec) -> AttentionSpec:
    _, Hr, _, kvr = head_split(spec)
    return replace(spec, variant="routing", num_heads=Hr, num_kv_heads=kvr,
                   window=0, routing_heads=0)


# ---------------------------------------------------------------------------
# Apply (train / prefill) paths
# ---------------------------------------------------------------------------
def _full_xla_apply(spec, q, k, v, *, state=None, positions=None,
                    pad_mask=None, update_state=True, interpret=None):
    qr, kr = _rope_qk(spec, q, k, positions)
    o = full_attention(qr, kr, v, spec.causal, pad_mask,
                       positions=positions,
                       chunk=resolve_chunk(spec, q.shape[2]),
                       logit_scale=spec.logit_scale)
    return o, state


def _block_size(n: int, pref: int = 128) -> int:
    """Largest kernel block <= pref that divides n (fall back to n)."""
    for b in (pref, pref // 2, pref // 4):
        if b and n % b == 0:
            return b
    return n


def _full_pallas_apply(spec, q, k, v, *, state=None, positions=None,
                       pad_mask=None, update_state=True, interpret=None):
    from repro.kernels import ops as kops
    qr, kr = _rope_qk(spec, q, k, positions)
    o = kops.flash_attention(qr, kr, v, causal=spec.causal,
                             bq=_block_size(q.shape[2]),
                             bk=_block_size(k.shape[2]),
                             interpret=interpret)
    return o, state


def _local_xla_apply(spec, q, k, v, *, state=None, positions=None,
                     pad_mask=None, update_state=True, interpret=None):
    qr, kr = _rope_qk(spec, q, k, positions)
    o = local_attention(qr, kr, v, spec.window, spec.causal, pad_mask)
    return o, state


def _local_pallas_apply(spec, q, k, v, *, state=None, positions=None,
                        pad_mask=None, update_state=True, interpret=None):
    from repro.kernels import ops as kops
    qr, kr = _rope_qk(spec, q, k, positions)
    o = kops.local_attention(qr, kr, v, window=min(spec.window, q.shape[2]),
                             causal=spec.causal, interpret=interpret)
    return o, state


def _make_routing_apply(kernel_impl: str):
    def apply(spec, q, k, v, *, state=None, positions=None, pad_mask=None,
              update_state=True, interpret=None):
        rc = spec.routing
        g = spec.q_per_kv
        v_e = _expand_kv(v, g)
        k_in = (None if (rc.share_qk and spec.causal) or k is None
                else _expand_kv(k, g))
        ro = routed_attention(q, k_in, v_e, KMeansState(mu=state), rc,
                              positions, pad_mask, update_state,
                              impl=kernel_impl, interpret=interpret)
        # 3-tuple: routing backends also surface the RoutingStats aux
        # (None unless rc.stats); attend() tolerates 2- and 3-tuples
        return ro.out, ro.state.mu, ro.stats
    return apply


def _make_mixed_apply(kernel_impl: str, local_kernel: bool = False):
    """Composite apply for the local+routing head split.

    ``local_kernel=True`` (every Pallas-family registration) runs the
    local half on the Pallas window kernel — which carries its own
    flash-style custom VJP, so the composite gradient is kernel-backed
    end to end instead of mixing a fused routing grad with the XLA-
    reference local grad. The window kernel's affine BlockSpec pipeline
    already double-buffers its (w, dh) tiles, so its VMEM footprint is
    bounded by the window, never by N — it needs no manual paging. The
    reference serves the cases the kernel does not express (pad_mask,
    N not a multiple of the window)."""
    routing_apply = _make_routing_apply(kernel_impl)

    def apply(spec, q, k, v, *, state=None, positions=None, pad_mask=None,
              update_state=True, interpret=None):
        (ql, kl, vl), (qr, kr, vr) = _split_heads(spec, q, k, v)
        lspec = _local_subspec(spec)
        N = q.shape[2]
        use_kernel = (local_kernel and pad_mask is None
                      and N % min(lspec.window, N) == 0)
        local_fn = _local_pallas_apply if use_kernel else _local_xla_apply
        o_l, _ = local_fn(
            lspec, ql, kl, vl, positions=positions,
            pad_mask=pad_mask, interpret=interpret)
        o_r, new_mu, stats = routing_apply(
            _routing_subspec(spec), qr, kr, vr, state=state,
            positions=positions, pad_mask=pad_mask,
            update_state=update_state, interpret=interpret)
        return jnp.concatenate([o_l, o_r], axis=1), new_mu, stats
    return apply


# ---------------------------------------------------------------------------
# Decode paths + cache layouts
# ---------------------------------------------------------------------------
def _append_cache(spec, B, max_len, dtype):
    dh, Hkv = spec.head_dim, spec.num_kv_heads
    return {"k": jnp.zeros((B, Hkv, max_len, dh), dtype),
            "v": jnp.zeros((B, Hkv, max_len, dh), dtype)}


def _ring_cache(spec, B, max_len, dtype):
    dh = spec.head_dim
    kvl = (head_split(spec)[2] if spec.variant == "local+routing"
           else spec.num_kv_heads)
    W = spec.window
    return {"lk": jnp.zeros((B, kvl, 2 * W, dh), dtype),
            "lv": jnp.zeros((B, kvl, 2 * W, dh), dtype),
            "lpos": jnp.full((B, 2 * W), -1, jnp.int32)}


def _page_dims(spec, max_len):
    kc = spec.routing.num_clusters
    cap = spec.routing.window or max(1, max_len // kc)
    return kc, cap


def _pages_cache(spec, B, max_len, dtype):
    dh = spec.head_dim
    Hr = (head_split(spec)[1] if spec.variant == "local+routing"
          else spec.num_heads)
    kc, cap = _page_dims(spec, max_len)
    return {"rk": jnp.zeros((B, Hr, kc, cap, dh), dtype),
            "rv": jnp.zeros((B, Hr, kc, cap, dh), dtype),
            "rlen": jnp.zeros((B, Hr, kc), jnp.int32)}


def _mixed_cache(spec, B, max_len, dtype):
    return {**_ring_cache(spec, B, max_len, dtype),
            **_pages_cache(spec, B, max_len, dtype)}


def _full_decode(spec, q, k, v, *, cache, pos, state=None, interpret=None):
    """Append k/v at ``pos`` and attend the whole cache, causal on
    original positions (the N=1-query-vs-long-cache path)."""
    qr, kr = _rope_qk(spec, q, k, pos[:, None])
    B, Hkv = kr.shape[0], kr.shape[1]
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(Hkv)[None, :]
    ck = cache["k"].at[bi, hi, pos[:, None]].set(
        kr[:, :, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bi, hi, pos[:, None]].set(
        v[:, :, 0].astype(cache["v"].dtype))
    o = full_attention(qr, ck, cv, causal=True, positions=pos[:, None],
                       logit_scale=spec.logit_scale)
    return o, {**cache, "k": ck, "v": cv}


def _local_decode(spec, q, k, v, *, cache, pos, state=None, interpret=None):
    """Blocked-local decode over the 2W ring: attend keys whose stored
    absolute position lies in blocks b-1, b of the query position."""
    qr, kr = _rope_qk(spec, q, k, pos[:, None])
    window = spec.window
    B, Hkv = kr.shape[0], kr.shape[1]
    S2 = cache["lk"].shape[2]
    slot = pos % S2
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(Hkv)[None, :]
    ck = cache["lk"].at[bi, hi, slot[:, None]].set(
        kr[:, :, 0].astype(cache["lk"].dtype))
    cv = cache["lv"].at[bi, hi, slot[:, None]].set(
        v[:, :, 0].astype(cache["lv"].dtype))
    cp = cache["lpos"].at[jnp.arange(B), slot].set(pos)
    lo = (pos // window - 1) * window      # start of block b-1
    valid = (cp >= jnp.maximum(lo, 0)[:, None]) & (cp >= 0) & \
            (cp <= pos[:, None])
    o = full_attention(qr, ck, cv, causal=False, pad_mask=valid,
                       logit_scale=spec.logit_scale)
    return o, {**cache, "lk": ck, "lv": cv, "lpos": cp}


def _route_token(q, mu, cache):
    """Stage 1 of cluster-paged decode, shared verbatim by the xla and
    pallas_paged paths (so their cache trajectories are identical by
    construction): normalize the token's routing vector, argmax it
    against the centroids, read the selected page's write counter."""
    r = normalize_routing(q)[:, :, 0]      # (B,Hr,dh)
    scores = jnp.einsum("bhd,hkd->bhk", r.astype(jnp.float32),
                        mu.astype(jnp.float32))
    c = jnp.argmax(scores, axis=-1)        # (B,Hr)
    plen = jnp.take_along_axis(cache["rlen"], c[:, :, None], axis=2)[..., 0]
    return r, c, plen


def _write_page_slot(cache, r, v0, c, plen):
    """Ring-overwrite the new token into slot plen % cap of page c —
    the one cache write of a decode step, shared by both paths."""
    B, Hr = c.shape
    cap = cache["rk"].shape[3]
    wslot = plen % cap
    bi = jnp.arange(B)[:, None]
    hi = jnp.arange(Hr)[None, :]
    ck = cache["rk"].at[bi, hi, c, wslot].set(r.astype(cache["rk"].dtype))
    cv = cache["rv"].at[bi, hi, c, wslot].set(v0.astype(cache["rv"].dtype))
    cl = cache["rlen"].at[bi, hi, c].set(plen + 1)
    return {**cache, "rk": ck, "rv": cv, "rlen": cl}


def _routing_decode(spec, q, k, v, *, cache, pos, state=None,
                    interpret=None):
    """Cluster-paged routing decode: the token routes to its argmax
    centroid and attends only that page (+ itself). ``state`` is the
    layer's centroid tree mu (Hr, kc, dh); q/v arrive un-roped with Hkv
    heads and are expanded to the routing head count here."""
    mu = state
    v = _expand_kv(v, spec.q_per_kv)
    _, _, _, dh = q.shape
    cap = cache["rk"].shape[3]
    r, c, plen = _route_token(q, mu, cache)
    sel = c[:, :, None, None, None]
    page_k = jnp.take_along_axis(cache["rk"], sel, axis=2)[:, :, 0]
    page_v = jnp.take_along_axis(cache["rv"], sel, axis=2)[:, :, 0]
    nvalid = jnp.minimum(plen, cap)        # (B,Hr)
    logits = jnp.einsum("bhd,bhcd->bhc", r, page_k).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh)
    slot_ok = jnp.arange(cap)[None, None, :] < nvalid[..., None]
    logits = jnp.where(slot_ok, logits, _BIG_NEG)
    self_logit = (jnp.einsum("bhd,bhd->bh", r, r) /
                  jnp.sqrt(dh)).astype(jnp.float32)
    all_logits = jnp.concatenate([logits, self_logit[..., None]], -1)
    attn = jax.nn.softmax(all_logits, axis=-1)
    vals = jnp.concatenate([page_v, v[:, :, 0][:, :, None, :]], 2)
    o = jnp.einsum("bhc,bhcd->bhd", attn.astype(vals.dtype), vals)
    new_cache = _write_page_slot(cache, r, v[:, :, 0], c, plen)
    return o[:, :, None, :], new_cache


def _routing_decode_paged(spec, q, k, v, *, cache, pos, state=None,
                          interpret=None):
    """Paged-kernel routing decode: stage 1 and the ring-slot write are
    the exact XLA code the reference runs; the page attention itself is
    the Pallas kernel, which DMAs only the selected cluster page into
    VMEM through scalar-prefetched page tables (kernels.routing_decode)
    instead of materializing a gathered page copy in HBM."""
    from repro.kernels.routing_decode import paged_routing_decode
    mu = state
    v = _expand_kv(v, spec.q_per_kv)
    r, c, plen = _route_token(q, mu, cache)
    o = paged_routing_decode(r, v[:, :, 0], cache["rk"], cache["rv"],
                             cache["rlen"], c, interpret=interpret)
    new_cache = _write_page_slot(cache, r, v[:, :, 0], c, plen)
    return o[:, :, None, :], new_cache


def _make_mixed_decode(routing_decode):
    """local+routing decode: ring-local reference half + the given
    routing decode fn (xla reference or the paged kernel)."""
    def decode(spec, q, k, v, *, cache, pos, state=None, interpret=None):
        (ql, kl, vl), (qr, _, vr) = _split_heads(spec, q, k, v)
        ring = {n: cache[n] for n in ("lk", "lv", "lpos")}
        o_l, ring = _local_decode(_local_subspec(spec), ql, kl, vl,
                                  cache=ring, pos=pos, interpret=interpret)
        pages = {n: cache[n] for n in ("rk", "rv", "rlen")}
        o_r, pages = routing_decode(_routing_subspec(spec), qr, None, vr,
                                    cache=pages, pos=pos, state=state,
                                    interpret=interpret)
        return jnp.concatenate([o_l, o_r], axis=1), {**ring, **pages}
    return decode


_mixed_decode = _make_mixed_decode(_routing_decode)
_mixed_decode_paged = _make_mixed_decode(_routing_decode_paged)


# ---------------------------------------------------------------------------
# Prefill cache fill
# ---------------------------------------------------------------------------
def _append_fill(spec, cache, q, k, v, *, positions, state=None):
    _, kr = _rope_qk(spec, q, k, positions)
    out = dict(cache)
    out["k"] = jax.lax.dynamic_update_slice(
        cache["k"], kr.astype(cache["k"].dtype), (0, 0, 0, 0))
    out["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    return out


def _ring_fill(spec, cache, q, k, v, *, positions, state=None):
    """Place token t at ring slot t % 2W; keep the last 2W tokens."""
    B, N = positions.shape
    _, kr = _rope_qk(spec, q, k, positions)
    S2 = cache["lk"].shape[2]
    take = min(N, S2)
    tail_k = kr[:, :, -take:]
    tail_v = v[:, :, -take:]
    tail_pos = positions[:, -take:]
    slots = tail_pos % S2                                  # (B,take)
    bi = jnp.arange(B)[:, None, None]
    hi = jnp.arange(tail_k.shape[1])[None, :, None]
    si = slots[:, None, :]
    out = dict(cache)
    out["lk"] = cache["lk"].at[bi, hi, si].set(
        tail_k.astype(cache["lk"].dtype))
    out["lv"] = cache["lv"].at[bi, hi, si].set(
        tail_v.astype(cache["lv"].dtype))
    out["lpos"] = cache["lpos"].at[jnp.arange(B)[:, None], slots].set(
        tail_pos)
    return out


def _pages_fill(spec, cache, q, k, v, *, positions, state=None):
    """Route every prefix token to its argmax page, keeping the most
    recent ``cap`` per page at the ring slots sequential decode would
    have used (ring continuity)."""
    B = q.shape[0]
    vr = _expand_kv(v, spec.q_per_kv)
    r = normalize_routing(q)                               # (B,Hr,N,dh)
    kc, cap = cache["rk"].shape[2], cache["rk"].shape[3]
    Hr = r.shape[1]
    scores = jnp.einsum("bhnd,hkd->bhnk", r.astype(jnp.float32),
                        state.astype(jnp.float32))
    assign = jnp.argmax(scores, -1)                        # (B,Hr,N)
    memb = jax.nn.one_hot(assign, kc, dtype=jnp.int32)     # (B,Hr,N,kc)
    rank_from_end = jnp.cumsum(memb[:, :, ::-1], axis=2)[:, :, ::-1]
    rank_from_end = (rank_from_end * memb).max(-1)         # (B,Hr,N) 1-based
    keep = (rank_from_end >= 1) & (rank_from_end <= cap)
    counts = memb.sum(2)                                   # (B,Hr,kc)
    write_slot = jnp.where(
        keep,
        (jnp.take_along_axis(counts, assign, axis=2) % cap
         - rank_from_end) % cap,
        cap)                                               # cap = trash
    bi = jnp.arange(B)[:, None, None]
    hi = jnp.arange(Hr)[None, :, None]
    rk_pad = jnp.concatenate(
        [cache["rk"], jnp.zeros_like(cache["rk"][:, :, :, :1])], 3)
    rv_pad = jnp.concatenate(
        [cache["rv"], jnp.zeros_like(cache["rv"][:, :, :, :1])], 3)
    rk_pad = rk_pad.at[bi, hi, assign, write_slot].set(
        r.astype(rk_pad.dtype))
    rv_pad = rv_pad.at[bi, hi, assign, write_slot].set(
        vr.astype(rv_pad.dtype))
    out = dict(cache)
    out["rk"] = rk_pad[:, :, :, :cap]
    out["rv"] = rv_pad[:, :, :, :cap]
    out["rlen"] = counts
    return out


def _mixed_fill(spec, cache, q, k, v, *, positions, state=None):
    (ql, kl, vl), (qr, _, vr) = _split_heads(spec, q, k, v)
    out = _ring_fill(_local_subspec(spec), cache, ql, kl, vl,
                     positions=positions)
    out = _pages_fill(_routing_subspec(spec), out, qr, None, vr,
                      positions=positions, state=state)
    return out


# ---------------------------------------------------------------------------
# Registration
# ---------------------------------------------------------------------------
_RING_FILLS = {"lpos": -1}
_RING_AXES = {"lk": 2, "lv": 2}
_PAGE_AXES = {"rk": 2, "rv": 2, "rlen": 2}
_PAGE_LEAVES = ("rk", "rv")

APPEND_LAYOUT = CacheLayout(
    name="append", init=_append_cache, fill=_append_fill,
    head_axes={"k": 2, "v": 2})

RING_LAYOUT = CacheLayout(
    name="ring", init=_ring_cache, fill=_ring_fill,
    reset_values=_RING_FILLS, head_axes=_RING_AXES)

PAGES_LAYOUT = CacheLayout(
    name="pages", init=_pages_cache, fill=_pages_fill,
    head_axes=_PAGE_AXES, pageable_leaves=_PAGE_LEAVES,
    page_len_leaf="rlen")

MIXED_LAYOUT = CacheLayout(
    name="ring+pages", init=_mixed_cache, fill=_mixed_fill,
    reset_values=_RING_FILLS, head_axes={**_RING_AXES, **_PAGE_AXES},
    pageable_leaves=_PAGE_LEAVES, page_len_leaf="rlen")

registry.register(Backend(
    variant="full", impl="xla", apply=_full_xla_apply,
    decode=_full_decode, layout=APPEND_LAYOUT,
    caps=Capabilities(supports_decode=True, supports_mesh=True,
                      supports_pad_mask=True, supports_logit_scale=True,
                      supports_grad=True)))

# supports_positions=False: the flash kernel masks causality by row
# index — the positions-aware reference must serve packed/offset calls
registry.register(Backend(
    variant="full", impl="pallas", apply=_full_pallas_apply, priority=10,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=False, supports_positions=False,
                      supports_grad=True, needs_tpu=True)))

registry.register(Backend(
    variant="local", impl="xla", apply=_local_xla_apply,
    decode=_local_decode, layout=RING_LAYOUT,
    caps=Capabilities(supports_decode=True, supports_mesh=True,
                      supports_pad_mask=True, supports_grad=True)))

registry.register(Backend(
    variant="local", impl="pallas", apply=_local_pallas_apply, priority=10,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=False, supports_grad=True,
                      needs_tpu=True)))

registry.register(Backend(
    variant="routing", impl="xla", apply=_make_routing_apply("xla"),
    decode=_routing_decode, layout=PAGES_LAYOUT,
    caps=Capabilities(supports_decode=True, supports_mesh=True,
                      supports_pad_mask=True, supports_grad=True)))

registry.register(Backend(
    variant="routing", impl="pallas", apply=_make_routing_apply("pallas"),
    priority=10,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))

# gather-free fused kernel: highest priority, so TPU auto-selection takes
# it over the gathered pallas path; supports_grad via its custom VJP.
# supports_mesh=False like every Pallas backend: a GSPMD mesh call falls
# back to the reference; the shard_map train path (per-device programs,
# no mesh at attend) runs the kernel in distributed training (§9).
# No max_seq_elems cap: the kernel auto-switches its memory plan at the
# VMEM residency budget (kernels.common.FUSED_RESIDENT_ELEMS, N·dh =
# 8192·128) — whole-plane VMEM residency below it, double-buffered
# per-row DMA paging above (VMEM bounded by the tile sizes, not N), so
# paper-scale N=8k–32k stays fused forward and backward.
registry.register(Backend(
    variant="routing", impl="pallas_fused",
    apply=_make_routing_apply("pallas_fused"), priority=20,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))

# forced memory plans of the fused kernel, priority 0: never auto-chosen
# (tie with xla resolves to the earlier registration), reachable with an
# explicit impl= — the parity matrix and benches exercise both plans this
# way. Only the unpaged one still carries the residency cap: whole-plane
# VMEM residency genuinely overflows past it, and resolve() now names
# the fallback in the forced-impl error instead of stranding the caller.
registry.register(Backend(
    variant="routing", impl="pallas_fused_paged",
    apply=_make_routing_apply("pallas_fused_paged"), priority=0,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))

registry.register(Backend(
    variant="routing", impl="pallas_fused_unpaged",
    apply=_make_routing_apply("pallas_fused_unpaged"), priority=0,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True,
                      max_seq_elems=FUSED_RESIDENT_ELEMS)))

registry.register(Backend(
    variant="local+routing", impl="xla", apply=_make_mixed_apply("xla"),
    decode=_mixed_decode, layout=MIXED_LAYOUT,
    caps=Capabilities(supports_decode=True, supports_mesh=True,
                      supports_pad_mask=True, supports_grad=True)))

registry.register(Backend(
    variant="local+routing", impl="pallas",
    apply=_make_mixed_apply("pallas", local_kernel=True), priority=10,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))

registry.register(Backend(
    variant="local+routing", impl="pallas_fused",
    apply=_make_mixed_apply("pallas_fused", local_kernel=True),
    priority=20,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))

registry.register(Backend(
    variant="local+routing", impl="pallas_fused_paged",
    apply=_make_mixed_apply("pallas_fused_paged", local_kernel=True),
    priority=0,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))

registry.register(Backend(
    variant="local+routing", impl="pallas_fused_unpaged",
    apply=_make_mixed_apply("pallas_fused_unpaged", local_kernel=True),
    priority=0,
    caps=Capabilities(supports_decode=False, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True,
                      max_seq_elems=FUSED_RESIDENT_ELEMS)))

# paged decode: fused apply plus the paged-decode kernel, so the serving
# hot path is Pallas too. Registered AFTER pallas_fused at the same
# priority 20 on purpose: resolve() keeps the first max on a tie, so
# apply calls still pick pallas_fused while decode (where fused declares
# supports_decode=False) lands here instead of the priority-0 xla
# reference. Shares the cluster-page layouts with xla — engines can
# prefill under one impl and decode under the other, and decode under a
# GSPMD mesh falls back to the reference like every Pallas backend.
registry.register(Backend(
    variant="routing", impl="pallas_paged",
    apply=_make_routing_apply("pallas_fused"),
    decode=_routing_decode_paged, layout=PAGES_LAYOUT, priority=20,
    caps=Capabilities(supports_decode=True, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))

registry.register(Backend(
    variant="local+routing", impl="pallas_paged",
    apply=_make_mixed_apply("pallas_fused", local_kernel=True),
    decode=_mixed_decode_paged, layout=MIXED_LAYOUT, priority=20,
    caps=Capabilities(supports_decode=True, supports_mesh=False,
                      supports_pad_mask=True, supports_grad=True,
                      needs_tpu=True)))
