"""Attention backend registry: (variant, impl) -> Backend + capabilities.

A ``Backend`` bundles everything one implementation of one variant can
do: the train/prefill ``apply`` math, optionally a single-token
``decode`` against the typed ``CacheLayout`` it declares (cache init,
prefill fill, reset fill values, head-axis sharding hints, pageable
page structure), and a ``Capabilities`` record the resolver filters on.

Resolution order (``resolve``): among the backends registered for the
spec's variant, drop those whose capabilities don't cover the call
(decode needed, pad_mask present, mesh > 1 device, sequence too long,
TPU-only backend off-TPU), then take the highest ``priority``. Pallas
kernels register with priority 10 and ``needs_tpu=True``: auto-selection
prefers them on TPU and never picks them elsewhere, while an explicit
``impl="pallas"`` still runs anywhere via interpret mode (that is what
the CPU kernel-parity CI lane exercises). Every *other* capability
mismatch on an explicit ``impl=`` override is a loud
``BackendResolutionError`` — a forced backend silently computing the
wrong thing (ignoring padding, lacking a decode path) is the failure
mode this registry exists to kill; the error also names the backend
auto-selection would have used, so the caller knows the escape hatch.

Auto-selection that skips a higher-priority backend *purely on sequence
capacity* (``max_seq`` / ``max_seq_elems``) is not silent either: each
occurrence increments the obs ``attn/fallback`` counter and the first
occurrence per (excluded, chosen) pair emits a RuntimeWarning — a call
landing on a slower path at scale leaves a signal.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro.attn.spec import AttentionSpec


class BackendResolutionError(ValueError):
    """No registered backend satisfies the call (or a forced one can't)."""


@dataclass(frozen=True)
class CacheLayout:
    """Typed decode-cache layout owned by a backend — the one object that
    answers every layout question the serving stack used to scatter
    across free functions (``serving.cache_reset_value``,
    ``registry.cache_fill_values``, ``cache_head_axes``).

    ``init(spec, B, max_len, dtype)``   build the cache-leaf dict
    ``fill(spec, cache, q, k, v, *, positions, state)``
                                        fill it from prefix q/k/v
    ``reset_values``   leaf name -> init/reset fill value (default 0);
                       ``fill_values`` is a compat alias
    ``head_axes``      leaf name -> axis carrying the head dim in POOL
                       coords (leaves are (G, B, head, ...) once stacked
                       over scan groups) — dist.sharding.cache_sharding
                       consumes the merged map
    ``pageable_leaves`` leaf names laid out as cluster pages
                       (B, H, kc, cap, ...) whose occupied prefix per
                       page is ``min(page_len_leaf, cap)`` — the tiered
                       KV store transfers/evicts these at per-page
                       granularity instead of whole-lane blobs
    ``page_len_leaf``  the (B, H, kc) int leaf counting writes per page
    ``lane_bytes``     bytes of one B=1 lane at (spec, max_len, dtype) —
                       abstract-eval'd, nothing is allocated
    """

    name: str
    init: Optional[Callable] = None
    fill: Optional[Callable] = None
    reset_values: Mapping[str, int] = field(default_factory=dict)
    head_axes: Mapping[str, int] = field(default_factory=dict)
    pageable_leaves: Tuple[str, ...] = ()
    page_len_leaf: str = ""

    @property
    def fill_values(self) -> Mapping[str, int]:
        return self.reset_values

    def reset_value(self, leaf_name: str) -> int:
        return self.reset_values.get(leaf_name, 0)

    def lane_bytes(self, spec: AttentionSpec, max_len: int, dtype) -> int:
        import jax
        import jax.numpy as jnp
        import numpy as np
        dt = jnp.dtype(dtype)
        shapes = jax.eval_shape(lambda: self.init(spec, 1, max_len, dt))
        return int(sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree_util.tree_leaves(shapes)))


@dataclass(frozen=True)
class Capabilities:
    """What a backend can serve. ``needs_tpu`` gates auto-selection only;
    every other flag is enforced for forced ``impl=`` overrides too.

    ``supports_positions``: the causal mask honors caller-supplied
    (non-arange) positions — kernels that mask by row/block index must
    declare False so packed-sequence calls fall back to (or loudly
    refuse into) the positions-aware reference instead of silently
    attending across the wrong boundary.
    ``supports_logit_scale``: the backend honors
    ``AttentionSpec.logit_scale``; backends with a baked 1/sqrt(dh)
    scale declare False and are excluded for specs that override it.
    ``supports_grad``: the apply path is differentiable (XLA math, or a
    kernel with a custom VJP). Deliberately defaults to False — a new
    kernel backend must *claim* differentiability (and then pass the
    grad leg of the registry parity matrix), it cannot inherit it.
    Backends at False are excluded from calls that announce
    ``needs_grad`` and — because jax.grad can reach a call that didn't
    announce it — their outputs are wrapped in a guard whose backward
    raises this registry's error instead of an opaque Pallas trace
    failure (see ``attn.attend``).
    ``max_seq_elems``: cap on seq_len · head_dim — for kernels whose
    working set scales with the (N, dh) plane (the fused routing kernel
    keeps q/k/v sequence planes VMEM-resident), where a seq-only cap
    would be wrong for wide heads.
    """

    supports_decode: bool = False
    supports_mesh: bool = True
    supports_pad_mask: bool = True
    supports_positions: bool = True
    supports_logit_scale: bool = False
    supports_grad: bool = False
    needs_tpu: bool = False
    max_seq: Optional[int] = None
    max_seq_elems: Optional[int] = None
    # DEPRECATED (one-release shim): the stringly-typed layout tag.
    # The typed ``Backend.layout`` (a CacheLayout) is authoritative;
    # ``register`` mirrors ``layout.name`` into this field so external
    # readers of the old string keep working for one release. Do not
    # read it in new code — use ``backend.layout``.
    cache_layout: str = ""


@dataclass(frozen=True)
class Backend:
    """One (variant, impl) implementation.

    apply(spec, q, k, v, *, state, positions, pad_mask, update_state,
          interpret) -> (out, new_state)
          or (out, new_state, stats): routing backends return a third
          element — the obs.RoutingStats aux pytree (None unless
          RoutingConfig.stats) — and attend() accepts either arity, so
          existing 2-tuple backends keep working unchanged
    decode(spec, q, k, v, *, cache, pos, state, interpret)
          -> (out, new_cache)                      [supports_decode only]
    layout: the backend's typed CacheLayout — cache init, prefill fill,
          reset fill values, head-axis sharding hints, pageable-page
          structure, and lane-byte accounting, all in one object
          (decode-capable backends must declare one).
    """

    variant: str
    impl: str
    apply: Callable
    caps: Capabilities
    decode: Optional[Callable] = None
    layout: Optional[CacheLayout] = None
    priority: int = 0

    @property
    def key(self) -> Tuple[str, str]:
        return (self.variant, self.impl)

    @property
    def name(self) -> str:
        return f"{self.variant}/{self.impl}"

    # -- deprecated accessors (pre-CacheLayout spelling) -------------------
    @property
    def init_cache(self) -> Optional[Callable]:
        """DEPRECATED: use ``backend.layout.init``."""
        return self.layout.init if self.layout is not None else None

    @property
    def prefill_fill(self) -> Optional[Callable]:
        """DEPRECATED: use ``backend.layout.fill``."""
        return self.layout.fill if self.layout is not None else None

    @property
    def cache_head_axes(self) -> Mapping[str, int]:
        """DEPRECATED: use ``backend.layout.head_axes``."""
        return self.layout.head_axes if self.layout is not None else {}

    @property
    def cache_fill(self) -> Mapping[str, int]:
        """DEPRECATED: use ``backend.layout.reset_values``."""
        return self.layout.reset_values if self.layout is not None else {}


_REGISTRY: Dict[Tuple[str, str], Backend] = {}


def register(backend: Backend) -> Backend:
    if backend.key in _REGISTRY:
        raise ValueError(f"backend {backend.name} already registered")
    if backend.caps.supports_decode and backend.decode is None:
        raise ValueError(f"{backend.name}: supports_decode without a "
                         f"decode fn")
    if backend.caps.supports_decode and (
            backend.layout is None or backend.layout.init is None
            or backend.layout.fill is None):
        raise ValueError(f"{backend.name}: supports_decode without a "
                         f"declared CacheLayout (layout.init/layout.fill)")
    if backend.layout is not None:
        # one-release shim: mirror the typed layout's name into the
        # deprecated caps.cache_layout string so external readers of the
        # old field keep seeing the right value. A backend that sets the
        # string itself must agree with its typed layout.
        if (backend.caps.cache_layout
                and backend.caps.cache_layout != backend.layout.name):
            raise ValueError(
                f"{backend.name}: deprecated caps.cache_layout "
                f"{backend.caps.cache_layout!r} contradicts the typed "
                f"layout {backend.layout.name!r}")
        if backend.caps.cache_layout != backend.layout.name:
            object.__setattr__(
                backend, "caps",
                replace(backend.caps, cache_layout=backend.layout.name))
    elif backend.caps.cache_layout:
        warnings.warn(
            f"{backend.name}: caps.cache_layout is a deprecated string "
            f"tag; declare a typed CacheLayout via Backend(layout=...)",
            DeprecationWarning, stacklevel=2)
    _REGISTRY[backend.key] = backend
    return backend


def unregister(variant: str, impl: str) -> None:
    """Test hook: remove a backend (e.g. a dummy registered by a test)."""
    _REGISTRY.pop((variant, impl), None)


def get(variant: str, impl: str) -> Backend:
    try:
        return _REGISTRY[(variant, impl)]
    except KeyError:
        impls = sorted(i for v, i in _REGISTRY if v == variant)
        raise BackendResolutionError(
            f"no backend registered for variant={variant!r} impl={impl!r};"
            f" registered impls for this variant: {impls or 'none'}"
        ) from None


def backends_for(variant: str) -> List[Backend]:
    return [b for b in _REGISTRY.values() if b.variant == variant]


def registered() -> List[Backend]:
    """All registered backends (benchmark sweeps, the parity matrix)."""
    return list(_REGISTRY.values())


def _layouts() -> List[CacheLayout]:
    return [b.layout for b in _REGISTRY.values() if b.layout is not None]


def cache_head_axes() -> Dict[str, int]:
    """Merged leaf-name -> head-axis map over every registered backend's
    CacheLayout (pool coords). dist.sharding consumes this instead of
    hardcoding cache leaf names."""
    hints: Dict[str, int] = {}
    for lo in _layouts():
        for leaf, axis in lo.head_axes.items():
            prev = hints.setdefault(leaf, axis)
            if prev != axis:
                raise ValueError(
                    f"conflicting head-axis hints for cache leaf "
                    f"{leaf!r}: {prev} vs {axis} (layout {lo.name!r})")
    return hints


def cache_reset_values() -> Dict[str, int]:
    """Merged leaf-name -> reset fill value over the registered layouts
    (the slot pool's reset_slot; leaves not listed reset to 0)."""
    fills: Dict[str, int] = {}
    for lo in _layouts():
        for leaf, val in lo.reset_values.items():
            prev = fills.setdefault(leaf, val)
            if prev != val:
                raise ValueError(
                    f"conflicting fill values for cache leaf {leaf!r}: "
                    f"{prev} vs {val} (layout {lo.name!r})")
    return fills


def pageable_cache_leaves() -> Dict[str, str]:
    """Merged leaf-name -> page-length-leaf map for cluster-page-
    structured cache leaves ((B, H, kc, cap, ...) with an occupied
    prefix of min(page_len, cap) per page). The tiered KV store uses
    this to park/transfer pages at per-page granularity."""
    out: Dict[str, str] = {}
    for lo in _layouts():
        for leaf in lo.pageable_leaves:
            prev = out.setdefault(leaf, lo.page_len_leaf)
            if prev != lo.page_len_leaf:
                raise ValueError(
                    f"conflicting page-length leaves for {leaf!r}: "
                    f"{prev!r} vs {lo.page_len_leaf!r} ({lo.name!r})")
    return out


def _capacity_gaps(b: Backend, *, seq_len: Optional[int],
                   head_dim: int) -> List[str]:
    """Sequence-capacity gaps only (max_seq / max_seq_elems) — the class
    of exclusion that silently degrades an otherwise-eligible backend at
    scale, which auto-selection reports through obs (see resolve)."""
    gaps = []
    if (seq_len is not None and b.caps.max_seq is not None
            and seq_len > b.caps.max_seq):
        gaps.append(f"seq_len {seq_len} exceeds max_seq {b.caps.max_seq}")
    if (seq_len is not None and b.caps.max_seq_elems is not None
            and seq_len * head_dim > b.caps.max_seq_elems):
        gaps.append(
            f"seq_len x head_dim {seq_len}x{head_dim} exceeds "
            f"max_seq_elems {b.caps.max_seq_elems} (the backend's "
            f"resident-plane budget)")
    return gaps


_FALLBACK_WARNED: set = set()


def _note_capacity_fallback(excluded: List[Backend], chosen: Backend,
                            gap_kw) -> None:
    """A strictly-higher-priority backend lost to ``chosen`` purely on
    sequence capacity: count it (obs ``attn/fallback``) and warn once per
    (excluded, chosen) pair — the N=8k-silently-lands-on-the-gathered-
    path failure mode gets a signal instead of a mystery slowdown."""
    from repro.obs import default_registry
    for b in excluded:
        cap = _capacity_gaps(b, seq_len=gap_kw["seq_len"],
                             head_dim=gap_kw["head_dim"])
        other = [g for g in _gaps(b, forced=False, **gap_kw)
                 if g not in cap]
        if not cap or other:
            continue   # excluded for a non-capacity reason too — normal
        default_registry().counter("attn/fallback").inc()
        key = (b.name, chosen.name)
        if key not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(key)
            warnings.warn(
                f"attn auto-selection fell back from {b.name} "
                f"(priority {b.priority}) to {chosen.name} "
                f"(priority {chosen.priority}): {'; '.join(cap)}. "
                f"Further fallbacks of this pair are counted on the obs "
                f"'attn/fallback' counter without re-warning.",
                RuntimeWarning, stacklevel=3)


def _gaps(b: Backend, *, decode: bool, padded: bool,
          positioned: bool, scaled: bool, needs_grad: bool,
          seq_len: Optional[int], head_dim: int, mesh_devices: int,
          platform: str, forced: bool) -> List[str]:
    """Capability gaps of ``b`` for this call. ``needs_tpu`` only counts
    against auto-selection (forced backends fall back to interpret)."""
    gaps = []
    if decode and not b.caps.supports_decode:
        gaps.append("call needs a decode path (cache given) but "
                    "supports_decode=False")
    if needs_grad and not b.caps.supports_grad:
        gaps.append("call is differentiated (needs_grad=True) but the "
                    "backend has no VJP (supports_grad=False)")
    if padded and not b.caps.supports_pad_mask:
        gaps.append("call has a pad_mask but supports_pad_mask=False")
    if positioned and not b.caps.supports_positions:
        gaps.append("call has explicit positions but the backend masks "
                    "by row index (supports_positions=False)")
    if scaled and not b.caps.supports_logit_scale:
        gaps.append("spec sets logit_scale but the backend's scale is "
                    "baked at 1/sqrt(head_dim) "
                    "(supports_logit_scale=False)")
    if mesh_devices > 1 and not b.caps.supports_mesh:
        gaps.append(f"call runs on a {mesh_devices}-device mesh but "
                    f"supports_mesh=False")
    gaps += _capacity_gaps(b, seq_len=seq_len, head_dim=head_dim)
    if not forced and b.caps.needs_tpu and platform != "tpu":
        gaps.append(f"needs_tpu on platform {platform!r}")
    return gaps


def resolve(spec: AttentionSpec, *, decode: bool = False,
            padded: bool = False, positioned: bool = False,
            needs_grad: bool = False, seq_len: Optional[int] = None,
            mesh=None, impl: Optional[str] = None,
            platform: str = "cpu") -> Backend:
    """Pick the backend for this call, or raise loudly.

    ``impl``: explicit override — capability mismatches are errors, not
    silent fallbacks. Without it: best (highest-priority) registered
    backend whose capabilities cover the call on ``platform``.
    ``needs_grad``: the caller will differentiate through the result
    (train paths announce this) — non-differentiable backends are
    excluded / refused.
    """
    mesh_devices = getattr(mesh, "size", 1) if mesh is not None else 1
    gap_kw = dict(decode=decode, padded=padded, positioned=positioned,
                  needs_grad=needs_grad,
                  scaled=spec.logit_scale is not None, seq_len=seq_len,
                  head_dim=spec.head_dim, mesh_devices=mesh_devices,
                  platform=platform)
    if impl is not None:
        b = get(spec.variant, impl)
        gaps = _gaps(b, forced=True, **gap_kw)
        if gaps:
            msg = (f"forced backend {b.name} cannot serve this call:\n  - "
                   + "\n  - ".join(gaps))
            try:
                alt = resolve(spec, decode=decode, padded=padded,
                              positioned=positioned, needs_grad=needs_grad,
                              seq_len=seq_len, mesh=mesh, impl=None,
                              platform=platform)
            except BackendResolutionError:
                alt = None
            if alt is not None:
                msg += (f"\nauto-selection (impl=None) would serve this "
                        f"call with {alt.name}")
            raise BackendResolutionError(msg)
        return b
    cands = backends_for(spec.variant)
    if not cands:
        raise BackendResolutionError(
            f"no backends registered for variant {spec.variant!r}")
    ok = [b for b in cands if not _gaps(b, forced=False, **gap_kw)]
    if not ok:
        detail = "; ".join(
            f"{b.name}: "
            f"{', '.join(_gaps(b, forced=False, **gap_kw))}"
            for b in cands)
        raise BackendResolutionError(
            f"no registered backend for variant {spec.variant!r} covers "
            f"this call ({detail})")
    chosen = max(ok, key=lambda b: b.priority)
    skipped = [b for b in cands if b.priority > chosen.priority]
    if skipped:
        _note_capacity_fallback(skipped, chosen, gap_kw)
    return chosen
