"""Mamba2 SSD (state-space duality) mixer — arXiv:2405.21060.

Chunked dual form: intra-chunk quadratic term (MXU-friendly (Q x Q) blocks)
+ inter-chunk linear state recurrence via lax.scan. A naive time-step scan
(`ssd_naive`) is the test oracle. Decode is a single-step state update.

Per-head state: (N, P) with N = ssm_state, P = headdim. B/C projections use
one group (mamba2 default), broadcast over heads.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_norm, apply_norm


class SSMSpec(NamedTuple):
    d_inner: int
    nheads: int
    headdim: int
    nstate: int
    conv: int
    chunk: int


def ssm_spec(cfg) -> SSMSpec:
    d_inner = cfg.ssm_expand * cfg.d_model
    headdim = 64
    nheads = cfg.ssm_heads or d_inner // headdim
    return SSMSpec(d_inner, nheads, d_inner // nheads, cfg.ssm_state,
                   cfg.ssm_conv, cfg.ssm_chunk)


def init_ssd(key, cfg):
    s = ssm_spec(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    conv_ch = s.d_inner + 2 * s.nstate
    ks = jax.random.split(key, 6)
    proj_out = 2 * s.d_inner + 2 * s.nstate + s.nheads
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv, conv_ch)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, s.nheads)).astype(jnp.float32),
        "D": jnp.ones((s.nheads,), jnp.float32),
        "dt_bias": jnp.full((s.nheads,), -2.0, jnp.float32),
        "norm": init_norm(s.d_inner, "rmsnorm", dt),
        "out_proj": dense_init(ks[2], s.d_inner, d, dt),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). state: (B,K-1,C)|None."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, [(0, 0), (K - 1, 0), (0, 0)])
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out, new_state


def _split_proj(zxbcdt, s: SSMSpec):
    z = zxbcdt[..., :s.d_inner]
    xBC = zxbcdt[..., s.d_inner:2 * s.d_inner + 2 * s.nstate]
    dt = zxbcdt[..., -s.nheads:]
    return z, xBC, dt


def ssd_chunked(xh, dt, A, Bm, Cm, chunk, init_state=None):
    """Chunked SSD. xh: (B,S,H,P), dt: (B,S,H) fp32, A: (H,) fp32 (<0),
    Bm/Cm: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    Sp = nc * Q
    if Sp != S:
        xh = jnp.pad(xh, [(0, 0), (0, Sp - S), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, Sp - S), (0, 0)])
        Bm = jnp.pad(Bm, [(0, 0), (0, Sp - S), (0, 0)])
        Cm = jnp.pad(Cm, [(0, 0), (0, Sp - S), (0, 0)])
    xc = xh.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), jnp.float32)
    iq = jnp.arange(Q)
    causal = iq[:, None] >= iq[None, :]

    def chunk_step(Sprev, xs):
        """One chunk: intra (quadratic) + inter (state) terms, then the
        state recurrence. Checkpointed so the scan saves only the (B,H,N,P)
        state chain — the (Q,Q,H) decay tensor would otherwise be stacked
        across all chunks as bwd residuals (mamba2-780m train_4k: 40.7 ->
        <16 GiB/chip, §Perf)."""
        xq, dtq, Bq, Cq = xs                      # (B,Q,H,P),(B,Q,H),(B,Q,N)
        l = dtq * A                               # (B,Q,H) log-decay <= 0
        cum = jnp.cumsum(l, axis=1)
        xbar = xq * dtq[..., None]
        cb = jnp.einsum("bqn,bkn->bqk", Cq, Bq)   # (B,Q,Q)
        decay = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H)
        # mask *inside* the exp: exp of the (positive) acausal deltas
        # overflows and poisons gradients through jnp.where otherwise
        decay = jnp.where(causal[None, :, :, None], decay, -1e9)
        M = cb[..., None] * jnp.exp(decay)                  # (B,Q,Q,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", M, xbar)
        y_inter = jnp.einsum("bqn,bqh,bhnp->bqhp", Cq, jnp.exp(cum), Sprev)
        tot = cum[:, -1, :]                                 # (B,H)
        w_in = jnp.exp(tot[:, None, :] - cum)               # (B,Q,H)
        cs = jnp.einsum("bqn,bqh,bqhp->bhnp", Bq, w_in, xbar)
        Snew = Sprev * jnp.exp(tot)[..., None, None] + cs
        return Snew, y_intra + y_inter

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    final_state, ys = jax.lax.scan(jax.checkpoint(chunk_step),
                                   init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, H, P)[:, :S]
    return y, final_state


def ssd_naive(xh, dt, A, Bm, Cm, init_state=None):
    """Step-by-step oracle: h_t = exp(dt A) h + B (dt x); y_t = C . h."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def step(h, xs):
        x_t, dt_t, B_t, C_t = xs
        da = jnp.exp(dt_t * A)                             # (B,H)
        inc = jnp.einsum("bn,bhp->bhnp", B_t, x_t * dt_t[..., None])
        h = h * da[..., None, None] + inc
        y = jnp.einsum("bn,bhnp->bhp", C_t, h)
        return h, y

    xs = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, init_state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def apply_ssd(p, x, cfg, conv_state=None, ssm_state=None, decode=False):
    """Full mamba2 mixer. x: (B,S,d). Returns (y, (conv_state, ssm_state))."""
    s = ssm_spec(cfg)
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xBC, dtr = _split_proj(zxbcdt, s)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs = xBC[..., :s.d_inner]
    Bm = xBC[..., s.d_inner:s.d_inner + s.nstate]
    Cm = xBC[..., s.d_inner + s.nstate:]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, s.nheads, s.headdim)
    if decode:
        y, new_state = ssd_naive(xh, dt, A, Bm, Cm, ssm_state)
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, ssm_state)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, S, s.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = apply_norm(p["norm"], y, "rmsnorm")
    return y @ p["out_proj"], (new_conv, new_state)
