"""RG-LRU recurrent mixer (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)           recurrence gate
    i_t = sigmoid(W_x x_t + b_x)           input gate
    a_t = a ** (c * r_t),  a = sigmoid(Lambda)  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Implemented with an associative scan over (a, b) pairs of the linear
recurrence h = a*h + b; a step-by-step oracle (`rglru_naive`) backs the
tests. The full block is: linear-in -> causal conv(4) -> RG-LRU -> gated by
a GeLU branch -> linear-out (Griffin recurrent block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.ssm import _causal_conv

_C = 8.0


def init_rglru(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], d, w, dt),
        "w_gate_branch": dense_init(ks[1], d, w, dt),
        "conv_w": (jax.random.normal(ks[2], (4, w)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": dense_init(ks[3], w, w, jnp.float32),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": dense_init(ks[4], w, w, jnp.float32),
        "b_x": jnp.zeros((w,), jnp.float32),
        # Lambda init s.t. a in [0.9, 0.999] roughly
        "lam": jnp.linspace(2.2, 6.9, w).astype(jnp.float32),
        "w_out": dense_init(ks[5], w, d, dt),
    }


def _gates(p, u):
    """u: (B,S,w) fp32 -> per-step decay a_t and input b_t."""
    r = jax.nn.sigmoid(u @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(u @ p["w_x"] + p["b_x"])
    a_base = jax.nn.sigmoid(p["lam"])
    log_a = _C * r * jnp.log(a_base)          # a_t = a ** (c r_t)
    a_t = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a_t), 1e-12)) * (i * u)
    return a_t, b_t


def rglru_scan(a, b, h0=None, chunk: int = 512):
    """Linear recurrence h = a*h_prev + b via chunked associative scan.

    Outer lax.scan over chunks (checkpointed body) + inner associative
    scan: the log-depth associative-scan intermediates and bwd residuals
    then live only per-chunk instead of across the full (B,S,w) tensor —
    recurrentgemma-9b train_4k peak 43.6 -> <16 GiB/chip (§Perf). Griffin's
    TPU implementation makes the same trade (linear scan over blocks).
    a, b: (B,S,w).
    """
    B, S, w = a.shape
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    Q = min(chunk, S)
    nc = -(-S // Q)
    Sp = nc * Q
    if Sp != S:
        a = jnp.pad(a, [(0, 0), (0, Sp - S), (0, 0)], constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, Sp - S), (0, 0)])
    ac = jnp.moveaxis(a.reshape(B, nc, Q, w), 1, 0)
    bc = jnp.moveaxis(b.reshape(B, nc, Q, w), 1, 0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, xs):
        aq, bq = xs                                   # (B,Q,w)
        bq = bq.at[:, 0].add(aq[:, 0] * h)
        _, hq = jax.lax.associative_scan(combine, (aq, bq), axis=1)
        return hq[:, -1], hq

    _, hs = jax.lax.scan(jax.checkpoint(chunk_step),
                         jnp.zeros((B, w), a.dtype), (ac, bc))
    return jnp.moveaxis(hs, 0, 1).reshape(B, Sp, w)[:, :S]


def rglru_fused(p, u, h0=None, chunk: int = 512):
    """Gates + recurrence fused per chunk: the full-length fp32 (B,S,w)
    gate tensors never materialize — only (B,Q,w) per chunk inside the
    checkpointed body (bwd recomputes the gate matmuls per chunk)."""
    B, S, w = u.shape
    Q = min(chunk, S)
    nc = -(-S // Q)
    Sp = nc * Q
    if Sp != S:
        u = jnp.pad(u, [(0, 0), (0, Sp - S), (0, 0)])
    uc = jnp.moveaxis(u.reshape(B, nc, Q, w), 1, 0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, uq):
        aq, bq = _gates(p, uq.astype(jnp.float32))
        bq = bq.at[:, 0].add(aq[:, 0] * h)
        _, hq = jax.lax.associative_scan(combine, (aq, bq), axis=1)
        return hq[:, -1], hq

    h_init = (jnp.zeros((B, w), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    _, hs = jax.lax.scan(jax.checkpoint(chunk_step), h_init, uc)
    return jnp.moveaxis(hs, 0, 1).reshape(B, Sp, w)[:, :S]


def rglru_naive(a, b, h0=None):
    B, S, w = a.shape
    h = jnp.zeros((B, w), a.dtype) if h0 is None else h0

    def step(h, xs):
        a_t, b_t = xs
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h, (jnp.moveaxis(a, 1, 0),
                                   jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def apply_rglru(p, x, cfg, conv_state=None, h_state=None, decode=False):
    """x: (B,S,d) -> (y, (conv_state, h_state))."""
    gate = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32))
    u = x @ p["w_in"]
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    if decode:
        a, b = _gates(p, u.astype(jnp.float32))
        h = rglru_naive(a, b, h_state)
    else:
        h = rglru_fused(p, u, h_state)
    new_h = h[:, -1]
    y = (h * gate).astype(x.dtype)
    return y @ p["w_out"], (new_conv, new_h)
