"""Top-level model: embed -> stack -> final norm -> logits, plus losses.

`init_model` returns (params, kstate); `apply_model` is pure and returns
(logits, new_kstate, aux). The k-means centroid state is functional: the
caller (train step) decides whether to keep the update.

Batch dict keys:
  tokens        (B, S) int32 — LM inputs / hubert codebook targets
  positions     (B, S) int32 (optional, defaults to arange)
  pad_mask      (B, S) bool  (optional)
  features      (B, S, d)    — [audio] stub frontend frame embeddings
  image_embeds  (B, M, d)    — [vlm] stub frontend patch embeddings
  mask_spans    (B, S) bool  — [audio] masked-prediction positions
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


def init_model(cfg: ModelConfig, key: jax.Array):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    params: Dict[str, Any] = {
        "embed": L.init_embed(ks[0], cfg.padded_vocab, cfg.d_model, dt,
                              cfg.tie_embeddings),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
    }
    if cfg.family == "encoder":
        params["mask_emb"] = (jax.random.normal(ks[2], (cfg.d_model,))
                              * 0.02).astype(dt)
    seg_params, seg_kstate = T.init_stack(ks[1], cfg)
    params["stack"] = seg_params
    return params, seg_kstate


def apply_model(params, kstate, batch: Dict[str, jax.Array],
                cfg: ModelConfig, *, update_state: bool = True,
                impl: Optional[str] = None, moe_impl: str = "einsum",
                remat: str = "none", drop_rng: Optional[jax.Array] = None,
                constrain_fn=None, mesh=None, needs_grad: bool = False):
    positions = batch.get("positions")
    pad_mask = batch.get("pad_mask")
    if cfg.family == "encoder":
        x = batch["features"].astype(jnp.dtype(cfg.dtype))
        if "mask_spans" in batch:
            x = jnp.where(batch["mask_spans"][..., None],
                          params["mask_emb"].astype(x.dtype), x)
    else:
        x = L.embed(params["embed"], batch["tokens"])
    x, new_kstate, aux = T.apply_stack(
        params["stack"], kstate, x, cfg,
        positions=positions, pad_mask=pad_mask,
        image_embeds=batch.get("image_embeds"),
        update_state=update_state, impl=impl, moe_impl=moe_impl,
        remat=remat, drop_rng=drop_rng, constrain_fn=constrain_fn,
        mesh=mesh, needs_grad=needs_grad)
    epilogue = getattr(constrain_fn, "epilogue", None)
    if epilogue is not None:
        x = epilogue(x)          # SP epilogue: re-gather seq for the LM head
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.logits_out(params["embed"], x, cfg.tie_embeddings,
                          cfg.logit_softcap)
    logits = mask_vocab_pad(logits, cfg)
    return logits, new_kstate, aux


def mask_vocab_pad(logits, cfg):
    """Padding rows of the (256-aligned) embedding table never win: mask
    their logits so CE/argmax see only the logical vocabulary."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(valid, logits, -1e9)


def lm_loss(logits: jax.Array, targets: jax.Array,
            pad_mask: Optional[jax.Array] = None,
            z_loss: float = 0.0,
            loss_mask: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Token-mean cross entropy in fp32. logits (B,S,V), targets (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    mask = jnp.ones(targets.shape, jnp.float32)
    if pad_mask is not None:
        mask = mask * pad_mask.astype(jnp.float32)
    if loss_mask is not None:
        mask = mask * loss_mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    metrics = {"nll": loss, "tokens": denom}
    if z_loss:
        zl = z_loss * ((lse ** 2) * mask).sum() / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics


def next_token_batch(batch: Dict[str, jax.Array]) -> Tuple[Dict, jax.Array]:
    """Shift tokens for next-token prediction: inputs[t] predicts tokens[t+1]."""
    toks = batch["tokens"]
    inputs = dict(batch)
    inputs["tokens"] = toks[:, :-1]
    for k in ("positions", "pad_mask", "mask_spans"):
        if k in batch:
            inputs[k] = batch[k][:, :-1]
    if "features" in batch:
        inputs["features"] = batch["features"][:, :-1]
    return inputs, toks[:, 1:]
