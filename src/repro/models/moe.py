"""Mixture-of-Experts FFN (llama4-style: top-1 router + shared expert).

Two dispatch implementations with identical math:

* `einsum` (default under pjit): Shazeer-style one-hot dispatch/combine
  einsums with per-example capacity. GSPMD-friendly: with experts sharded on
  the "model" axis and batch on "data", dispatch/expert/combine einsums
  partition locally and the only collective is the TP-style all-reduce of the
  combined output. No emulated NCCL all-to-all.
* `scatter` (CPU/eval): position-in-expert scatter into (E, C, d) buffers —
  zero dispatch FLOPs, used as the correctness oracle.

Aux outputs: Switch-style load-balance loss + router z-loss + drop fraction.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, apply_mlp


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) / jnp.sqrt(d)).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) / jnp.sqrt(d)).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) / jnp.sqrt(f)).astype(dt),
    }
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(ks[4], d, f, cfg.act, dt)
    return p


def _router(p, x, cfg):
    """Returns (gate (B,N), expert_idx (B,N), probs fp32 (B,N,E), aux)."""
    logits = (x.astype(jnp.float32) @ p["router"])            # (B,N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    gate = jnp.max(probs, axis=-1)
    E = cfg.moe_experts
    # Switch load-balance loss: E * sum_e f_e * p_e
    f_e = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(f_e * p_e)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gate, idx, probs, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _capacity(cfg, n):
    return max(1, int(cfg.moe_capacity_factor * n / cfg.moe_experts))


def apply_moe(p, x, cfg, impl: str = "einsum") -> Tuple[jax.Array, Dict]:
    """x: (B, N, d) -> (B, N, d), aux dict."""
    B, N, d = x.shape
    E, C = cfg.moe_experts, _capacity(cfg, N)
    gate, idx, probs, aux = _router(p, x, cfg)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # (B,N,E)
    # position of each token within its expert's capacity buffer (per example)
    pos = jnp.cumsum(onehot, axis=1) * onehot                 # (B,N,E) 1-based
    pos_tok = (jnp.sum(pos, axis=-1) - 1.0)                   # (B,N) 0-based
    keep = (pos_tok < C) & (pos_tok >= 0)
    aux["moe_drop_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))

    if impl == "scatter":
        y = _moe_scatter(p, x, cfg, idx, pos_tok, keep, C)
    else:
        y = _moe_einsum(p, x, cfg, onehot, pos_tok, keep, C)
    y = y * gate[..., None].astype(y.dtype)
    if cfg.moe_shared_expert:
        y = y + apply_mlp(p["shared"], x, cfg.act)
    return y.astype(x.dtype), aux


def _moe_einsum(p, x, cfg, onehot, pos_tok, keep, C):
    B, N, d = x.shape
    E = cfg.moe_experts
    # dispatch[b,n,e,c] = 1 iff token (b,n) is slot c of expert e
    pos_oh = jax.nn.one_hot(pos_tok, C, dtype=jnp.float32) \
        * keep[..., None].astype(jnp.float32)                 # (B,N,C)
    dispatch = onehot[..., :, None] * pos_oh[..., None, :]    # (B,N,E,C)
    dispatch = dispatch.astype(x.dtype)
    xin = jnp.einsum("bnec,bnd->becd", dispatch, x)           # (B,E,C,d)
    h = jnp.einsum("becd,edf->becf", xin, p["w_up"])
    if cfg.act == "swiglu":
        g = jnp.einsum("becd,edf->becf", xin, p["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("becf,efd->becd", h, p["w_down"])
    return jnp.einsum("bnec,becd->bnd", dispatch, out)


def _moe_scatter(p, x, cfg, idx, pos_tok, keep, C):
    B, N, d = x.shape
    E = cfg.moe_experts
    pos = pos_tok.astype(jnp.int32)
    slot = jnp.where(keep, pos, C)                    # overflow -> trash slot
    bi = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E, C + 1, d), x.dtype).at[bi, idx, slot].add(x)
    xin = buf[:, :, :C]                               # (B,E,C,d)
    h = jnp.einsum("becd,edf->becf", xin, p["w_up"])
    if cfg.act == "swiglu":
        g = jnp.einsum("becd,edf->becf", xin, p["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("becf,efd->becd", h, p["w_down"])        # (B,E,C,d)
    y = out[bi, idx, jnp.minimum(slot, C - 1)] * \
        keep[..., None].astype(out.dtype)
    return y.reshape(B, N, d)
