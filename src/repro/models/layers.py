"""Shared layers: norms, RoPE, MLPs, projections, embeddings.

Pure functional: `init_*` returns a param pytree (nested dict of arrays);
`apply` functions are pure. Params are stored in the config dtype; matmuls
run in that dtype with fp32 norm/softmax statistics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(d, kind, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, N, dh); positions: (B, N) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)          # (B,1,N,dh/2)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU / ReLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d, d_ff, act, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d, dtype)}
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def apply_mlp(p, x, act="swiglu"):
    up = x @ p["w_up"]
    if act == "swiglu":
        gate = x @ p["w_gate"]
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif act == "gelu":
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.relu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# QKV / output projections (GQA)
# ---------------------------------------------------------------------------
def init_attn_proj(key, cfg):
    d, dh = cfg.d_model, cfg.head_dim_
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {"wq": dense_init(ks[0], d, H * dh, dt),
         "wk": dense_init(ks[1], d, Hkv * dh, dt),
         "wv": dense_init(ks[2], d, Hkv * dh, dt),
         "wo": dense_init(ks[3], H * dh, d, dt)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dt)
        p["bk"] = jnp.zeros((Hkv * dh,), dt)
        p["bv"] = jnp.zeros((Hkv * dh,), dt)
    return p


def qkv_project(p, x, cfg, positions=None, rope=True):
    """x: (B,N,d) -> q (B,H,N,dh), k/v (B,Hkv,N,dh)."""
    B, N, _ = x.shape
    dh, H, Hkv = cfg.head_dim_, cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, N, H, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, N, Hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, N, Hkv, dh).transpose(0, 2, 1, 3)
    if rope and cfg.position == "rope":
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32),
                                         (B, N))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(p, o):
    """o: (B,H,N,dh) -> (B,N,d)."""
    B, H, N, dh = o.shape
    return o.transpose(0, 2, 1, 3).reshape(B, N, H * dh) @ p["wo"]


# ---------------------------------------------------------------------------
# Embeddings / logits
# ---------------------------------------------------------------------------
def init_embed(key, vocab, d, dtype, tie):
    ks = jax.random.split(key, 2)
    p = {"tok": (jax.random.normal(ks[0], (vocab, d)) * 0.02).astype(dtype)}
    if not tie:
        p["unembed"] = dense_init(ks[1], d, vocab, dtype)
    return p


def embed(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def logits_out(p, x, tie, softcap=0.0):
    if tie:
        lg = x @ p["tok"].T
    else:
        lg = x @ p["unembed"]
    lg = lg.astype(jnp.float32)
    if softcap:
        lg = softcap * jnp.tanh(lg / softcap)
    return lg
