"""Unified transformer stack for every assigned architecture family.

The stack is a list of *segments*; each segment is a repeating *pattern* of
layer specs scanned `n_groups` times with `jax.lax.scan` (keeps HLO size
independent of depth — critical for 48-layer 400B dry-runs), plus remat at
group granularity. k-means centroid state for routing layers is threaded
through the scan as xs/ys (functional state, no mutation).

Layer kinds:
  attn    norm -> self-attention (full|local|routing|local+routing) -> norm -> FFN
  moe     same but FFN is the MoE layer
  cross   norm -> cross-attention to image embeddings -> norm -> FFN (VLM)
  ssd     norm -> mamba2 SSD mixer (no FFN; d_ff=0)
  rglru   norm -> RG-LRU mixer -> norm -> FFN (Griffin block)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import attn as attn_api
from repro.attn.spec import head_split, spec_for_layer, variant_for_layer
from repro.configs.base import ModelConfig
from repro.core.attention import full_attention
from repro.core.kmeans import init_kmeans
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


@dataclass(frozen=True)
class LayerSpec:
    kind: str                 # attn | moe | cross | ssd | rglru
    attn: str = "full"        # attention backend for attn/moe/cross


# ---------------------------------------------------------------------------
# Segment construction
# ---------------------------------------------------------------------------
def per_layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    Lr = cfg.num_layers
    attn_mode = lambda i: variant_for_layer(cfg, i)  # noqa: E731
    specs = []
    for i in range(Lr):
        if cfg.family == "ssm":
            specs.append(LayerSpec("ssd"))
        elif cfg.family == "hybrid":
            pat = cfg.hybrid_pattern or ("rglru", "rglru", "attn")
            kind = pat[i % len(pat)]
            specs.append(LayerSpec(kind, attn_mode(i) if kind == "attn"
                                   else "full"))
        elif cfg.family == "moe":
            kind = "moe" if i % cfg.moe_interleave == 0 else "attn"
            specs.append(LayerSpec(kind, attn_mode(i)))
        elif cfg.family == "vlm":
            kind = "cross" if (i + 1) % 5 == 0 else "attn"
            specs.append(LayerSpec(kind, attn_mode(i)))
        else:  # dense / encoder
            specs.append(LayerSpec("attn", attn_mode(i)))
    return specs


def build_segments(cfg: ModelConfig) -> List[Tuple[Tuple[LayerSpec, ...], int]]:
    """Compress the per-layer spec list into (pattern, n_groups) segments."""
    specs = per_layer_specs(cfg)
    period = {"moe": cfg.moe_interleave, "vlm": 5,
              "hybrid": len(cfg.hybrid_pattern or ("rglru", "rglru", "attn"))
              }.get(cfg.family, 1)
    segments: List[Tuple[Tuple[LayerSpec, ...], int]] = []
    i = 0
    while i < len(specs):
        # longest run of repeats of specs[i:i+period]
        pat = tuple(specs[i:i + period])
        g = 0
        while (i + (g + 1) * len(pat) <= len(specs)
               and tuple(specs[i + g * len(pat):i + (g + 1) * len(pat)]) == pat):
            g += 1
        if g == 0:                       # tail shorter than period
            pat = tuple(specs[i:])
            g = 1
        segments.append((pat, g))
        i += g * len(pat)
    return segments


# head_split (the paper's local/routing split) now lives in
# repro.attn.spec and is re-exported above for existing importers.


def where_active(active: jax.Array, new_tree, old_tree, batch_axis: int = 1):
    """Row-select between two cache pytrees along the slot (batch) axis.

    Continuous-batching decode runs every pool slot through the stack each
    step; rows where ``active`` is False must be exact no-ops so a finished
    or free slot's cache is untouched until it is re-admitted. ``active`` is
    a (B,) bool vector; leaves are indexed (…, B, …) at ``batch_axis``.
    """
    def sel(n, o):
        shape = [1] * n.ndim
        shape[batch_axis] = -1
        return jnp.where(active.reshape(shape), n, o)
    return jax.tree.map(sel, new_tree, old_tree)


# ---------------------------------------------------------------------------
# Layer init
# ---------------------------------------------------------------------------
def init_layer(key, spec: LayerSpec, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p: Dict[str, Any] = {"ln1": L.init_norm(cfg.d_model, cfg.norm, dt)}
    if spec.kind in ("attn", "moe", "cross"):
        p["attn"] = L.init_attn_proj(ks[0], cfg)
        p["ln2"] = L.init_norm(cfg.d_model, cfg.norm, dt)
        if spec.kind == "moe":
            p["ffn"] = moe_mod.init_moe(ks[1], cfg)
        else:
            p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
        if spec.kind == "cross":
            p["xgate_attn"] = jnp.zeros((), jnp.float32)
            p["xgate_ffn"] = jnp.zeros((), jnp.float32)
    elif spec.kind == "ssd":
        p["mixer"] = ssm_mod.init_ssd(ks[0], cfg)
    elif spec.kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg)
        p["ln2"] = L.init_norm(cfg.d_model, cfg.norm, dt)
        p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def layer_kstate(key, spec: LayerSpec, cfg: ModelConfig):
    """Centroid state for a layer, or None if no routing heads."""
    if spec.kind not in ("attn", "moe", "cross") or "routing" not in spec.attn:
        return None
    if spec.attn == "routing":
        Hr = cfg.num_heads
    else:
        _, Hr, _, _ = head_split(cfg)
    return init_kmeans(key, Hr, cfg.routing.num_clusters, cfg.head_dim_).mu


# ---------------------------------------------------------------------------
# Attention dispatch — one call into repro.attn; variant math, rope
# policy, head splitting, and backend selection all live behind
# attn.attend (DESIGN.md §8)
# ---------------------------------------------------------------------------
def self_attention(p, h, cfg: ModelConfig, mode: str, kmu,
                   positions, pad_mask, update_state, impl=None, mesh=None,
                   needs_grad=False):
    """h: (B,N,d) -> ((B,N,d), new_kmu, stats). ``stats`` is the
    obs.RoutingStats aux of a routing variant with RoutingConfig.stats
    on, else None."""
    q, k, v = L.qkv_project(p, h, cfg, positions, rope=False)
    out = attn_api.attend(spec_for_layer(cfg, mode), q, k, v, state=kmu,
                          positions=positions, pad_mask=pad_mask,
                          update_state=update_state, impl=impl, mesh=mesh,
                          needs_grad=needs_grad)
    return L.out_project(p, out.out), out.state, out.stats


def cross_attention(p, h, image_embeds, cfg: ModelConfig, pad_mask=None):
    """Text queries attend to image tokens (no causal mask, no rope)."""
    B, N, _ = h.shape
    q, _, _ = L.qkv_project(p, h, cfg, rope=False)
    dh, Hkv = cfg.head_dim_, cfg.num_kv_heads
    M = image_embeds.shape[1]
    k = (image_embeds @ p["wk"]).reshape(B, M, Hkv, dh).transpose(0, 2, 1, 3)
    v = (image_embeds @ p["wv"]).reshape(B, M, Hkv, dh).transpose(0, 2, 1, 3)
    o = full_attention(q, k, v, causal=False)
    return L.out_project(p, o)


# ---------------------------------------------------------------------------
# Layer apply
# ---------------------------------------------------------------------------
def _dropout(x, rate, rng):
    if rng is None or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0).astype(x.dtype)


def apply_layer(spec: LayerSpec, p, kmu, x, cfg: ModelConfig, *,
                positions=None, pad_mask=None, image_embeds=None,
                update_state=True, impl=None, moe_impl="einsum",
                drop_rng=None, mesh=None, needs_grad=False):
    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    new_kmu = kmu
    rngs = (jax.random.split(drop_rng, 2) if drop_rng is not None
            else (None, None))
    if spec.kind in ("attn", "moe", "cross"):
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        if spec.kind == "cross":
            a = cross_attention(p["attn"], h, image_embeds, cfg)
            a = a * jnp.tanh(p["xgate_attn"]).astype(a.dtype)
        else:
            a, new_kmu, a_stats = self_attention(
                p["attn"], h, cfg, spec.attn, kmu, positions, pad_mask,
                update_state, impl, mesh=mesh, needs_grad=needs_grad)
            if a_stats is not None:
                # rides in aux (popped by apply_stack / prefill into the
                # scan ys; NOT one of the fixed AUX_KEYS scalars)
                aux["routing_stats"] = a_stats
        x = x + _dropout(a, cfg.dropout, rngs[0])
        h2 = L.apply_norm(p["ln2"], x, cfg.norm)
        if spec.kind == "moe":
            ff, moe_aux = moe_mod.apply_moe(p["ffn"], h2, cfg, impl=moe_impl)
            aux.update({k: jnp.asarray(v, jnp.float32)
                        for k, v in moe_aux.items()})
        else:
            ff = L.apply_mlp(p["ffn"], h2, cfg.act)
            if spec.kind == "cross":
                ff = ff * jnp.tanh(p["xgate_ffn"]).astype(ff.dtype)
        x = x + _dropout(ff, cfg.dropout, rngs[1])
    elif spec.kind == "ssd":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, _ = ssm_mod.apply_ssd(p["mixer"], h, cfg)
        x = x + y
    elif spec.kind == "rglru":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, _ = rglru_mod.apply_rglru(p["mixer"], h, cfg)
        x = x + y
        h2 = L.apply_norm(p["ln2"], x, cfg.norm)
        x = x + _dropout(L.apply_mlp(p["ffn"], h2, cfg.act), cfg.dropout,
                         rngs[1])
    return x, new_kmu, aux


# ---------------------------------------------------------------------------
# Stack init / apply (scan over segment groups)
# ---------------------------------------------------------------------------
def init_stack(key, cfg: ModelConfig):
    segments = build_segments(cfg)
    seg_params, seg_kstate = [], []
    for si, (pattern, G) in enumerate(segments):
        key, sk = jax.random.split(key)
        gkeys = jax.random.split(sk, G)

        def init_group(k, pattern=pattern):
            ks = jax.random.split(k, 2 * len(pattern))
            params = tuple(init_layer(ks[2 * i], s, cfg)
                           for i, s in enumerate(pattern))
            kst = {str(i): layer_kstate(ks[2 * i + 1], s, cfg)
                   for i, s in enumerate(pattern)
                   if layer_kstate(ks[2 * i + 1], s, cfg) is not None}
            return params, kst

        params, kst = jax.vmap(init_group)(gkeys)
        seg_params.append(params)
        seg_kstate.append(kst)
    return seg_params, seg_kstate


def apply_stack(seg_params, seg_kstate, x, cfg: ModelConfig, *,
                positions=None, pad_mask=None, image_embeds=None,
                update_state=True, impl=None, moe_impl="einsum",
                remat="none", drop_rng=None,
                constrain_fn: Optional[Callable] = None, mesh=None,
                needs_grad=False):
    segments = build_segments(cfg)
    aux_tot = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    new_seg_kstate = []
    seg_stats = []
    constrain = constrain_fn or (lambda t: t)
    # fsdp prefetch (dist/sharding.make_constrain_fn): re-constrain the
    # group's weight slice to its gathered (TP-only) layout at group entry,
    # pinning the zero-3 all-gather to one schedulable point per group
    gather = getattr(constrain, "gather_params", None)
    # constrain the embedding output too: with sequence parallelism the
    # residual stream must enter the first scan group already seq-sharded,
    # or GSPMD keeps a replicated copy alive until the first group boundary
    x = constrain(x)
    layer_counter = 0
    for si, (pattern, G) in enumerate(segments):

        def group_fn(x, xs, pattern=pattern, base=layer_counter):
            p_group, k_group, gi = xs
            if gather is not None:
                p_group = gather(p_group)
            aux_g = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
            new_k = {}
            stats_g = {}
            for i, spec in enumerate(pattern):
                rng_i = None
                if drop_rng is not None and cfg.dropout > 0:
                    rng_i = jax.random.fold_in(
                        jax.random.fold_in(drop_rng, base + i), gi)
                x, nk, aux_i = apply_layer(
                    spec, p_group[i], k_group.get(str(i)), x, cfg,
                    positions=positions, pad_mask=pad_mask,
                    image_embeds=image_embeds, update_state=update_state,
                    impl=impl, moe_impl=moe_impl, drop_rng=rng_i,
                    mesh=mesh, needs_grad=needs_grad)
                if str(i) in k_group:
                    new_k[str(i)] = nk
                st = aux_i.pop("routing_stats", None)
                if st is not None:
                    # per-layer stats leave the scan as stacked ys (a
                    # tracer cannot escape the scan body any other way);
                    # leaves come back with a leading (G,) group axis
                    stats_g[str(i)] = st
                aux_g = {k: aux_g[k] + aux_i[k] for k in AUX_KEYS}
            return constrain(x), new_k, stats_g, aux_g

        if remat == "full":
            group_fn = jax.checkpoint(group_fn, static_argnums=())
        elif remat == "save_dots":
            group_fn = jax.checkpoint(
                group_fn,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        def scan_body(carry, xs):
            x, aux = carry
            x, new_k, stats_g, aux_g = group_fn(x, xs)
            aux = {k: aux[k] + aux_g[k] for k in AUX_KEYS}
            return (x, aux), (new_k, stats_g)

        xs = (seg_params[si], seg_kstate[si], jnp.arange(G))
        (x, aux_tot), (new_k, seg_st) = jax.lax.scan(
            scan_body, (x, aux_tot), xs)
        new_seg_kstate.append(new_k)
        seg_stats.append(seg_st)
        layer_counter += G * len(pattern)
    if any(seg_st for seg_st in seg_stats):
        # list over segments of {layer: RoutingStats}, leaves stacked
        # over scan groups (G, ...); absent entirely when stats are off
        # so the aux pytree (and with it the HLO) is unchanged
        aux_tot = dict(aux_tot)
        aux_tot["routing_stats"] = seg_stats
    return x, new_seg_kstate, aux_tot
