"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/arrays.npz   flattened "path/to/leaf" -> np array
         <dir>/step_<N>/manifest.json  step, loader state, tree metadata
Commit protocol: write into `step_<N>.tmp/`, fsync, then os.rename — a
checkpoint directory either exists completely or not at all; interrupted
saves leave only .tmp garbage that restore ignores and cleanup removes.

Async: `save_async` snapshots to host (device_get) synchronously — cheap —
then writes in a daemon thread; `wait()` joins before the next save so at
most one writer is in flight (bounded memory).

Elastic restore: arrays are stored *unsharded* (gathered); `restore` takes
an optional sharding tree and `jax.device_put`s each leaf with the NEW
sharding — restoring onto a different mesh shape (elastic scale-up/down)
is just a different sharding tree. Restores also work across
dtype-preserving param-structure-identical config tweaks, and across
toggling int8_ef grad compression: missing or device-count-mismatched
`ef_state/*` leaves re-zero instead of failing (the error-feedback
residual is approximation state, zero is always a valid restart).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_kname(k) for k in path)
        out[key] = leaf
    return out, treedef


def _kname(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        flat, _ = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        manifest = {"step": int(step), "keys": sorted(host.keys()),
                    "extra": extra or {}}
        self.wait()                      # at most one writer in flight
        if int(step) in self.all_steps():
            return                       # already committed (final-save dup)
        if blocking:
            self._write(step, host, manifest)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, manifest), daemon=True)
            self._thread.start()

    def save_async(self, step: int, state: Any,
                   extra: Optional[Dict] = None) -> None:
        self.save(step, state, extra, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray],
               manifest: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name,
                                                "manifest.json")):
                steps.append(int(name[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def keys(self, step: Optional[int] = None):
        """The flattened leaf keys a checkpoint holds (from its
        manifest) — lets callers detect the on-disk layout before
        committing to a restore template."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)["keys"]

    def restore(self, state_like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Returns (state, extra). state_like provides the pytree structure
        (arrays or ShapeDtypeStructs); shardings optionally re-shards each
        leaf onto the current mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = _flatten(state_like)
        if shardings is not None:
            shard_flat, _ = _flatten(shardings)
        leaves = []
        for key, like in flat.items():
            # error-feedback residuals (TrainState.ef_state, saved under a
            # field-named dict by the Trainer) are approximation state: a
            # warm start from a pre-compression checkpoint or an elastic
            # mesh change (different device-axis length) re-zeros them
            # instead of failing the restore.
            is_ef = key.split("/", 1)[0] == "ef_state"
            arr = data[key] if key in data else None
            if arr is None or (is_ef and
                               tuple(arr.shape) != tuple(like.shape)):
                if not is_ef:
                    raise KeyError(f"checkpoint missing leaf {key}")
                arr = np.zeros(tuple(like.shape), np.float32)
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} "
                    f"vs expected {like.shape}")
            arr = arr.astype(like.dtype)
            if shardings is not None:
                arr = jax.device_put(arr, shard_flat[key])
            leaves.append(arr)
        keys = list(flat.keys())
        order = {k: i for i, k in enumerate(keys)}
        state = jax.tree_util.tree_unflatten(
            treedef, [leaves[order[k]] for k in keys])
        return state, manifest.get("extra", {})
