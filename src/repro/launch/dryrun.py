import os
_flags = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
          or os.environ.get("XLA_FLAGS"))
if _flags is None:
    _flags = "--xla_force_host_platform_device_count=512"
elif ("xla_force_host_platform_device_count" not in _flags
      and not os.environ.get("REPRO_DRYRUN_XLA_FLAGS")):
    # unrelated ambient XLA_FLAGS (dump dirs etc.): keep them AND the
    # forced device count the dry-run needs
    _flags += " --xla_force_host_platform_device_count=512"
os.environ["XLA_FLAGS"] = _flags
# ^ MUST precede every other import: jax locks the device count on first
# init. An XLA_FLAGS that already forces a device count (the multi-device
# CI lane forces 8) wins over the 512-device dry-run default.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this builds the real step function (train_step / prefill
forward / serve_step), shards it with the production rules (dist/sharding),
lowers against ShapeDtypeStruct stand-ins (zero allocation), compiles for
the 16x16 single-pod and 2x16x16 multi-pod meshes, and records:

  * memory_analysis()  — bytes per device (proves the cell fits v5e HBM)
  * cost_analysis()    — per-device HLO FLOPs / bytes for §Roofline
  * collective bytes   — parsed from the compiled HLO per collective kind

Results append incrementally to benchmarks/dryrun_results.json so the sweep
is resumable. Skips are explicit records, never silent:
  * encoder archs have no decode  -> status "skip_encoder_no_decode"
  * long_500k on pure full-attention archs is impossible natively -> the
    native row is "skip_native_quadratic" AND a routing-enabled variant row
    (the paper's technique) is produced instead.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --cell train_4k --mesh pod
  python -m repro.launch.dryrun --all [--resume]
"""
import argparse
import functools
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCHS, cell_by_name, get_config, input_specs,
                           routing_for_seq, with_routing)
from repro.configs.base import (ModelConfig, RunConfig, TrainConfig,
                                SHAPE_CELLS, with_overrides)
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "dryrun_results.json")

ASSIGNED = [a for a in ARCHS if not a.startswith("rt-")]
FULL_ATTN_ARCHS = {"granite-8b", "qwen2-0.5b", "starcoder2-3b",
                   "phi4-mini-3.8b", "llama4-scout-17b-a16e",
                   "llama4-maverick-400b-a17b", "llama-3.2-vision-11b"}

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _line_collective(line: str):
    for kind in _COLL_KINDS:
        # match "= TYPE kind(" and "= TYPE kind-start("
        if f" {kind}(" in line or f" {kind}-start(" in line:
            lhs = line.split("=", 1)
            if len(lhs) != 2:
                return None
            return kind, _shape_bytes(lhs[1].strip().split(f" {kind}")[0])
    return None


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """HLO text -> {computation_name: body_text}."""
    comps: Dict[str, str] = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                name = m.group(1)
                buf = []
                continue
        if name is not None:
            if line.startswith("}"):
                comps[name] = "\n".join(buf)
                name = None
            else:
                buf.append(line)
    return comps


_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\{?\}? constant\((\d+)\)")


def _trip_count(cond_text: str) -> int:
    """Heuristic: largest s32 scalar constant in the loop condition (lax.scan
    emits `lt counter, constant(G)`)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Loop-aware per-device collective byte accounting.

    XLA emits each while (lax.scan) body as ONE computation executed
    trip-count times; naive line-counting undercounts scanned-layer
    collectives by the group count. We build the while-nesting multiplier
    per computation and weight its collective bytes accordingly.
    """
    comps = _split_computations(hlo_text)
    if not comps:                        # bare snippet (tests)
        comps = {"entry": hlo_text}
    mult = {name: 0.0 for name in comps}
    referenced = set()
    edges: Dict[str, list] = {name: [] for name in comps}
    for name, text in comps.items():
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            edges[name].append((body, trips))
            referenced.add(body)
            referenced.add(cond)
    for name in comps:
        if name not in referenced:
            mult[name] = 1.0
    for _ in range(len(comps)):          # propagate down the nesting DAG
        changed = False
        for name, out_edges in edges.items():
            for body, trips in out_edges:
                new = mult.get(name, 0.0) * trips
                if new > mult.get(body, 0.0):
                    mult[body] = new
                    changed = True
        if not changed:
            break

    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    raw_total = 0
    for name, text in comps.items():
        m = mult.get(name) or 1.0
        for line in text.splitlines():
            hit = _line_collective(line)
            if hit:
                kind, b = hit
                out[kind]["count"] += int(m)
                out[kind]["bytes"] += int(b * m)
                raw_total += b
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["raw_total_bytes"] = raw_total
    return out


# ---------------------------------------------------------------------------
# Per-cell step builders
# ---------------------------------------------------------------------------
SEQ_PARALLEL = os.environ.get("REPRO_SP", "1") == "1"


def _train_cfg(arch: str, cfg: ModelConfig, cell) -> TrainConfig:
    big = cfg.param_count() > 20e9
    accum = {"llama4-maverick-400b-a17b": 4,
             "llama4-scout-17b-a16e": 2}.get(arch, 1)
    if not SEQ_PARALLEL:
        accum = max(accum, 4)   # bound activation carries without SP
    return TrainConfig(
        global_batch=cell.global_batch, seq_len=cell.seq_len,
        optimizer="adafactor" if big else "adam",
        remat="full",
        grad_accum=accum,
        accum_dtype="bfloat16" if cfg.param_count() > 200e9 else "float32")


FSDP_THRESHOLD = 20e9   # below this, params fit replicated-over-data +
                        # TP and per-layer weight all-gathers are pure waste
                        # (quantified in EXPERIMENTS.md §Perf: granite-8b
                        # train collective bytes drop ~3x without FSDP)


def _use_fsdp(cfg: ModelConfig) -> bool:
    return cfg.param_count() > FSDP_THRESHOLD


def build_train(arch: str, cfg: ModelConfig, cell, mesh):
    from repro.train.train_step import init_train_state, make_train_step
    run = RunConfig(model=cfg, train=_train_cfg(arch, cfg, cell))
    # deliberately NO attn_specs here: the dryrun is a cost explorer and
    # must be able to price seq-parallel layouts that global routing
    # would re-gather under (launch/train.py is where the
    # attn.seq_shardable validation refuses them for real runs)
    constrain = shd.make_constrain_fn(mesh, seq_parallel=SEQ_PARALLEL)
    ts_shapes = jax.eval_shape(
        functools.partial(init_train_state, run), jax.random.PRNGKey(0))
    batch = input_specs(cfg, cell)
    ts_spec = shd.train_state_sharding(mesh, ts_shapes,
                                       fsdp=_use_fsdp(cfg))

    def grad_constrain(grads):
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, ts_spec.params)

    fn = make_train_step(run, constrain_fn=constrain,
                         grad_constrain=grad_constrain, mesh=mesh)
    b_spec = shd.batch_sharding(mesh, batch)
    metrics_shape = jax.eval_shape(fn, ts_shapes, batch)[1]
    m_spec = shd.replicated(mesh, metrics_shape)
    jfn = jax.jit(fn, in_shardings=(ts_spec, b_spec),
                  out_shardings=(ts_spec, m_spec), donate_argnums=(0,))
    return jfn, (ts_shapes, batch)


def build_prefill(arch: str, cfg: ModelConfig, cell, mesh):
    from repro.models.model import init_model, apply_model

    def forward(params, kstate, batch):
        logits, _, _ = apply_model(
            params, kstate, batch, cfg, update_state=False,
            # unvalidated SP on purpose — see build_train's constrain note
            constrain_fn=shd.make_constrain_fn(mesh, seq_parallel=True),
            mesh=mesh)
        return logits

    pk = jax.eval_shape(functools.partial(init_model, cfg),
                        jax.random.PRNGKey(0))
    p_shapes, k_shapes = pk
    batch = input_specs(cfg, cell)
    p_spec = shd.params_sharding(mesh, p_shapes, fsdp=_use_fsdp(cfg))
    k_spec = shd.replicated(mesh, k_shapes)
    b_spec = shd.batch_sharding(mesh, batch)
    dp = shd.dp_axes(mesh)
    B = cell.global_batch
    v_ok = cfg.padded_vocab % shd._axis_size(mesh, "model") == 0
    lg_spec = NamedSharding(mesh, P(
        dp if B % shd._axis_size(mesh, dp) == 0 else None, None,
        "model" if v_ok else None))
    jfn = jax.jit(forward, in_shardings=(p_spec, k_spec, b_spec),
                  out_shardings=lg_spec)
    return jfn, (p_shapes, k_shapes, batch)


def build_decode(arch: str, cfg: ModelConfig, cell, mesh):
    from repro.models.model import init_model
    from repro.serve.serving import init_cache, make_serve_step

    fn = make_serve_step(cfg)
    pk = jax.eval_shape(functools.partial(init_model, cfg),
                        jax.random.PRNGKey(0))
    p_shapes, k_shapes = pk
    B = cell.global_batch
    cache_shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, B, cell.seq_len))
    specs = input_specs(cfg, cell)
    tokens, pos = specs["tokens"], specs["pos"]
    p_spec = shd.params_sharding(mesh, p_shapes, fsdp=_use_fsdp(cfg))
    k_spec = shd.replicated(mesh, k_shapes)
    c_spec = shd.cache_sharding(mesh, cache_shapes, B)
    dp = shd.dp_axes(mesh)
    b_ok = B % shd._axis_size(mesh, dp) == 0
    v_ok = cfg.padded_vocab % shd._axis_size(mesh, "model") == 0
    t_spec = NamedSharding(mesh, P(dp if b_ok else None))
    lg_spec = NamedSharding(mesh, P(dp if b_ok else None,
                                    "model" if v_ok else None))
    jfn = jax.jit(fn, in_shardings=(p_spec, k_spec, c_spec, t_spec, t_spec),
                  out_shardings=(lg_spec, c_spec), donate_argnums=(2,))
    return jfn, (p_shapes, k_shapes, cache_shapes, tokens, pos)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------
def cell_config(arch: str, cell_name: str, variant: str) -> ModelConfig:
    cfg = get_config(arch)
    cell = cell_by_name(cell_name)
    if variant == "routing":
        # decode keeps global cluster geometry (the paged cache shards kc
        # across the mesh); train/prefill >=32k use shard-local segments
        cfg = routing_for_seq(with_routing(cfg), cell.seq_len,
                              segments=1 if cell.kind == "decode" else 0)
    # memory-efficient chunked attention (the XLA stand-in for the flash
    # kernel): bounds fp32 logits at (B, H, N, chunk) instead of (.., N)
    if cell.seq_len >= 4096 and cfg.attention == "full":
        cfg = with_overrides(cfg, attn_chunk=2048 if cell.seq_len >= 32768
                             else 1024)
    return cfg


def cell_status(arch: str, cell_name: str, variant: str) -> str:
    cfg = get_config(arch)
    cell = cell_by_name(cell_name)
    if cfg.family == "encoder" and cell.kind == "decode":
        return "skip_encoder_no_decode"
    if (cell_name == "long_500k" and variant == "native"
            and arch in FULL_ATTN_ARCHS):
        return "skip_native_quadratic(run routing variant instead)"
    if variant == "routing" and cfg.family == "ssm":
        return "skip_routing_inapplicable_ssm"
    return "run"


def analyze(compiled) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # jaxlib <= 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    return {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_device_bytes": int(ma.argument_size_in_bytes
                                 + ma.output_size_in_bytes
                                 + ma.temp_size_in_bytes
                                 - ma.alias_size_in_bytes),
        "collectives": collective_bytes(hlo),
        "hlo_bytes": len(hlo),
    }


def run_cell(arch: str, cell_name: str, mesh_kind: str,
             variant: str = "native") -> Dict[str, Any]:
    rec: Dict[str, Any] = {"arch": arch, "cell": cell_name,
                           "mesh": mesh_kind, "variant": variant}
    status = cell_status(arch, cell_name, variant)
    rec["status"] = status
    if status != "run":
        return rec
    cell = cell_by_name(cell_name)
    cfg = cell_config(arch, cell_name, variant)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    builder = {"train": build_train, "prefill": build_prefill,
               "decode": build_decode}[cell.kind]
    t0 = time.time()
    try:
        with mesh:
            jfn, args = builder(arch, cfg, cell, mesh)
            lowered = jfn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            rec.update(analyze(compiled))
            rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


# ---------------------------------------------------------------------------
# Sweep
# ---------------------------------------------------------------------------
def load_results() -> Dict[str, Any]:
    path = os.path.abspath(RESULTS)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(res: Dict[str, Any]) -> None:
    path = os.path.abspath(RESULTS)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def cell_key(arch, cell, mesh, variant):
    return f"{arch}|{cell}|{mesh}|{variant}"


def all_cells(meshes=("pod", "multipod")):
    for arch in ASSIGNED:
        for cell in SHAPE_CELLS:
            for mesh in meshes:
                yield arch, cell.name, mesh, "native"
                # routing variant where it is the only way to run the cell
                if cell.name == "long_500k" and arch in FULL_ATTN_ARCHS:
                    yield arch, cell.name, mesh, "routing"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--variant", default="native",
                    choices=["native", "routing"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    res = load_results()    # always merge into existing records
    if args.all:
        todo = list(all_cells())
    else:
        todo = [(args.arch, args.cell, args.mesh, args.variant)]
    for arch, cell, mesh, variant in todo:
        key = cell_key(arch, cell, mesh, variant)
        prev = res.get(key, {}).get("status", "")
        if args.resume and (prev == "ok" or prev.startswith("skip")):
            print(f"[cached] {key}: {prev}")
            continue
        print(f"[run] {key} ...", flush=True)
        rec = run_cell(arch, cell, mesh, variant)
        res[key] = rec
        save_results(res)
        extra = ""
        if rec["status"] == "ok":
            extra = (f" peak={rec['peak_device_bytes']/2**30:.2f}GiB"
                     f" flops/dev={rec['flops_per_device']:.3g}"
                     f" coll={rec['collectives']['total_bytes']/2**30:.3f}GiB"
                     f" ({rec['total_s']}s)")
        elif rec["status"] == "error":
            extra = " ERROR " + rec["error"][:200]
        print(f"[done] {key}: {rec['status']}{extra}", flush=True)


if __name__ == "__main__":
    main()
