"""Production mesh builders. Functions, not module constants, so importing
never touches jax device state (the dry-run must set XLA_FLAGS first)."""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def feasible_mesh_shape(n: int, data: int, model: int) -> Tuple[int, int]:
    """Largest (data, model) grid that fits on ``n`` devices.

    When the request fits, it is returned unchanged. When it oversubscribes,
    the model axis is preserved as far as possible — clamped to the largest
    divisor of ``n`` not exceeding the request — and data fills the rest,
    instead of silently dropping model parallelism altogether.
    """
    if data * model <= n:
        return data, model
    model = max(m for m in range(1, min(model, n) + 1) if n % m == 0)
    return n // model, model


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data, model = feasible_mesh_shape(n, data, model)
    return jax.make_mesh((data, model), ("data", "model"))
