"""Multi-host launch scaffolding: `jax.distributed.initialize` wiring
(DESIGN.md §7).

One process per host; process 0 doubles as the coordination service.
Discovery is env/flag-driven (flags override env):

  REPRO_COORDINATOR    host:port of process 0's coordinator service
  REPRO_NUM_PROCESSES  total number of launched processes
  REPRO_PROCESS_ID     this process's rank in [0, num_processes)

When nothing is configured, `initialize()` is a no-op single-process
fallback — laptops, CI, and every test run exactly the code path a real
fleet runs, minus the coordinator handshake. `jax.distributed.initialize`
MUST run before anything else touches the jax backend (it registers the
global device view), which is why `launch/train.py` calls this before its
first `jax.devices()`.

After initialization, mesh construction goes through the same
`launch/mesh.make_host_mesh` used everywhere else: `jax.make_mesh`
enumerates the GLOBAL device set, so the per-process code is identical on
one host and on sixty-four.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Mapping, Optional

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"


@dataclass(frozen=True)
class LaunchSpec:
    """A validated multi-process launch description."""
    coordinator: str            # "host:port" of process 0
    num_processes: int
    process_id: int

    def __post_init__(self):
        if self.num_processes < 1:
            raise ValueError(f"num_processes must be >= 1, "
                             f"got {self.num_processes}")
        if not 0 <= self.process_id < self.num_processes:
            raise ValueError(f"process_id {self.process_id} outside "
                             f"[0, {self.num_processes})")
        if self.num_processes > 1 and ":" not in self.coordinator:
            raise ValueError("multi-process launch needs a host:port "
                             f"coordinator, got {self.coordinator!r}")


def detect(env: Optional[Mapping[str, str]] = None, *,
           coordinator: Optional[str] = None,
           num_processes: Optional[int] = None,
           process_id: Optional[int] = None) -> Optional[LaunchSpec]:
    """Build a LaunchSpec from explicit flags, falling back to env vars.

    Returns None when nothing is configured (the single-process
    fallback); raises on half-configured launches so a typo'd env never
    silently trains on 1/N of the fleet.
    """
    env = os.environ if env is None else env
    coordinator = coordinator or env.get(ENV_COORDINATOR, "")
    if num_processes is None:
        num_processes = int(env.get(ENV_NUM_PROCESSES, "0") or 0)
    if process_id is None:
        # "" counts as unset: REPRO_PROCESS_ID=$RANK with $RANK unset
        # must hit the explicit-rank error, not a bare int('') crash
        raw = env.get(ENV_PROCESS_ID, "")
        process_id = int(raw) if raw != "" else None
    if not coordinator and num_processes <= 1:
        return None
    if not coordinator:
        raise ValueError(f"{ENV_NUM_PROCESSES}={num_processes} but no "
                         f"coordinator address ({ENV_COORDINATOR})")
    if num_processes < 1:
        raise ValueError(f"coordinator {coordinator!r} set but "
                         f"{ENV_NUM_PROCESSES} missing")
    if process_id is None:
        # defaulting to rank 0 would make EVERY host claim process 0 and
        # hang the coordinator handshake — fail fast instead
        raise ValueError(f"multi-process launch needs an explicit rank "
                         f"({ENV_PROCESS_ID} or --process-id)")
    return LaunchSpec(coordinator, num_processes, process_id)


def initialize(spec: Optional[LaunchSpec] = None,
               env: Optional[Mapping[str, str]] = None, **detect_kw) -> bool:
    """Initialize `jax.distributed` when a launch is configured.

    Call before any other jax API. Returns True when multi-process
    initialization ran, False on the single-process fallback (no jax
    backend state is touched in that case).
    """
    if spec is None:
        spec = detect(env, **detect_kw)
    if spec is None or spec.num_processes <= 1:
        return False
    import jax
    jax.distributed.initialize(coordinator_address=spec.coordinator,
                               num_processes=spec.num_processes,
                               process_id=spec.process_id)
    return True


def process_info() -> dict:
    """Rank/host-count view after (maybe-)initialization, for logging."""
    import jax
    return {"process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "local_devices": jax.local_device_count(),
            "global_devices": jax.device_count()}


def make_process_mesh(data: int = 1, model: int = 1):
    """Mesh over the GLOBAL device view (call after `initialize`).

    Delegates to `launch/mesh.make_host_mesh`, which clamps the request
    to the largest feasible (data, model) grid — identical semantics for
    a laptop, a CI runner, and a multi-host fleet.
    """
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(data, model)
