"""Distributed training launcher: mesh + sharding rules + Trainer.

On real hardware this runs under `jax.distributed.initialize()` per host;
here it drives any `--arch` on whatever devices exist (use
XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the sharded
path on CPU).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 100 --mesh 2x4
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced_config
from repro.configs.base import RunConfig, TrainConfig, with_overrides
from repro.data.synthetic import SyntheticLoader
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.train.train_step import init_train_state, make_train_step
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="", help="DxM, e.g. 2x4 (default: "
                                               "all devices as data)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = with_overrides(cfg, dtype="float32")
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=args.batch, seq_len=args.seq, steps=args.steps,
        lr=1e-3, schedule="linear_warmup_rsqrt", warmup_steps=20))

    n = len(jax.devices())
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = n, 1
    mesh = make_host_mesh(d, m)      # clamps oversubscribed requests
    d, m = mesh.shape["data"], mesh.shape["model"]
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh=({d}x{m}) devices={n}")

    ts_shapes = jax.eval_shape(
        functools.partial(init_train_state, run), jax.random.PRNGKey(0))
    ts_spec = shd.train_state_sharding(mesh, ts_shapes,
                                       fsdp=cfg.param_count() > 20e9)
    constrain = shd.make_constrain_fn(mesh, args.seq_parallel)
    fn = make_train_step(run, constrain_fn=constrain)

    def pinned_fn(ts, batch):
        # pin the output state to the rule layout so it round-trips into
        # the next step's in_shardings (GSPMD would otherwise pick its own
        # layout for unconstrained outputs, e.g. scanned norm scales)
        new_ts, metrics = fn(ts, batch)
        new_ts = jax.tree.map(jax.lax.with_sharding_constraint,
                              new_ts, ts_spec)
        return new_ts, metrics

    def sharded_step(ts, batch):
        b_spec = shd.batch_sharding(mesh, batch)
        batch = jax.device_put(batch, b_spec)
        return jax.jit(pinned_fn, in_shardings=(ts_spec, b_spec),
                       donate_argnums=(0,))(ts, batch)

    loader = SyntheticLoader("markov", min(cfg.vocab_size, 512),
                             args.batch, args.seq)
    with mesh:
        ts = jax.device_put(init_train_state(run, jax.random.PRNGKey(0)),
                            ts_spec)
        tr = Trainer(run, loader, ckpt_dir=args.ckpt_dir, mesh=mesh,
                     shardings=ts_spec, step_fn=sharded_step)
        tr.state = ts
        out = tr.fit(args.steps)
    hist = tr.metrics_history
    if hist:
        print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(out)


if __name__ == "__main__":
    main()
