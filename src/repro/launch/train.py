"""Distributed training launcher: mesh + sharding rules + Trainer.

Multi-host: each host runs this module once; coordinator discovery is
env/flag-driven (launch/distributed.py, DESIGN.md §7). Single-process
runs — laptops, CI — take the same path through the no-op fallback (use
XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the
sharded path on CPU).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 100 --mesh 2x4

  # int8 error-feedback gradient compression (data-parallel shard_map)
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 100 --grad-compression int8_ef

  # two-host launch (per host; coordinator = host 0)
  REPRO_COORDINATOR=host0:9876 REPRO_NUM_PROCESSES=2 REPRO_PROCESS_ID=$RANK \
      python -m repro.launch.train --arch granite-8b --mesh 8x2
"""
from __future__ import annotations

import argparse
import functools

from repro.launch import distributed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="", help="DxM, e.g. 2x4 (default: "
                                               "all devices as data)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--grad-compression", default="none",
                    help="one of configs.base.GRAD_COMPRESSION_MODES; "
                         "int8_ef: error-feedback int8 gradient exchange "
                         "(data-parallel shard_map path); validated by "
                         "TrainConfig after the deferred imports")
    ap.add_argument("--obs-jsonl", default=None,
                    help="append per-step metric records (schema v1 JSONL, "
                         "validated by `python -m repro.obs.schema`)")
    ap.add_argument("--routing-stats", action="store_true",
                    help="compute routing-health telemetry (occupancy "
                         "entropy, dead clusters, centroid drift, sampled "
                         "attention recall) inside the jitted step; off by "
                         "default — stats-off compiles byte-identical HLO")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax profiler trace of the whole run "
                         "into this directory (TensorBoard/Perfetto)")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 (or $REPRO_COORDINATOR)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total launched processes (or $REPRO_NUM_PROCESSES)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank (or $REPRO_PROCESS_ID)")
    args = ap.parse_args()

    # before ANY other jax API: registers the global device view
    multi = distributed.initialize(coordinator=args.coordinator,
                                   num_processes=args.num_processes,
                                   process_id=args.process_id)

    import jax
    import jax.numpy as jnp  # noqa: F401  (kept for parity with examples)

    from repro.configs import ARCHS, get_config, reduced_config
    from repro.configs.base import RunConfig, TrainConfig, with_overrides
    from repro.data.synthetic import SyntheticLoader
    from repro.dist import sharding as shd
    from repro.train.train_step import init_train_state, make_train_step
    from repro.train.trainer import Trainer

    if args.arch not in ARCHS:
        ap.error(f"unknown --arch {args.arch}; choices: {sorted(ARCHS)}")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = with_overrides(cfg, dtype="float32")
    if args.routing_stats:
        cfg = with_overrides(
            cfg, routing=with_overrides(cfg.routing, stats=True))
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=args.batch, seq_len=args.seq, steps=args.steps,
        lr=1e-3, schedule="linear_warmup_rsqrt", warmup_steps=20,
        grad_compression=args.grad_compression))

    n = jax.device_count()
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = n, 1
    compressed = run.train.grad_compression == "int8_ef"
    if compressed and m > 1:
        ap.error("--grad-compression int8_ef is data-parallel only; "
                 "use --mesh Dx1")
    if compressed and args.seq_parallel:
        ap.error("--seq-parallel needs the GSPMD path; drop it or use "
                 "--grad-compression none")
    mesh = distributed.make_process_mesh(d, m)   # clamps oversubscription
    d, m = mesh.shape["data"], mesh.shape["model"]
    info = distributed.process_info()
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh=({d}x{m}) devices={n} "
          f"process={info['process_index']}/{info['process_count']} "
          f"multi_host={multi} compression={run.train.grad_compression}")

    use_fsdp = cfg.param_count() > 20e9
    ts_shapes = jax.eval_shape(
        functools.partial(init_train_state, run, mesh=mesh),
        jax.random.PRNGKey(0))
    ts_spec = shd.train_state_sharding(mesh, ts_shapes, fsdp=use_fsdp)
    from repro.attn import specs_for_model
    constrain = (None if compressed else shd.make_constrain_fn(
        mesh, args.seq_parallel, fsdp_prefetch=use_fsdp,
        attn_specs=specs_for_model(cfg)))
    fn = make_train_step(run, constrain_fn=constrain, mesh=mesh)

    def pinned_fn(ts, batch):
        # pin the output state to the rule layout so it round-trips into
        # the next step's in_shardings (GSPMD would otherwise pick its own
        # layout for unconstrained outputs, e.g. scanned norm scales)
        new_ts, metrics = fn(ts, batch)
        new_ts = jax.tree.map(jax.lax.with_sharding_constraint,
                              new_ts, ts_spec)
        return new_ts, metrics

    def sharded_step(ts, batch):
        b_spec = shd.batch_sharding(mesh, batch)
        batch = jax.device_put(batch, b_spec)
        return jax.jit(pinned_fn, in_shardings=(ts_spec, b_spec),
                       donate_argnums=(0,))(ts, batch)

    loader = SyntheticLoader("markov", min(cfg.vocab_size, 512),
                             args.batch, args.seq)
    from repro.obs import trace as obs_trace
    with mesh:
        tr = Trainer(run, loader, ckpt_dir=args.ckpt_dir, mesh=mesh,
                     shardings=ts_spec, step_fn=sharded_step,
                     obs_jsonl=args.obs_jsonl)
        tr.init_or_restore()   # fresh: sharded init; ckpt: elastic resume
        with obs_trace.profile(args.profile_dir):
            out = tr.fit(args.steps)
        tr.close()
    hist = tr.metrics_history
    if hist:
        print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(out)


if __name__ == "__main__":
    main()
