"""Deterministic synthetic data pipeline.

Offline container: no datasets on disk, so the pipeline generates
deterministic, *learnable* token streams — the training loop, checkpointing
of iterator state, and loss-decrease integration tests all run against it.

Tasks:
  * `markov`   — order-1 Markov chain with a Zipfian, seed-derived transition
                 table; has real mutual information so LM loss decreases.
  * `copy`     — prefix + delimiter + repeat-prefix. Content-based lookup:
                 exactly the access pattern routing attention exploits
                 (used by the paper-mechanism tests).
  * `uniform`  — i.i.d. uniform tokens (throughput benchmarks).

Every batch is a pure function of (seed, step) — the loader's checkpoint
state is just the step counter, which makes restart-equivalence exact.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def markov_table(vocab: int, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    # sparse-ish rows: each state transitions mostly to a few successors
    logits = rng.gumbel(size=(vocab, vocab)) * 2.0
    tbl = np.exp(logits - logits.max(1, keepdims=True))
    return (tbl / tbl.sum(1, keepdims=True)).astype(np.float64)


def markov_batch(vocab: int, batch: int, seq: int, seed: int,
                 step: int) -> np.ndarray:
    rng = np.random.RandomState((seed * 1_000_003 + step) % (2 ** 31))
    tbl = markov_table(vocab, seed)
    cum = np.cumsum(tbl, axis=1)
    toks = np.zeros((batch, seq), np.int32)
    toks[:, 0] = rng.randint(0, vocab, size=batch)
    u = rng.random_sample((batch, seq))
    for t in range(1, seq):
        toks[:, t] = (cum[toks[:, t - 1]] < u[:, t:t + 1]).sum(1)
    return toks


def copy_batch(vocab: int, batch: int, seq: int, seed: int,
               step: int) -> np.ndarray:
    rng = np.random.RandomState((seed * 7_777_777 + step) % (2 ** 31))
    half = (seq - 1) // 2
    prefix = rng.randint(2, vocab, size=(batch, half)).astype(np.int32)
    delim = np.ones((batch, 1), np.int32)       # token 1 = delimiter
    out = np.concatenate([prefix, delim, prefix], axis=1)
    if out.shape[1] < seq:
        pad = np.zeros((batch, seq - out.shape[1]), np.int32)
        out = np.concatenate([out, pad], axis=1)
    return out[:, :seq]


def uniform_batch(vocab: int, batch: int, seq: int, seed: int,
                  step: int) -> np.ndarray:
    rng = np.random.RandomState((seed * 31 + step) % (2 ** 31))
    return rng.randint(0, vocab, size=(batch, seq)).astype(np.int32)


_TASKS = {"markov": markov_batch, "copy": copy_batch, "uniform": uniform_batch}


class SyntheticLoader:
    """Deterministic loader; `state()`/`restore()` checkpoint the cursor."""

    def __init__(self, task: str, vocab: int, batch: int, seq: int,
                 seed: int = 0, start_step: int = 0):
        self.fn = _TASKS[task]
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.step = start_step

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, st: Dict) -> None:
        self.step = int(st["step"])
        self.seed = int(st["seed"])

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        toks = self.fn(self.vocab, self.batch, self.seq + 1, self.seed,
                       self.step)
        self.step += 1
        return {"tokens": toks}
