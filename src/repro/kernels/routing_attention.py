"""Routed (intra-cluster) attention — Pallas TPU kernel. THE paper hot-spot.

Stage 2 of the two-stage TPU adaptation (DESIGN.md §3): assignment/top-k/
gather stay in XLA; this kernel computes the O(k·w²·d) attention over the
*gathered* cluster blocks with flash-style streaming, so no (w x w) matrix
ever reaches HBM.

Inputs are the gathered blocks (B,H,k,w,dh) plus the original sequence
positions of every gathered row. The causal mask compares those gathered
positions (pos_q >= pos_k) — this is what makes cluster blocks order-correct
— and invalid (padding) keys are encoded by the caller as pos_k = _SENTINEL,
which the same comparison masks out for free.

Grid: (B·H·k clusters, w/bq, w/bk) with the KV axis sequential; (m, l, acc)
scratch in VMEM. MXU-aligned: bq = bk = 128 default, dh in {64, 128, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG = -1e9
SENTINEL = 2 ** 30          # python int: usable inside the kernel body


def _kernel(q_ref, k_ref, v_ref, pq_ref, pk_ref, o_ref,
            m_ref, l_ref, acc_ref, *, causal, scale):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    pq = pq_ref[0]                                    # (bq,) int32
    pk = pk_ref[0]                                    # (bk,) int32
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if causal:
        keep = pq[:, None] >= pk[None, :]
    else:
        keep = (pk < SENTINEL)[None, :] & jnp.ones_like(s, bool)
    s = jnp.where(keep, s, _NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def routed_attention_blocks(qg, kg, vg, pos_q, pos_k, causal=True,
                            valid_k=None, bq=128, bk=128,
                            interpret=True):
    """qg/kg/vg: (B,H,k,w,dh); pos_q/pos_k: (B,H,k,w) -> (B,H,k,w,dh)."""
    B, H, kc, w, dh = qg.shape
    bq = min(bq, w)
    bk = min(bk, w)
    assert w % bq == 0 and w % bk == 0, (w, bq, bk)
    n = B * H * kc
    qf = qg.reshape(n, w, dh)
    kf = kg.reshape(n, w, dh)
    vf = vg.reshape(n, w, dh)
    pqf = pos_q.reshape(n, w).astype(jnp.int32)
    pkf = pos_k.reshape(n, w).astype(jnp.int32)
    if valid_k is not None:
        pkf = jnp.where(valid_k.reshape(n, w), pkf, SENTINEL)

    grid = (n, w // bq, w // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=1.0 / (dh ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda c, iq, ik: (c, iq, 0)),
            pl.BlockSpec((1, bk, dh), lambda c, iq, ik: (c, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda c, iq, ik: (c, ik, 0)),
            pl.BlockSpec((1, bq), lambda c, iq, ik: (c, iq)),
            pl.BlockSpec((1, bk), lambda c, iq, ik: (c, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda c, iq, ik: (c, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w, dh), qg.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, pqf, pkf)
    return out.reshape(B, H, kc, w, dh)
