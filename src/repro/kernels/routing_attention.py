"""Routed (intra-cluster) attention — Pallas TPU kernels. THE paper hot-spot.

Two kernels implement stage 2 of the TPU adaptation (DESIGN.md §3, §9):

``routed_attention_blocks`` — the original *gathered* kernel: XLA
materializes (B,H,k,w,dh) copies of q/k/v (three HBM round-trips of the
whole sequence, four in shared-QK mode before the dedupe) and the kernel
streams the cluster blocks with flash-style online softmax.

``routed_attention_fused`` — the *gather-free* kernel: q/k/v stay in
sequence layout (B,H,N,dh); the (B,H,k,w) membership indices ride in as
scalar-prefetch operands (``PrefetchScalarGridSpec``, SMEM), the per-
(batch·head) sequence plane is the kernel's input block, and each grid
step pulls exactly the bq/bk member rows it needs from VMEM — the same
page-table trick TPU paged attention uses, at row granularity. No gathered
(B,H,k,w,dh) q/k/v tensor ever reaches HBM, and shared-QK causal mode
reads keys from the q plane (one VMEM-resident buffer instead of two).
Positions are read from the (B,N) sequence-level arrays through the same
indices, so the causal mask still compares original positions
(pos_q >= pos_k) and padded keys arrive pre-encoded as pos = SENTINEL.

The fused kernel has two memory plans behind one entry point
(``paged=None`` auto-switches on the ``FUSED_RESIDENT_ELEMS`` budget):

* *unpaged* — the sequence plane is the kernel's input block (whole
  (N, dh) plane resident in VMEM, one bulk DMA per batch·head). Fastest
  while the plane fits; refuses nothing but wastes nothing either.
* *paged* — q/k/v stay in HBM (``memory_space=ANY``); every grid step
  pulls exactly the bq/bk member rows of its cluster tile with per-row
  ``make_async_copy`` DMAs into revolving double-buffered VMEM slots
  (tile ik+1's DMAs issue before tile ik's compute runs), so VMEM live
  bytes are O(bq·dh + 4·bk·dh) — independent of N. Membership indices
  AND pre-gathered int32 positions ride in SMEM as scalar-prefetch
  operands (4 B/row, so the causal mask needs no position DMAs). This
  kills the old ``seq_len·head_dim ≈ 1M`` registration cliff: paper-scale
  N=8k–32k runs fused, forward and backward.

Both kernels are differentiable (``jax.custom_vjp``): the forward emits
per-row lse stats (m + log l); the backward recomputes p = exp(s - lse)
tile by tile — no (w x w) matrix is ever stored — and runs a dq kernel
(KV-sequential grid) plus a dk/dv kernel (Q-sequential grid) over the same
cluster-block structure. The fused backward produces per-cluster gradient
blocks and scatter-adds them to sequence layout in XLA (duplicate
memberships accumulate, exactly the transpose of the implicit gather).

Grid: (B·H·k clusters, w/bq, w/bk) gathered; (B·H, k, w/bq, w/bk) fused,
KV axis sequential; (m, l, acc) scratch in VMEM. MXU-aligned: bq = bk =
128 default, dh in {64, 128, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG as _NEG
from repro.kernels.common import CompilerParams as _CompilerParams
from repro.kernels.common import (default_interpret, float0_like,
                                  fused_paged_default)

SENTINEL = 2 ** 30          # python int: usable inside the kernel body


def _keep_mask(pq, pk, causal):
    """Attendable (q row, k row) pairs. Padded keys carry pos = SENTINEL,
    which the causal comparison masks for free; the non-causal branch
    checks the sentinel explicitly."""
    if causal:
        return pq[:, None] >= pk[None, :]
    return jnp.broadcast_to((pk < SENTINEL)[None, :],
                            (pq.shape[0], pk.shape[0]))


# ---------------------------------------------------------------------------
# Gathered kernel (blocks already materialized by XLA)
# ---------------------------------------------------------------------------
def _kernel(q_ref, k_ref, v_ref, pq_ref, pk_ref, o_ref, lse_ref,
            m_ref, l_ref, acc_ref, *, causal, scale):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    pq = pq_ref[0]                                    # (bq,) int32
    pk = pk_ref[0]                                    # (bk,) int32
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    keep = _keep_mask(pq, pk, causal)
    s = jnp.where(keep, s, _NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def _g_dq_kernel(q_ref, k_ref, v_ref, pq_ref, pk_ref, do_ref, lse_ref,
                 dsum_ref, dq_ref, dq_acc, *, causal, scale):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    keep = _keep_mask(pq_ref[0], pk_ref[0], causal)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    p = jnp.where(keep, jnp.exp(s - lse_ref[0][:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum_ref[0][:, None]) * scale
    dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0] = dq_acc[...]


def _g_dkv_kernel(q_ref, k_ref, v_ref, pq_ref, pk_ref, do_ref, lse_ref,
                  dsum_ref, dk_ref, dv_ref, dk_acc, dv_acc, *, causal,
                  scale):
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    keep = _keep_mask(pq_ref[0], pk_ref[0], causal)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    p = jnp.where(keep, jnp.exp(s - lse_ref[0][:, None]), 0.0)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum_ref[0][:, None]) * scale
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0] = dk_acc[...]
        dv_ref[0] = dv_acc[...]


def _g_fwd_call(qf, kf, vf, pqf, pkf, causal, bq, bk, interpret):
    n, w, dh = qf.shape
    grid = (n, w // bq, w // bk)
    out, lse = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=1.0 / (dh ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda c, iq, ik: (c, iq, 0)),
            pl.BlockSpec((1, bk, dh), lambda c, iq, ik: (c, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda c, iq, ik: (c, ik, 0)),
            pl.BlockSpec((1, bq), lambda c, iq, ik: (c, iq)),
            pl.BlockSpec((1, bk), lambda c, iq, ik: (c, ik)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda c, iq, ik: (c, iq, 0)),
            pl.BlockSpec((1, bq), lambda c, iq, ik: (c, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, w, dh), qf.dtype),
            jax.ShapeDtypeStruct((n, w), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, pqf, pkf)
    return out, lse


def _g_bwd_call(qf, kf, vf, pqf, pkf, out, lse, do, causal, bq, bk,
                interpret):
    n, w, dh = qf.shape
    dsum = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    scale = 1.0 / (dh ** 0.5)
    params = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
    q_at = lambda c, iq, ik: (c, iq, 0)
    k_at = lambda c, iq, ik: (c, ik, 0)
    rq_at = lambda c, iq, ik: (c, iq)
    rk_at = lambda c, iq, ik: (c, ik)
    dq = pl.pallas_call(
        functools.partial(_g_dq_kernel, causal=causal, scale=scale),
        grid=(n, w // bq, w // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_at),
            pl.BlockSpec((1, bk, dh), k_at),
            pl.BlockSpec((1, bk, dh), k_at),
            pl.BlockSpec((1, bq), rq_at),
            pl.BlockSpec((1, bk), rk_at),
            pl.BlockSpec((1, bq, dh), q_at),
            pl.BlockSpec((1, bq), rq_at),
            pl.BlockSpec((1, bq), rq_at),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), q_at),
        out_shape=jax.ShapeDtypeStruct((n, w, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(qf, kf, vf, pqf, pkf, do, lse, dsum)

    # swapped grid: key tile parallel, query sweep sequential
    q_at2 = lambda c, ik, iq: (c, iq, 0)
    k_at2 = lambda c, ik, iq: (c, ik, 0)
    rq_at2 = lambda c, ik, iq: (c, iq)
    rk_at2 = lambda c, ik, iq: (c, ik)
    dk, dv = pl.pallas_call(
        functools.partial(_g_dkv_kernel, causal=causal, scale=scale),
        grid=(n, w // bk, w // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_at2),
            pl.BlockSpec((1, bk, dh), k_at2),
            pl.BlockSpec((1, bk, dh), k_at2),
            pl.BlockSpec((1, bq), rq_at2),
            pl.BlockSpec((1, bk), rk_at2),
            pl.BlockSpec((1, bq, dh), q_at2),
            pl.BlockSpec((1, bq), rq_at2),
            pl.BlockSpec((1, bq), rq_at2),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, dh), k_at2),
            pl.BlockSpec((1, bk, dh), k_at2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, w, dh), jnp.float32),
            jax.ShapeDtypeStruct((n, w, dh), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, dh), jnp.float32),
            pltpu.VMEM((bk, dh), jnp.float32),
        ],
        compiler_params=params,
        interpret=interpret,
    )(qf, kf, vf, pqf, pkf, do, lse, dsum)
    return (dq.astype(qf.dtype), dk.astype(kf.dtype), dv.astype(vf.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _routed_gathered(causal, bq, bk, interpret, qf, kf, vf, pqf, pkf):
    out, _ = _g_fwd_call(qf, kf, vf, pqf, pkf, causal, bq, bk, interpret)
    return out


def _routed_gathered_fwd(causal, bq, bk, interpret, qf, kf, vf, pqf, pkf):
    out, lse = _g_fwd_call(qf, kf, vf, pqf, pkf, causal, bq, bk, interpret)
    return out, (qf, kf, vf, pqf, pkf, out, lse)


def _routed_gathered_bwd(causal, bq, bk, interpret, res, do):
    qf, kf, vf, pqf, pkf, out, lse = res
    dq, dk, dv = _g_bwd_call(qf, kf, vf, pqf, pkf, out, lse, do, causal,
                             bq, bk, interpret)
    return dq, dk, dv, float0_like(pqf), float0_like(pkf)


_routed_gathered.defvjp(_routed_gathered_fwd, _routed_gathered_bwd)


def routed_attention_blocks(qg, kg, vg, pos_q, pos_k, causal=True,
                            valid_k=None, bq=128, bk=128,
                            interpret=None):
    """qg/kg/vg: (B,H,k,w,dh); pos_q/pos_k: (B,H,k,w) -> (B,H,k,w,dh).

    Differentiable (custom flash-style VJP). ``interpret=None`` derives
    from the platform (compiled on TPU, interpret elsewhere)."""
    B, H, kc, w, dh = qg.shape
    bq = min(bq, w)
    bk = min(bk, w)
    assert w % bq == 0 and w % bk == 0, (w, bq, bk)
    n = B * H * kc
    qf = qg.reshape(n, w, dh)
    kf = kg.reshape(n, w, dh)
    vf = vg.reshape(n, w, dh)
    pqf = pos_q.reshape(n, w).astype(jnp.int32)
    pkf = pos_k.reshape(n, w).astype(jnp.int32)
    if valid_k is not None:
        pkf = jnp.where(valid_k.reshape(n, w), pkf, SENTINEL)
    out = _routed_gathered(bool(causal), int(bq), int(bk),
                           default_interpret(interpret), qf, kf, vf, pqf,
                           pkf)
    return out.reshape(B, H, kc, w, dh)


# ---------------------------------------------------------------------------
# Fused gather-free kernel: sequence-layout q/k/v + scalar-prefetch indices
# ---------------------------------------------------------------------------
def _rows(seq, idx):
    """Pull ``idx`` rows of the VMEM-resident sequence plane. Mosaic
    lowers the sublane gather via dynamic_gather (one-row DMAs on older
    toolchains); indices are always < N so clip never fires."""
    return jnp.take(seq, idx, axis=0, mode="clip")


def _f_fwd_kernel(qi_ref, ki_ref, *refs, shared, causal, scale, bq, bk):
    if shared:
        (q_ref, v_ref, pq_ref, pk_ref, o_ref, lse_ref,
         qt_ref, pqt_ref, m_ref, l_ref, acc_ref) = refs
        k_ref = q_ref
    else:
        (q_ref, k_ref, v_ref, pq_ref, pk_ref, o_ref, lse_ref,
         qt_ref, pqt_ref, m_ref, l_ref, acc_ref) = refs
    b = pl.program_id(0)
    c = pl.program_id(1)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        qidx = qi_ref[b, c, pl.ds(iq * bq, bq)]
        qt_ref[...] = _rows(q_ref[0], qidx).astype(jnp.float32)
        pqt_ref[...] = _rows(pq_ref[0], qidx)

    kidx = ki_ref[b, c, pl.ds(ik * bk, bk)]
    k = _rows(k_ref[0], kidx).astype(jnp.float32)
    v = _rows(v_ref[0], kidx).astype(jnp.float32)
    pk = _rows(pk_ref[0], kidx)
    q = qt_ref[...]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    keep = _keep_mask(pqt_ref[...], pk, causal)
    s = jnp.where(keep, s, _NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def _f_dq_kernel(qi_ref, ki_ref, *refs, shared, causal, scale, bq, bk):
    if shared:
        (q_ref, v_ref, pq_ref, pk_ref, do_ref, lse_ref, dsum_ref,
         dq_ref, qt_ref, pqt_ref, dq_acc) = refs
        k_ref = q_ref
    else:
        (q_ref, k_ref, v_ref, pq_ref, pk_ref, do_ref, lse_ref, dsum_ref,
         dq_ref, qt_ref, pqt_ref, dq_acc) = refs
    b = pl.program_id(0)
    c = pl.program_id(1)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)
        qidx = qi_ref[b, c, pl.ds(iq * bq, bq)]
        qt_ref[...] = _rows(q_ref[0], qidx).astype(jnp.float32)
        pqt_ref[...] = _rows(pq_ref[0], qidx)

    kidx = ki_ref[b, c, pl.ds(ik * bk, bk)]
    k = _rows(k_ref[0], kidx).astype(jnp.float32)
    v = _rows(v_ref[0], kidx).astype(jnp.float32)
    pk = _rows(pk_ref[0], kidx)
    q = qt_ref[...]
    do = do_ref[0, 0].astype(jnp.float32)
    keep = _keep_mask(pqt_ref[...], pk, causal)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    p = jnp.where(keep, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum_ref[0, 0][:, None]) * scale
    dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0, 0] = dq_acc[...]


def _f_dkv_kernel(qi_ref, ki_ref, *refs, shared, causal, scale, bq, bk):
    if shared:
        (q_ref, v_ref, pq_ref, pk_ref, do_ref, lse_ref, dsum_ref,
         dk_ref, dv_ref, kt_ref, vt_ref, pkt_ref, dk_acc, dv_acc) = refs
        k_ref = q_ref
    else:
        (q_ref, k_ref, v_ref, pq_ref, pk_ref, do_ref, lse_ref, dsum_ref,
         dk_ref, dv_ref, kt_ref, vt_ref, pkt_ref, dk_acc, dv_acc) = refs
    b = pl.program_id(0)
    c = pl.program_id(1)
    ik = pl.program_id(2)
    iq = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)
        kidx = ki_ref[b, c, pl.ds(ik * bk, bk)]
        kt_ref[...] = _rows(k_ref[0], kidx).astype(jnp.float32)
        vt_ref[...] = _rows(v_ref[0], kidx).astype(jnp.float32)
        pkt_ref[...] = _rows(pk_ref[0], kidx)

    qidx = qi_ref[b, c, pl.ds(iq * bq, bq)]
    q = _rows(q_ref[0], qidx).astype(jnp.float32)
    pq = _rows(pq_ref[0], qidx)
    do = do_ref[0, 0].astype(jnp.float32)
    k = kt_ref[...]
    v = vt_ref[...]
    keep = _keep_mask(pq, pkt_ref[...], causal)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    p = jnp.where(keep, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum_ref[0, 0][:, None]) * scale
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0, 0] = dk_acc[...]
        dv_ref[0, 0] = dv_acc[...]


def _f_specs(N, dh, H, shared):
    """Common fused in_specs: q [k] v sequence planes + the (B,N)
    position arrays — all index maps ignore the cluster/tile axes (the
    plane is revisited across every step of its (batch·head)) and take
    the two trailing scalar-prefetch refs as *_."""
    plane = lambda b, c, i2, i3, *_: (b, 0, 0)
    posp = lambda b, c, i2, i3, *_: (b // H, 0)
    specs = [pl.BlockSpec((1, N, dh), plane)]          # q
    if not shared:
        specs.append(pl.BlockSpec((1, N, dh), plane))  # k
    specs.append(pl.BlockSpec((1, N, dh), plane))      # v
    specs += [pl.BlockSpec((1, N), posp),              # pos_q (B,N)
              pl.BlockSpec((1, N), posp)]              # pos_k (B,N)
    return specs


def _f_q_blk(bq, dh):
    at = lambda b, c, iq, ik, *_: (b, c, iq, 0)
    rat = lambda b, c, iq, ik, *_: (b, c, iq)
    return (pl.BlockSpec((1, 1, bq, dh), at), pl.BlockSpec((1, 1, bq), rat))


def _f_q_blk_swapped(bq, dh):
    at = lambda b, c, ik, iq, *_: (b, c, iq, 0)
    rat = lambda b, c, ik, iq, *_: (b, c, iq)
    return (pl.BlockSpec((1, 1, bq, dh), at), pl.BlockSpec((1, 1, bq), rat))


def _f_fwd_call(qf, kf, vf, qi, ki, posq, posk, shared, causal, bq, bk, H,
                interpret):
    BH, N, dh = qf.shape
    _, kc, w = qi.shape
    nq, nk = w // bq, w // bk
    oq_at, olse_at = _f_q_blk(bq, dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, kc, nq, nk),
        in_specs=_f_specs(N, dh, H, shared),
        out_specs=[oq_at, olse_at],
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.int32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ])
    operands = (qi, ki, qf) + (() if shared else (kf,)) + (vf, posq, posk)
    out, lse = pl.pallas_call(
        functools.partial(_f_fwd_kernel, shared=shared, causal=causal,
                          scale=1.0 / (dh ** 0.5), bq=bq, bk=bk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, kc, w, dh), qf.dtype),
            jax.ShapeDtypeStruct((BH, kc, w), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out, lse


def _f_bwd_call(qf, kf, vf, qi, ki, posq, posk, out, lse, do, shared,
                causal, bq, bk, H, interpret):
    BH, N, dh = qf.shape
    _, kc, w = qi.shape
    nq, nk = w // bq, w // bk
    dsum = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    scale = 1.0 / (dh ** 0.5)
    kern_kw = dict(shared=shared, causal=causal, scale=scale, bq=bq,
                   bk=bk)
    params4 = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                            "arbitrary"))

    q_at, r_at = _f_q_blk(bq, dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, kc, nq, nk),
        in_specs=_f_specs(N, dh, H, shared)
        + [q_at, r_at, r_at],                     # do, lse, dsum
        out_specs=q_at,                           # dqg per-cluster blocks
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.int32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ])
    operands = ((qi, ki, qf) + (() if shared else (kf,))
                + (vf, posq, posk, do, lse, dsum))
    dqg = pl.pallas_call(
        functools.partial(_f_dq_kernel, **kern_kw),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, kc, w, dh), jnp.float32),
        compiler_params=params4,
        interpret=interpret,
    )(*operands)

    # swapped grid: key tile parallel over (b, c, ik), query sweep inner
    q_at2, r_at2 = _f_q_blk_swapped(bq, dh)
    k_out = lambda b, c, ik, iq, *_: (b, c, ik, 0)
    grid_spec2 = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, kc, nk, nq),
        in_specs=_f_specs(N, dh, H, shared)
        + [q_at2, r_at2, r_at2],
        out_specs=[pl.BlockSpec((1, 1, bk, dh), k_out),
                   pl.BlockSpec((1, 1, bk, dh), k_out)],
        scratch_shapes=[
            pltpu.VMEM((bk, dh), jnp.float32),
            pltpu.VMEM((bk, dh), jnp.float32),
            pltpu.VMEM((bk,), jnp.int32),
            pltpu.VMEM((bk, dh), jnp.float32),
            pltpu.VMEM((bk, dh), jnp.float32),
        ])
    dkg, dvg = pl.pallas_call(
        functools.partial(_f_dkv_kernel, **kern_kw),
        grid_spec=grid_spec2,
        out_shape=[
            jax.ShapeDtypeStruct((BH, kc, w, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, kc, w, dh), jnp.float32),
        ],
        compiler_params=params4,
        interpret=interpret,
    )(*operands)

    # scatter-add per-cluster gradient blocks back to sequence layout —
    # the exact transpose of the kernel's implicit gather; duplicate
    # memberships accumulate
    bi = jnp.arange(BH)[:, None]
    qi2 = qi.reshape(BH, -1)
    ki2 = ki.reshape(BH, -1)
    dq = jnp.zeros((BH, N, dh), jnp.float32).at[bi, qi2].add(
        dqg.reshape(BH, -1, dh))
    dk = jnp.zeros((BH, N, dh), jnp.float32).at[bi, ki2].add(
        dkg.reshape(BH, -1, dh))
    dv = jnp.zeros((BH, N, dh), jnp.float32).at[bi, ki2].add(
        dvg.reshape(BH, -1, dh))
    return dq.astype(qf.dtype), dk.astype(kf.dtype), dv.astype(vf.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _routed_fused(shared, causal, bq, bk, H, interpret, qf, kf, vf, qi, ki,
                  posq, posk):
    out, _ = _f_fwd_call(qf, kf, vf, qi, ki, posq, posk, shared, causal,
                         bq, bk, H, interpret)
    return out


def _routed_fused_fwd(shared, causal, bq, bk, H, interpret, qf, kf, vf, qi,
                      ki, posq, posk):
    out, lse = _f_fwd_call(qf, kf, vf, qi, ki, posq, posk, shared, causal,
                           bq, bk, H, interpret)
    return out, (qf, kf, vf, qi, ki, posq, posk, out, lse)


def _routed_fused_bwd(shared, causal, bq, bk, H, interpret, res, do):
    qf, kf, vf, qi, ki, posq, posk, out, lse = res
    dq, dk, dv = _f_bwd_call(qf, kf, vf, qi, ki, posq, posk, out, lse, do,
                             shared, causal, bq, bk, H, interpret)
    return (dq, dk, dv, float0_like(qi), float0_like(ki),
            float0_like(posq), float0_like(posk))


_routed_fused.defvjp(_routed_fused_fwd, _routed_fused_bwd)


# ---------------------------------------------------------------------------
# Paged fused kernel: q/k/v stay in HBM; member rows stream through
# revolving double-buffered VMEM slots via per-row async DMA
# ---------------------------------------------------------------------------
def _dma_start_rows(hbm, b, idx_ref, c, base, rows, dst, sem):
    """Issue one-row async copies ``hbm[b, idx_ref[b, c, base+j]] ->
    dst[j]`` for j < rows, all signalling the same semaphore. Cluster
    membership has no sequence locality, so rows — not contiguous chunks —
    are the DMA unit; the scalar-prefetch index table in SMEM drives the
    source addresses (the same trick the paged decode kernel uses)."""
    def body(j, _):
        row = idx_ref[b, c, base + j]
        pltpu.make_async_copy(hbm.at[b, pl.ds(row, 1)],
                              dst.at[pl.ds(j, 1)], sem).start()
        return 0
    jax.lax.fori_loop(0, rows, body, 0, unroll=False)


def _dma_wait_rows(hbm, b, rows, dst, sem):
    """Wait the ``rows`` one-row copies previously started into ``dst``
    (the wait descriptor only needs the byte count, so src row 0 serves
    for every j)."""
    def body(j, _):
        pltpu.make_async_copy(hbm.at[b, pl.ds(0, 1)],
                              dst.at[pl.ds(j, 1)], sem).wait()
        return 0
    jax.lax.fori_loop(0, rows, body, 0, unroll=False)


def _p_fwd_kernel(qi_ref, ki_ref, pqg_ref, pkg_ref, *refs, shared, causal,
                  scale, bq, bk):
    if shared:
        (q_hbm, v_hbm, o_ref, lse_ref, qt_ref, kt_ref, vt_ref,
         m_ref, l_ref, acc_ref, q_sem, k_sem, v_sem) = refs
        k_hbm = q_hbm
    else:
        (q_hbm, k_hbm, v_hbm, o_ref, lse_ref, qt_ref, kt_ref, vt_ref,
         m_ref, l_ref, acc_ref, q_sem, k_sem, v_sem) = refs
    b = pl.program_id(0)
    c = pl.program_id(1)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    def start_kv(t, slot):
        _dma_start_rows(k_hbm, b, ki_ref, c, t * bk, bk,
                        kt_ref.at[slot], k_sem.at[slot])
        _dma_start_rows(v_hbm, b, ki_ref, c, t * bk, bk,
                        vt_ref.at[slot], v_sem.at[slot])

    @pl.when(ik == 0)
    def _prologue():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        _dma_start_rows(q_hbm, b, qi_ref, c, iq * bq, bq, qt_ref, q_sem)
        start_kv(0, 0)
        _dma_wait_rows(q_hbm, b, bq, qt_ref, q_sem)

    # double-buffer: tile ik+1's DMAs are in flight while tile ik computes
    @pl.when(ik + 1 < nk)
    def _prefetch():
        start_kv(ik + 1, (ik + 1) % 2)

    slot = ik % 2
    _dma_wait_rows(k_hbm, b, bk, kt_ref.at[slot], k_sem.at[slot])
    _dma_wait_rows(v_hbm, b, bk, vt_ref.at[slot], v_sem.at[slot])

    q = qt_ref[...].astype(jnp.float32)
    k = kt_ref[slot].astype(jnp.float32)
    v = vt_ref[slot].astype(jnp.float32)
    pq = pqg_ref[b, c, pl.ds(iq * bq, bq)]
    pk = pkg_ref[b, c, pl.ds(ik * bk, bk)]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    keep = _keep_mask(pq, pk, causal)
    s = jnp.where(keep, s, _NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def _p_dq_kernel(qi_ref, ki_ref, pqg_ref, pkg_ref, *refs, shared, causal,
                 scale, bq, bk):
    if shared:
        (q_hbm, v_hbm, do_ref, lse_ref, dsum_ref, dq_ref,
         qt_ref, kt_ref, vt_ref, dq_acc, q_sem, k_sem, v_sem) = refs
        k_hbm = q_hbm
    else:
        (q_hbm, k_hbm, v_hbm, do_ref, lse_ref, dsum_ref, dq_ref,
         qt_ref, kt_ref, vt_ref, dq_acc, q_sem, k_sem, v_sem) = refs
    b = pl.program_id(0)
    c = pl.program_id(1)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    def start_kv(t, slot):
        _dma_start_rows(k_hbm, b, ki_ref, c, t * bk, bk,
                        kt_ref.at[slot], k_sem.at[slot])
        _dma_start_rows(v_hbm, b, ki_ref, c, t * bk, bk,
                        vt_ref.at[slot], v_sem.at[slot])

    @pl.when(ik == 0)
    def _prologue():
        dq_acc[...] = jnp.zeros_like(dq_acc)
        _dma_start_rows(q_hbm, b, qi_ref, c, iq * bq, bq, qt_ref, q_sem)
        start_kv(0, 0)
        _dma_wait_rows(q_hbm, b, bq, qt_ref, q_sem)

    @pl.when(ik + 1 < nk)
    def _prefetch():
        start_kv(ik + 1, (ik + 1) % 2)

    slot = ik % 2
    _dma_wait_rows(k_hbm, b, bk, kt_ref.at[slot], k_sem.at[slot])
    _dma_wait_rows(v_hbm, b, bk, vt_ref.at[slot], v_sem.at[slot])

    q = qt_ref[...].astype(jnp.float32)
    k = kt_ref[slot].astype(jnp.float32)
    v = vt_ref[slot].astype(jnp.float32)
    pq = pqg_ref[b, c, pl.ds(iq * bq, bq)]
    pk = pkg_ref[b, c, pl.ds(ik * bk, bk)]
    do = do_ref[0, 0].astype(jnp.float32)
    keep = _keep_mask(pq, pk, causal)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    p = jnp.where(keep, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum_ref[0, 0][:, None]) * scale
    dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0, 0] = dq_acc[...]


def _p_dkv_kernel(qi_ref, ki_ref, pqg_ref, pkg_ref, *refs, shared, causal,
                  scale, bq, bk):
    if shared:
        (q_hbm, v_hbm, do_ref, lse_ref, dsum_ref, dk_ref, dv_ref,
         qt_ref, kt_ref, vt_ref, dk_acc, dv_acc,
         q_sem, k_sem, v_sem) = refs
        k_hbm = q_hbm
    else:
        (q_hbm, k_hbm, v_hbm, do_ref, lse_ref, dsum_ref, dk_ref, dv_ref,
         qt_ref, kt_ref, vt_ref, dk_acc, dv_acc,
         q_sem, k_sem, v_sem) = refs
    b = pl.program_id(0)
    c = pl.program_id(1)
    ik = pl.program_id(2)
    iq = pl.program_id(3)
    nq = pl.num_programs(3)

    # swapped roles: the k/v tile is the single resident (it is revisited
    # by every q sweep step), the q tiles revolve through double buffers
    @pl.when(iq == 0)
    def _prologue():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)
        _dma_start_rows(k_hbm, b, ki_ref, c, ik * bk, bk, kt_ref, k_sem)
        _dma_start_rows(v_hbm, b, ki_ref, c, ik * bk, bk, vt_ref, v_sem)
        _dma_start_rows(q_hbm, b, qi_ref, c, 0, bq, qt_ref.at[0],
                        q_sem.at[0])
        _dma_wait_rows(k_hbm, b, bk, kt_ref, k_sem)
        _dma_wait_rows(v_hbm, b, bk, vt_ref, v_sem)

    @pl.when(iq + 1 < nq)
    def _prefetch():
        _dma_start_rows(q_hbm, b, qi_ref, c, (iq + 1) * bq, bq,
                        qt_ref.at[(iq + 1) % 2], q_sem.at[(iq + 1) % 2])

    slot = iq % 2
    _dma_wait_rows(q_hbm, b, bq, qt_ref.at[slot], q_sem.at[slot])

    q = qt_ref[slot].astype(jnp.float32)
    k = kt_ref[...].astype(jnp.float32)
    v = vt_ref[...].astype(jnp.float32)
    pq = pqg_ref[b, c, pl.ds(iq * bq, bq)]
    pk = pkg_ref[b, c, pl.ds(ik * bk, bk)]
    do = do_ref[0, 0].astype(jnp.float32)
    keep = _keep_mask(pq, pk, causal)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    p = jnp.where(keep, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum_ref[0, 0][:, None]) * scale
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0, 0] = dk_acc[...]
        dv_ref[0, 0] = dv_acc[...]


def _p_specs(shared):
    """Paged fused in_specs: q [k] v stay in HBM (ANY memory space) — the
    kernel DMAs member rows itself, nothing is staged as an input block."""
    return [pl.BlockSpec(memory_space=pltpu.ANY)] * (2 if shared else 3)


def _p_fwd_call(qf, kf, vf, qi, ki, pqg, pkg, shared, causal, bq, bk,
                interpret):
    BH, N, dh = qf.shape
    _, kc, w = qi.shape
    nq, nk = w // bq, w // bk
    oq_at, olse_at = _f_q_blk(bq, dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(BH, kc, nq, nk),
        in_specs=_p_specs(shared),
        out_specs=[oq_at, olse_at],
        scratch_shapes=[
            pltpu.VMEM((bq, dh), qf.dtype),
            pltpu.VMEM((2, bk, dh), kf.dtype),
            pltpu.VMEM((2, bk, dh), vf.dtype),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ])
    operands = (qi, ki, pqg, pkg, qf) + (() if shared else (kf,)) + (vf,)
    out, lse = pl.pallas_call(
        functools.partial(_p_fwd_kernel, shared=shared, causal=causal,
                          scale=1.0 / (dh ** 0.5), bq=bq, bk=bk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, kc, w, dh), qf.dtype),
            jax.ShapeDtypeStruct((BH, kc, w), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out, lse


def _p_bwd_call(qf, kf, vf, qi, ki, pqg, pkg, out, lse, do, shared, causal,
                bq, bk, interpret):
    BH, N, dh = qf.shape
    _, kc, w = qi.shape
    nq, nk = w // bq, w // bk
    dsum = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    scale = 1.0 / (dh ** 0.5)
    kern_kw = dict(shared=shared, causal=causal, scale=scale, bq=bq, bk=bk)
    params4 = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                            "arbitrary"))

    q_at, r_at = _f_q_blk(bq, dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(BH, kc, nq, nk),
        in_specs=_p_specs(shared) + [q_at, r_at, r_at],   # do, lse, dsum
        out_specs=q_at,                                   # dqg blocks
        scratch_shapes=[
            pltpu.VMEM((bq, dh), qf.dtype),
            pltpu.VMEM((2, bk, dh), kf.dtype),
            pltpu.VMEM((2, bk, dh), vf.dtype),
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ])
    operands = ((qi, ki, pqg, pkg, qf) + (() if shared else (kf,))
                + (vf, do, lse, dsum))
    dqg = pl.pallas_call(
        functools.partial(_p_dq_kernel, **kern_kw),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, kc, w, dh), jnp.float32),
        compiler_params=params4,
        interpret=interpret,
    )(*operands)

    # swapped grid: key tile parallel over (b, c, ik), query sweep inner
    q_at2, r_at2 = _f_q_blk_swapped(bq, dh)
    k_out = lambda b, c, ik, iq, *_: (b, c, ik, 0)
    grid_spec2 = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(BH, kc, nk, nq),
        in_specs=_p_specs(shared) + [q_at2, r_at2, r_at2],
        out_specs=[pl.BlockSpec((1, 1, bk, dh), k_out),
                   pl.BlockSpec((1, 1, bk, dh), k_out)],
        scratch_shapes=[
            pltpu.VMEM((2, bq, dh), qf.dtype),
            pltpu.VMEM((bk, dh), kf.dtype),
            pltpu.VMEM((bk, dh), vf.dtype),
            pltpu.VMEM((bk, dh), jnp.float32),
            pltpu.VMEM((bk, dh), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ])
    dkg, dvg = pl.pallas_call(
        functools.partial(_p_dkv_kernel, **kern_kw),
        grid_spec=grid_spec2,
        out_shape=[
            jax.ShapeDtypeStruct((BH, kc, w, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, kc, w, dh), jnp.float32),
        ],
        compiler_params=params4,
        interpret=interpret,
    )(*operands)

    # chunked scatter-add of per-cluster gradient blocks to sequence
    # layout (same transpose-of-the-gather as the unpaged path)
    bi = jnp.arange(BH)[:, None]
    qi2 = qi.reshape(BH, -1)
    ki2 = ki.reshape(BH, -1)
    dq = jnp.zeros((BH, N, dh), jnp.float32).at[bi, qi2].add(
        dqg.reshape(BH, -1, dh))
    dk = jnp.zeros((BH, N, dh), jnp.float32).at[bi, ki2].add(
        dkg.reshape(BH, -1, dh))
    dv = jnp.zeros((BH, N, dh), jnp.float32).at[bi, ki2].add(
        dvg.reshape(BH, -1, dh))
    return dq.astype(qf.dtype), dk.astype(kf.dtype), dv.astype(vf.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _routed_paged(shared, causal, bq, bk, interpret, qf, kf, vf, qi, ki,
                  pqg, pkg):
    out, _ = _p_fwd_call(qf, kf, vf, qi, ki, pqg, pkg, shared, causal,
                         bq, bk, interpret)
    return out


def _routed_paged_fwd(shared, causal, bq, bk, interpret, qf, kf, vf, qi,
                      ki, pqg, pkg):
    out, lse = _p_fwd_call(qf, kf, vf, qi, ki, pqg, pkg, shared, causal,
                           bq, bk, interpret)
    return out, (qf, kf, vf, qi, ki, pqg, pkg, out, lse)


def _routed_paged_bwd(shared, causal, bq, bk, interpret, res, do):
    qf, kf, vf, qi, ki, pqg, pkg, out, lse = res
    dq, dk, dv = _p_bwd_call(qf, kf, vf, qi, ki, pqg, pkg, out, lse, do,
                             shared, causal, bq, bk, interpret)
    return (dq, dk, dv, float0_like(qi), float0_like(ki),
            float0_like(pqg), float0_like(pkg))


_routed_paged.defvjp(_routed_paged_fwd, _routed_paged_bwd)


def routed_attention_fused(q, k, v, q_idx, k_idx, positions, causal=True,
                           kvalid=None, bq=128, bk=128, interpret=None,
                           paged=None):
    """Gather-free routed attention on sequence-layout tensors.

    q/v: (B,H,N,dh); k: like q, or None for shared-QK causal mode (keys
    are read from the q buffer — one VMEM plane instead of two).
    q_idx/k_idx: (B,H,k,w) sorted membership indices into the sequence.
    positions: (B,N) int32 original positions (the causal mask compares
    these). kvalid: (B,N) bool, True = attendable key (padding False).
    Returns per-cluster outputs (B,H,k,w,dh); callers scatter them back.

    ``paged=None`` auto-selects the memory plan: whole-plane VMEM
    residency while N·dh fits ``FUSED_RESIDENT_ELEMS``, double-buffered
    per-row DMA paging beyond it (VMEM bounded by the tile sizes, not N).
    Pass True/False to force a plan. The paged path pre-gathers int32
    positions per member (4 B/row, SMEM scalar-prefetch) — still no
    gathered q/k/v tensor in HBM.

    Differentiable: flash-style custom VJP that recomputes p from saved
    lse stats and scatter-adds per-cluster dq/dk/dv to sequence layout.
    """
    B, H, N, dh = q.shape
    _, _, kc, w = q_idx.shape
    bq = min(bq, w)
    bk = min(bk, w)
    assert w % bq == 0 and w % bk == 0, (w, bq, bk)
    shared = k is None
    qf = q.reshape(B * H, N, dh)
    kf = qf if shared else k.reshape(B * H, N, dh)
    vf = v.reshape(B * H, N, dh)
    qi = q_idx.reshape(B * H, kc, w).astype(jnp.int32)
    ki = k_idx.reshape(B * H, kc, w).astype(jnp.int32)
    posq = positions.astype(jnp.int32)
    posk = (jnp.where(kvalid, posq, SENTINEL) if kvalid is not None
            else posq)
    if fused_paged_default(N, dh, paged):
        pq_src = jnp.broadcast_to(posq[:, None, :], (B, H, N))
        pk_src = jnp.broadcast_to(posk[:, None, :], (B, H, N))
        pqg = jnp.take_along_axis(pq_src.reshape(B * H, N),
                                  qi.reshape(B * H, kc * w),
                                  axis=1).reshape(B * H, kc, w)
        pkg = jnp.take_along_axis(pk_src.reshape(B * H, N),
                                  ki.reshape(B * H, kc * w),
                                  axis=1).reshape(B * H, kc, w)
        out = _routed_paged(shared, bool(causal), int(bq), int(bk),
                            default_interpret(interpret), qf, kf, vf,
                            qi, ki, pqg, pkg)
    else:
        out = _routed_fused(shared, bool(causal), int(bq), int(bk),
                            int(H), default_interpret(interpret), qf, kf,
                            vf, qi, ki, posq, posk)
    return out.reshape(B, H, kc, w, dh)
