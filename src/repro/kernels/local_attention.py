"""Blocked local (sliding-window) attention — Pallas TPU kernel.

One grid point per (batch·head, query block). The query block attends its
own block and the previous one (+ next in encoder mode) — the paper's local
attention. Both KV tiles are index-mapped views of the same HBM array
(block b-1 clamps to 0 and is masked for b == 0), so the softmax over the
concatenated 2w (3w) keys happens entirely in VMEM in one shot: for w <= 512
the (w x 2w) fp32 score tile is ~2 MiB, comfortably inside VMEM — no
running-softmax needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG = -1e9


def _kernel(q_ref, kp_ref, kc_ref, kn_ref, vp_ref, vc_ref, vn_ref, o_ref, *,
            w, causal, scale, nb):
    b = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                    # (w, dh)
    ks = [kp_ref[0], kc_ref[0]] + ([kn_ref[0]] if not causal else [])
    vs = [vp_ref[0], vc_ref[0]] + ([vn_ref[0]] if not causal else [])
    k = jnp.concatenate([x.astype(jnp.float32) for x in ks], axis=0)
    v = jnp.concatenate([x.astype(jnp.float32) for x in vs], axis=0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    cw = k.shape[0]
    pos_q = b * w + jax.lax.broadcasted_iota(jnp.int32, (w, cw), 0)
    off = jax.lax.broadcasted_iota(jnp.int32, (w, cw), 1)
    pos_k = (b - 1) * w + off                           # prev tile then own
    keep = (pos_k >= 0) & (pos_k < nb * w)
    if causal:
        keep &= pos_q >= pos_k
    s = jnp.where(keep, s, _NEG)
    m = s.max(-1, keepdims=True)
    p = jnp.where(keep, jnp.exp(s - m), 0.0)
    l = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jax.lax.dot_general(p / l, v, (((1,), (0,)), ((), ())))
    o_ref[0] = o.astype(o_ref.dtype)


def local_attention_kernel(q, k, v, window, causal=True, interpret=True):
    """q: (B,H,N,dh); k,v: (B,Hkv,N,dh); N % window == 0."""
    B, H, N, dh = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    w = min(window, N)
    assert N % w == 0, (N, w)
    nb = N // w
    qf = q.reshape(B * H, N, dh)
    kf = k.reshape(B * Hkv, N, dh)
    vf = v.reshape(B * Hkv, N, dh)

    def kv_at(delta):
        def index(bh, b):
            kvh = (bh // H) * Hkv + (bh % H) // g
            return (kvh, jnp.clip(b + delta, 0, nb - 1), 0)
        return index

    kv_spec = lambda d: pl.BlockSpec((1, w, dh), kv_at(d))
    out = pl.pallas_call(
        functools.partial(_kernel, w=w, causal=causal,
                          scale=1.0 / (dh ** 0.5), nb=nb),
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1, w, dh), lambda bh, b: (bh, b, 0)),
            kv_spec(-1), kv_spec(0), kv_spec(+1),
            kv_spec(-1), kv_spec(0), kv_spec(+1),
        ],
        out_specs=pl.BlockSpec((1, w, dh), lambda bh, b: (bh, b, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, N, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, kf, kf, vf, vf, vf)
    return out.reshape(B, H, N, dh)
