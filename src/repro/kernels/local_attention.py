"""Blocked local (sliding-window) attention — Pallas TPU kernel,
differentiable.

One grid point per (batch·head, query block). The query block attends its
own block and the previous one (+ next in encoder mode) — the paper's local
attention. Both KV tiles are index-mapped views of the same HBM array
(block b-1 clamps to 0 and is masked for b == 0), so the softmax over the
concatenated 2w (3w) keys happens entirely in VMEM in one shot: for w <= 512
the (w x 2w) fp32 score tile is ~2 MiB, comfortably inside VMEM — no
running-softmax needed.

Backward (``jax.custom_vjp``): the forward also emits per-row lse stats;
the dq kernel mirrors the forward exactly (recompute p = exp(s - lse),
dq = ds @ K_cat). The dk/dv kernel inverts the window: key block b is
attended by query blocks {b, b+1} (causal; {b-1, b, b+1} in encoder mode),
so it index-maps those q/do/lse/D blocks in (clamped at the edges, masked
via intended positions) and accumulates both contributions in one grid
point. dk/dv come out per *query* head and are group-summed to the GQA kv
heads in XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import NEG as _NEG
from repro.kernels.common import CompilerParams as _CompilerParams
from repro.kernels.common import default_interpret


def _kernel(q_ref, kp_ref, kc_ref, kn_ref, vp_ref, vc_ref, vn_ref, o_ref,
            lse_ref, *, w, causal, scale, nb):
    b = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                    # (w, dh)
    ks = [kp_ref[0], kc_ref[0]] + ([kn_ref[0]] if not causal else [])
    vs = [vp_ref[0], vc_ref[0]] + ([vn_ref[0]] if not causal else [])
    k = jnp.concatenate([x.astype(jnp.float32) for x in ks], axis=0)
    v = jnp.concatenate([x.astype(jnp.float32) for x in vs], axis=0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    cw = k.shape[0]
    pos_q = b * w + jax.lax.broadcasted_iota(jnp.int32, (w, cw), 0)
    off = jax.lax.broadcasted_iota(jnp.int32, (w, cw), 1)
    pos_k = (b - 1) * w + off                           # prev tile then own
    keep = (pos_k >= 0) & (pos_k < nb * w)
    if causal:
        keep &= pos_q >= pos_k
    s = jnp.where(keep, s, _NEG)
    m = s.max(-1, keepdims=True)
    p = jnp.where(keep, jnp.exp(s - m), 0.0)
    l = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jax.lax.dot_general(p / l, v, (((1,), (0,)), ((), ())))
    o_ref[0] = o.astype(o_ref.dtype)
    lse_ref[0] = (m + jnp.log(l))[:, 0]


def _bwd_dq_kernel(q_ref, kp_ref, kc_ref, kn_ref, vp_ref, vc_ref, vn_ref,
                   do_ref, lse_ref, dsum_ref, dq_ref, *, w, causal, scale,
                   nb):
    b = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    ks = [kp_ref[0], kc_ref[0]] + ([kn_ref[0]] if not causal else [])
    vs = [vp_ref[0], vc_ref[0]] + ([vn_ref[0]] if not causal else [])
    k = jnp.concatenate([x.astype(jnp.float32) for x in ks], axis=0)
    v = jnp.concatenate([x.astype(jnp.float32) for x in vs], axis=0)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    dsum = dsum_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    cw = k.shape[0]
    pos_q = b * w + jax.lax.broadcasted_iota(jnp.int32, (w, cw), 0)
    off = jax.lax.broadcasted_iota(jnp.int32, (w, cw), 1)
    pos_k = (b - 1) * w + off
    keep = (pos_k >= 0) & (pos_k < nb * w)
    if causal:
        keep &= pos_q >= pos_k
    p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum[:, None]) * scale
    dq_ref[0] = jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))


def _bwd_dkv_kernel(k_ref, v_ref, *refs, w, causal, scale, nb, deltas):
    """Key block b gathers contributions from the q blocks that attend it
    (b + delta for delta in ``deltas``); edge blocks are clamped by the
    index map and neutralized by the intended-position mask."""
    b = pl.program_id(1)
    q_refs, do_refs, lse_refs, dsum_refs = (
        refs[0:len(deltas)], refs[len(deltas):2 * len(deltas)],
        refs[2 * len(deltas):3 * len(deltas)],
        refs[3 * len(deltas):4 * len(deltas)])
    dk_ref, dv_ref = refs[4 * len(deltas):]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dk = jnp.zeros_like(k)
    dv = jnp.zeros_like(v)
    pos_k = b * w + jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    for d, q_r, do_r, lse_r, dsum_r in zip(deltas, q_refs, do_refs,
                                           lse_refs, dsum_refs):
        q = q_r[0].astype(jnp.float32)
        do = do_r[0].astype(jnp.float32)
        lse = lse_r[0]
        dsum = dsum_r[0]
        # intended (unclamped) query positions: rows outside [0, nb*w)
        # belong to a block that does not exist and mask to zero
        pos_q = (b + d) * w + jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)
        keep = (pos_q >= 0) & (pos_q < nb * w)
        if causal:
            keep &= pos_q >= pos_k
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        p = jnp.where(keep, jnp.exp(s - lse[:, None]), 0.0)
        dv += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - dsum[:, None]) * scale
        dk += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))
    dk_ref[0] = dk
    dv_ref[0] = dv


def _shapes(q, k):
    B, H, N, dh = q.shape
    Hkv = k.shape[1]
    return B, H, Hkv, N, dh


def _kv_at(H, Hkv, nb, delta):
    g = H // Hkv

    def index(bh, b):
        kvh = (bh // H) * Hkv + (bh % H) // g
        return (kvh, jnp.clip(b + delta, 0, nb - 1), 0)
    return index


def _q_at(nb, delta):
    def index(bh, b):
        return (bh, jnp.clip(b + delta, 0, nb - 1), 0)
    return index


def _r_at(nb, delta):
    def index(bh, b):
        return (bh, jnp.clip(b + delta, 0, nb - 1))
    return index


def _fwd_call(q, k, v, w, causal, interpret):
    B, H, Hkv, N, dh = _shapes(q, k)
    nb = N // w
    qf = q.reshape(B * H, N, dh)
    kf = k.reshape(B * Hkv, N, dh)
    vf = v.reshape(B * Hkv, N, dh)
    kv_spec = lambda d: pl.BlockSpec((1, w, dh), _kv_at(H, Hkv, nb, d))
    out, lse = pl.pallas_call(
        functools.partial(_kernel, w=w, causal=causal,
                          scale=1.0 / (dh ** 0.5), nb=nb),
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1, w, dh), lambda bh, b: (bh, b, 0)),
            kv_spec(-1), kv_spec(0), kv_spec(+1),
            kv_spec(-1), kv_spec(0), kv_spec(+1),
        ],
        out_specs=[
            pl.BlockSpec((1, w, dh), lambda bh, b: (bh, b, 0)),
            pl.BlockSpec((1, w), lambda bh, b: (bh, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, N, dh), q.dtype),
            jax.ShapeDtypeStruct((B * H, N), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, kf, kf, vf, vf, vf)
    return out.reshape(B, H, N, dh), lse


def _bwd_call(q, k, v, lse, out, do, w, causal, interpret):
    B, H, Hkv, N, dh = _shapes(q, k)
    g = H // Hkv
    nb = N // w
    qf = q.reshape(B * H, N, dh)
    kf = k.reshape(B * Hkv, N, dh)
    vf = v.reshape(B * Hkv, N, dh)
    dof = do.reshape(B * H, N, dh)
    dsum = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    dsum = dsum.reshape(B * H, N)
    scale = 1.0 / (dh ** 0.5)
    params = _CompilerParams(dimension_semantics=("parallel", "arbitrary"))
    kv_spec = lambda d: pl.BlockSpec((1, w, dh), _kv_at(H, Hkv, nb, d))
    q_spec = lambda d: pl.BlockSpec((1, w, dh), _q_at(nb, d))
    r_spec = lambda d: pl.BlockSpec((1, w), _r_at(nb, d))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, w=w, causal=causal, scale=scale,
                          nb=nb),
        grid=(B * H, nb),
        in_specs=[
            q_spec(0),
            kv_spec(-1), kv_spec(0), kv_spec(+1),
            kv_spec(-1), kv_spec(0), kv_spec(+1),
            q_spec(0), r_spec(0), r_spec(0),
        ],
        out_specs=pl.BlockSpec((1, w, dh), lambda bh, b: (bh, b, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, N, dh), jnp.float32),
        compiler_params=params,
        interpret=interpret,
    )(qf, kf, kf, kf, vf, vf, vf, dof, lse, dsum)

    deltas = (0, 1) if causal else (-1, 0, 1)
    dkv_in = ([kv_spec(0), kv_spec(0)]
              + [q_spec(d) for d in deltas]
              + [q_spec(d) for d in deltas]
              + [r_spec(d) for d in deltas]
              + [r_spec(d) for d in deltas])
    dkv_ops = ([kf, vf] + [qf] * len(deltas) + [dof] * len(deltas)
               + [lse] * len(deltas) + [dsum] * len(deltas))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, w=w, causal=causal, scale=scale,
                          nb=nb, deltas=deltas),
        grid=(B * H, nb),
        in_specs=dkv_in,
        out_specs=[
            pl.BlockSpec((1, w, dh), lambda bh, b: (bh, b, 0)),
            pl.BlockSpec((1, w, dh), lambda bh, b: (bh, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, N, dh), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, dh), jnp.float32),
        ],
        compiler_params=params,
        interpret=interpret,
    )(*dkv_ops)

    dq = dq.reshape(B, H, N, dh).astype(q.dtype)
    dk = dk.reshape(B, Hkv, g, N, dh).sum(2).astype(k.dtype)
    dv = dv.reshape(B, Hkv, g, N, dh).sum(2).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _local(w, causal, interpret, q, k, v):
    out, _ = _fwd_call(q, k, v, w, causal, interpret)
    return out


def _local_fwd(w, causal, interpret, q, k, v):
    out, lse = _fwd_call(q, k, v, w, causal, interpret)
    return out, (q, k, v, out, lse)


def _local_bwd(w, causal, interpret, res, do):
    q, k, v, out, lse = res
    return _bwd_call(q, k, v, lse, out, do, w, causal, interpret)


_local.defvjp(_local_fwd, _local_bwd)


def local_attention_kernel(q, k, v, window, causal=True, interpret=None):
    """q: (B,H,N,dh); k,v: (B,Hkv,N,dh); N % window == 0. Differentiable."""
    N = q.shape[2]
    w = min(window, N)
    assert N % w == 0, (N, w)
    return _local(int(w), bool(causal), default_interpret(interpret),
                  q, k, v)
