"""Paged routing decode — the Pallas kernel for the serving hot path.

Single-token decode for the routing variants attends one cluster page:
the decoded token's routing vector picks its argmax centroid and the
kernel scores it against that page's occupied slots (+ the token itself).
The XLA reference (`attn.backends._routing_decode`) materializes the
selected (B,Hr,1,cap,dh) page with `take_along_axis` — an HBM gather of
the whole page per step.

`paged_routing_decode` removes the gather with the same scalar-prefetch
page-table trick the fused train kernel uses (DESIGN.md §9): the selected
cluster ids (B,Hr) and the per-page length table `rlen` (B,Hr,kc) ride in
as scalar-prefetch operands (`PrefetchScalarGridSpec`, SMEM), and the
page BlockSpec's index map reads the cluster id to DMA exactly one
(cap,dh) page per (batch, head) grid step straight from the paged cache
into VMEM — no gathered copy ever reaches HBM. Slots at index >=
min(rlen, cap) are dead weight in the pull but masked to -1e9 before the
softmax, so garbage in unoccupied slots cannot leak into the output
(tests poison them to prove it).

Parity contract (gated in tests/test_routing_decode.py): stage 1
(routing-vector normalization, centroid argmax) and the ring-slot cache
write stay in XLA in the backend wrapper — literally the same code the
reference runs — so the cache trajectory is bit-identical by
construction, and greedy-decoded token streams are bit-identical over
long multi-step decode. The in-kernel attention mirrors the reference's
op sequence (dot in the promoted input dtype, f32 cast, divide by
sqrt(dh), occupancy mask, concat the self logit, `jax.nn.softmax` in
f32, concat values, dot), which pins the per-step attention output to
within a few float32 ulps of the reference (measured <= 2e-6 absolute);
exact bitwise equality of the float reductions is not promisable — XLA
compiles the same dot differently depending on surrounding program
context (verified: even jit(dynamic_slice + dot) differs from the eager
dot by 1 ulp on CPU), and on TPU the MXU accumulates differently from
an XLA einsum anyway. Because the only state fed forward between steps
is the cache (bitwise equal) and the sampled token (argmax, immune to
ulp noise), the ulp difference does not compound.

Grid: (B, Hr) — one grid step per (batch, head), blocks (1,1,cap,dh) for
the page and (1,1,dh) for the token vectors. cap*dh is a few KiB at
paper shapes (cap = routing window, 32..256), so the whole page fits
VMEM with no sequence-length cliff; decode cost per token is O(cap*dh)
per routing head regardless of context length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG as _NEG
from repro.kernels.common import CompilerParams as _CompilerParams
from repro.kernels.common import default_interpret


def _decode_kernel(c_ref, rlen_ref, r_ref, v_ref, rk_ref, rv_ref, o_ref,
                   *, cap, dh):
    b = pl.program_id(0)
    h = pl.program_id(1)
    c = c_ref[b, h]
    plen = rlen_ref[b, h, c]
    nvalid = jnp.minimum(plen, cap)

    r = r_ref[0]                       # (1, dh)
    page_k = rk_ref[0, 0, 0]           # (cap, dh) — the selected page
    page_v = rv_ref[0, 0, 0]

    # mirror the reference op-for-op: dot in the promoted input dtype,
    # THEN cast f32, THEN divide (mul-by-reciprocal would not be bitwise)
    s_dt = jnp.promote_types(r.dtype, page_k.dtype)
    logits = jax.lax.dot_general(r.astype(s_dt), page_k.astype(s_dt),
                                 (((1,), (1,)), ((), ())))      # (1, cap)
    logits = logits.astype(jnp.float32) / jnp.sqrt(dh)
    slot_ok = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1) < nvalid
    logits = jnp.where(slot_ok, logits, _NEG)
    # reference divides the self score in r.dtype before the f32 cast;
    # a dot (not mul+reduce) so the accumulation order matches einsum's
    self_logit = (jax.lax.dot_general(r, r, (((1,), (1,)), ((), ()))) /
                  jnp.sqrt(dh)).astype(jnp.float32)             # (1, 1)
    all_logits = jnp.concatenate([logits, self_logit], axis=1)  # (1,cap+1)
    attn = jax.nn.softmax(all_logits, axis=-1)

    v_new = v_ref[0]                   # (1, dh)
    vals_dt = jnp.promote_types(page_v.dtype, v_new.dtype)
    vals = jnp.concatenate([page_v.astype(vals_dt),
                            v_new.astype(vals_dt)], axis=0)     # (cap+1,dh)
    o = jax.lax.dot_general(attn.astype(vals_dt), vals,
                            (((1,), (0,)), ((), ())))           # (1, dh)
    o_ref[0] = o.astype(o_ref.dtype)


def paged_routing_decode(r, v_new, rk, rv, rlen, cluster, interpret=None):
    """One decoded token of routed attention over the cluster-paged cache.

    r:       (B,Hr,dh)  normalized routing vector of the new token
             (shared-QK: it is both the query and its own key)
    v_new:   (B,Hr,dh)  the new token's value (kv heads pre-expanded)
    rk/rv:   (B,Hr,kc,cap,dh)  paged cache of routing keys / values
    rlen:    (B,Hr,kc)  int32 per-page write counters (>= cap => full ring)
    cluster: (B,Hr)     int32 argmax page id of the new token

    Returns o (B,Hr,dh) — softmax over the page's min(rlen,cap) occupied
    slots plus the token itself. Pure read: the caller owns the ring-slot
    cache write (kept in XLA so the cache trajectory is shared with the
    reference backend). ``interpret=None`` derives from the platform.
    """
    B, Hr, dh = r.shape
    kc, cap = rk.shape[2], rk.shape[3]
    tok_at = lambda b, h, *_: (b, h, 0)
    # the paged-attention move: the index map reads the prefetched
    # cluster id, so only the selected page is ever DMA'd to VMEM
    page_at = lambda b, h, c_ref, rlen_ref: (b, h, c_ref[b, h], 0, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hr),
        in_specs=[
            pl.BlockSpec((1, 1, dh), tok_at),            # r
            pl.BlockSpec((1, 1, dh), tok_at),            # v_new
            pl.BlockSpec((1, 1, 1, cap, dh), page_at),   # rk page
            pl.BlockSpec((1, 1, 1, cap, dh), page_at),   # rv page
        ],
        out_specs=pl.BlockSpec((1, 1, dh), tok_at))
    out_dtype = jnp.promote_types(rv.dtype, v_new.dtype)
    return pl.pallas_call(
        functools.partial(_decode_kernel, cap=cap, dh=dh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hr, dh), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=default_interpret(interpret),
    )(cluster.astype(jnp.int32), rlen.astype(jnp.int32), r, v_new, rk, rv)
