"""jit'd public wrappers for the Pallas kernels.

``interpret=None`` (the default) derives from the platform: compiled
Mosaic on TPU, interpret mode everywhere else (kernels/common.py
``default_interpret``). A caller that forgets ``interpret=False`` on TPU
therefore cannot silently benchmark interpret mode, and a CPU caller
cannot crash into the Mosaic compiler. Explicit True/False still wins.

All wrappers are differentiable: the kernels carry flash-style
``jax.custom_vjp`` backwards (recompute-from-lse), so ``jax.grad``
through any of them runs Pallas end-to-end instead of falling back to
the XLA reference.

Every wrapper traces under an obs span ("kernels/<name>") so profiler
captures and HLO dumps attribute kernel time to the op, not to an
anonymous pallas_call.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _flash
from repro.kernels import local_attention as _local
from repro.kernels import routing_attention as _routing
from repro.obs.trace import span


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=None):
    with span("kernels/flash_attention"):
        return _flash.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "causal", "interpret"))
def local_attention(q, k, v, window, causal=True, interpret=None):
    with span("kernels/local_attention"):
        return _local.local_attention_kernel(q, k, v, window, causal=causal,
                                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def routed_attention_blocks(qg, kg, vg, pos_q, pos_k, causal=True,
                            valid_k=None, bq=128, bk=128, interpret=None):
    with span("kernels/routed_attention_blocks"):
        return _routing.routed_attention_blocks(
            qg, kg, vg, pos_q, pos_k, causal=causal, valid_k=valid_k,
            bq=bq, bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret", "paged"))
def routed_attention_fused(q, k, v, q_idx, k_idx, positions, causal=True,
                           kvalid=None, bq=128, bk=128, interpret=None,
                           paged=None):
    """Gather-free fused kernel: sequence-layout q/k/v (k=None reads keys
    from the q buffer — shared-QK causal mode) + (B,H,k,w) membership via
    scalar prefetch. Returns per-cluster blocks (B,H,k,w,dh).

    ``paged=None`` auto-switches the memory plan on the VMEM residency
    budget (``FUSED_RESIDENT_ELEMS``): whole-plane resident below it,
    double-buffered per-row DMA paging above — no sequence-length cliff."""
    with span("kernels/routed_attention_fused"):
        return _routing.routed_attention_fused(
            q, k, v, q_idx, k_idx, positions, causal=causal, kvalid=kvalid,
            bq=bq, bk=bk, interpret=interpret, paged=paged)
