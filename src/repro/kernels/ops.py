"""jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True (this container is CPU; the kernel bodies then
execute in Python with identical semantics). On TPU pass interpret=False —
the call sites (core/routing.py `impl="pallas"`, models) only toggle a flag.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _flash
from repro.kernels import local_attention as _local
from repro.kernels import routing_attention as _routing


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=True):
    return _flash.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "causal", "interpret"))
def local_attention(q, k, v, window, causal=True, interpret=True):
    return _local.local_attention_kernel(q, k, v, window, causal=causal,
                                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def routed_attention_blocks(qg, kg, vg, pos_q, pos_k, causal=True,
                            valid_k=None, bq=128, bk=128, interpret=True):
    return _routing.routed_attention_blocks(
        qg, kg, vg, pos_q, pos_k, causal=causal, valid_k=valid_k,
        bq=bq, bk=bk, interpret=interpret)
