"""Causal flash attention — Pallas TPU kernel.

Canonical TPU shape: grid (B*H, Nq/bq, Mk/bk) with the KV dimension as the
*sequential* (arbitrary) axis; running-softmax statistics (m, l) and the
output accumulator live in VMEM scratch across the KV sweep, so no (N x M)
score matrix ever exists in HBM. Causal blocks strictly above the diagonal
are skipped with pl.when (on hardware Mosaic elides them; the roofline model
counts 2x fewer FLOPs than dense attention accordingly).

GQA: the KV BlockSpec index-maps query-head bh -> kv head (bh % H) // g, so
no repeated KV is materialized.

VMEM budget per grid point (bq = bk = 128, dh <= 256, fp32 accumulators):
q/k/v tiles 3*128*256*4B = 384 KiB + acc 128*256*4B = 128 KiB + stats — well
under the ~16 MiB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG = -1e9


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq, bk, causal, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip KV blocks strictly in the future of the whole Q block
    run = (ik * bk <= iq * bq + (bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # (bq, dh)
        k = k_ref[0].astype(jnp.float32)             # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s * scale                                # (bq, bk)
        if causal:
            pos_q = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            pos_k = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(pos_q >= pos_k, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(pos_q >= pos_k, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B,H,N,dh); k,v: (B,Hkv,M,dh) -> (B,H,N,dh)."""
    B, H, N, dh = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(bq, N)
    bk = min(bk, M)
    assert N % bq == 0 and M % bk == 0, (N, bq, M, bk)
    qf = q.reshape(B * H, N, dh)
    kf = k.reshape(B * Hkv, M, dh)
    vf = v.reshape(B * Hkv, M, dh)

    def kv_index(bh, iq, ik):
        return ((bh // H) * Hkv + (bh % H) // g, ik, 0)

    grid = (B * H, N // bq, M // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                          scale=1.0 / (dh ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, N, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, N, dh)
