"""Causal flash attention — Pallas TPU kernel, differentiable.

Canonical TPU shape: grid (B*H, Nq/bq, Mk/bk) with the KV dimension as the
*sequential* (arbitrary) axis; running-softmax statistics (m, l) and the
output accumulator live in VMEM scratch across the KV sweep, so no (N x M)
score matrix ever exists in HBM. Causal blocks strictly above the diagonal
are skipped with pl.when (on hardware Mosaic elides them; the roofline model
counts 2x fewer FLOPs than dense attention accordingly).

GQA: the KV BlockSpec index-maps query-head bh -> kv head (bh % H) // g, so
no repeated KV is materialized.

Backward (``jax.custom_vjp``, flash style): the forward additionally emits
per-row log-sum-exp stats (lse = m + log l, one fp32 per query row); the
backward *recomputes* each probability tile as exp(s - lse) instead of
storing any (N x M) matrix, then runs two kernels over the same block
structure: a dq kernel (grid (B*H, Nq/bq, Mk/bk), KV sequential, dq tile
accumulated in VMEM) and a dk/dv kernel (grid (B*H, Mk/bk, Nq/bq), Q
sequential). dk/dv are produced per *query* head and group-summed to the
GQA kv heads in XLA (one cheap reshape-sum, no kernel-side cross-head
accumulation).

VMEM budget per grid point (bq = bk = 128, dh <= 256, fp32 accumulators):
q/k/v tiles 3*128*256*4B = 384 KiB + acc 128*256*4B = 128 KiB + stats — well
under the ~16 MiB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG as _NEG
from repro.kernels.common import CompilerParams as _CompilerParams
from repro.kernels.common import default_interpret


def _causal_iota(bq, bk, iq, ik):
    pos_q = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    pos_k = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return pos_q >= pos_k


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
            bq, bk, causal, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip KV blocks strictly in the future of the whole Q block
    run = (ik * bk <= iq * bq + (bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # (bq, dh)
        k = k_ref[0].astype(jnp.float32)             # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s * scale                                # (bq, bk)
        if causal:
            keep = _causal_iota(bq, bk, iq, ik)
            s = jnp.where(keep, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(keep, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dq_ref,
                   dq_acc, *, bq, bk, causal, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    run = (ik * bk <= iq * bq + (bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dsum = dsum_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        p = jnp.exp(s - lse[:, None])
        if causal:
            p = jnp.where(_causal_iota(bq, bk, iq, ik), p, 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - dsum[:, None]) * scale
        dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0] = dq_acc[...]


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, bq, bk, causal,
                    scale):
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = (iq * bq + (bq - 1) >= ik * bk) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dsum = dsum_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        p = jnp.exp(s - lse[:, None])
        if causal:
            p = jnp.where(_causal_iota(bq, bk, iq, ik), p, 0.0)
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - dsum[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0] = dk_acc[...]
        dv_ref[0] = dv_acc[...]


def _flatten(q, k, v):
    B, H, N, dh = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    return (q.reshape(B * H, N, dh), k.reshape(B * Hkv, M, dh),
            v.reshape(B * Hkv, M, dh))


def _kv_index(H, Hkv):
    g = H // Hkv

    def index(bh, iq, ik):
        return ((bh // H) * Hkv + (bh % H) // g, ik, 0)
    return index


def _fwd_call(q, k, v, causal, bq, bk, interpret):
    B, H, N, dh = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    qf, kf, vf = _flatten(q, k, v)
    kv_index = _kv_index(H, Hkv)
    grid = (B * H, N // bq, M // bk)
    out, lse = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal,
                          scale=1.0 / (dh ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bq), lambda bh, iq, ik: (bh, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, N, dh), q.dtype),
            jax.ShapeDtypeStruct((B * H, N), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, N, dh), lse


def _bwd_call(q, k, v, out, lse, do, causal, bq, bk, interpret):
    B, H, N, dh = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    g = H // Hkv
    qf, kf, vf = _flatten(q, k, v)
    dof = do.reshape(B * H, N, dh)
    dsum = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    dsum = dsum.reshape(B * H, N)
    kv_index = _kv_index(H, Hkv)
    scale = 1.0 / (dh ** 0.5)
    params = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))

    q_at = lambda bh, iq, ik: (bh, iq, 0)
    r_at = lambda bh, iq, ik: (bh, iq)
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=(B * H, N // bq, M // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_at),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bq, dh), q_at),
            pl.BlockSpec((1, bq), r_at),
            pl.BlockSpec((1, bq), r_at),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), q_at),
        out_shape=jax.ShapeDtypeStruct((B * H, N, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(qf, kf, vf, dof, lse, dsum)

    # dk/dv per *query* head; the kv-head group sum happens below in XLA
    q_at2 = lambda bh, ik, iq: (bh, iq, 0)
    r_at2 = lambda bh, ik, iq: (bh, iq)
    kv_at2 = lambda bh, ik, iq: kv_index(bh, 0, ik)
    k_out = lambda bh, ik, iq: (bh, ik, 0)
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=(B * H, M // bk, N // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_at2),
            pl.BlockSpec((1, bk, dh), kv_at2),
            pl.BlockSpec((1, bk, dh), kv_at2),
            pl.BlockSpec((1, bq, dh), q_at2),
            pl.BlockSpec((1, bq), r_at2),
            pl.BlockSpec((1, bq), r_at2),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, dh), k_out),
            pl.BlockSpec((1, bk, dh), k_out),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, M, dh), jnp.float32),
            jax.ShapeDtypeStruct((B * H, M, dh), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, dh), jnp.float32),
            pltpu.VMEM((bk, dh), jnp.float32),
        ],
        compiler_params=params,
        interpret=interpret,
    )(qf, kf, vf, dof, lse, dsum)

    dq = dq.reshape(B, H, N, dh).astype(q.dtype)
    dk = dk.reshape(B, Hkv, g, M, dh).sum(2).astype(k.dtype)
    dv = dv.reshape(B, Hkv, g, M, dh).sum(2).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash(causal, bq, bk, interpret, q, k, v):
    out, _ = _fwd_call(q, k, v, causal, bq, bk, interpret)
    return out


def _flash_fwd(causal, bq, bk, interpret, q, k, v):
    out, lse = _fwd_call(q, k, v, causal, bq, bk, interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, bq, bk, interpret, res, do):
    q, k, v, out, lse = res
    return _bwd_call(q, k, v, out, lse, do, causal, bq, bk, interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret=None) -> jax.Array:
    """q: (B,H,N,dh); k,v: (B,Hkv,M,dh) -> (B,H,N,dh). Differentiable."""
    N, M = q.shape[2], k.shape[2]
    bq = min(bq, N)
    bk = min(bk, M)
    assert N % bq == 0 and M % bk == 0, (N, bq, M, bk)
    return _flash(bool(causal), int(bq), int(bk),
                  default_interpret(interpret), q, k, v)
