"""Shared plumbing for the Pallas kernels.

Two things every kernel file needs:

* ``default_interpret``: the platform-derived Pallas interpret default.
  Kernels compile with Mosaic only on TPU; everywhere else (CPU CI, the
  dev container) they run in interpret mode with identical semantics.
  Callers that pass ``interpret=None`` get the derived default, so a
  call site that forgets ``interpret=False`` on TPU cannot silently
  benchmark interpret mode (and a CPU caller cannot crash into Mosaic).
* ``float0_like``: custom-VJP cotangents for integer operands (membership
  indices, positions). jax requires ``float0`` for int-dtype primals.
* ``FUSED_RESIDENT_ELEMS`` / ``fused_paged_default``: the shared rule for
  when the fused routing kernel keeps the whole (N, dh) sequence plane
  resident in VMEM vs pages it through double-buffered DMA chunks. The
  kernel layer, the backend registry, and the benches all derive from
  this one constant so the auto-switch point cannot drift between them.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import numpy as np
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG = -1e9

# N*dh budget for whole-plane VMEM residency in the fused routing kernel.
# At or below it the unpaged kernel (plane as a pipelined input block) is
# the fast path: one bulk DMA per (batch*head) plane, row pulls from VMEM.
# Above it the paged kernel streams member rows from HBM instead — was the
# hard `max_seq_elems` registration cliff before the paged path existed.
FUSED_RESIDENT_ELEMS = 8192 * 128


def fused_paged_default(n: int, dh: int, paged: Optional[bool] = None) -> bool:
    """Resolve a ``paged`` argument for the fused routing kernel: None
    auto-pages exactly when the sequence plane would blow the VMEM
    residency budget; an explicit bool wins."""
    if paged is None:
        return n * dh > FUSED_RESIDENT_ELEMS
    return bool(paged)


def default_interpret(interpret: Optional[bool] = None,
                      platform: Optional[str] = None) -> bool:
    """Resolve an ``interpret`` argument: None derives from the platform
    (compiled on TPU, interpret elsewhere); an explicit bool wins.
    ``platform`` overrides the detected backend (attn.attend passes the
    platform it resolved backends against) — this function is the single
    source of the rule.

    ``REPRO_FORCE_INTERPRET=1`` forces interpret mode for derived (None)
    arguments: paired with ``REPRO_ATTN_PLATFORM=tpu`` it lets tests run
    the full TPU backend-resolution path (fused apply + paged decode) on
    a CPU host without crashing into Mosaic. Explicit bools still win.
    """
    if interpret is None:
        if os.environ.get("REPRO_FORCE_INTERPRET", "") not in ("", "0"):
            return True
        return (platform or jax.default_backend()) != "tpu"
    return bool(interpret)


def float0_like(x):
    """Zero cotangent for an integer-dtype primal (custom_vjp bwd)."""
    return np.zeros(np.shape(x), jax.dtypes.float0)
