"""Shared plumbing for the Pallas kernels.

Two things every kernel file needs:

* ``default_interpret``: the platform-derived Pallas interpret default.
  Kernels compile with Mosaic only on TPU; everywhere else (CPU CI, the
  dev container) they run in interpret mode with identical semantics.
  Callers that pass ``interpret=None`` get the derived default, so a
  call site that forgets ``interpret=False`` on TPU cannot silently
  benchmark interpret mode (and a CPU caller cannot crash into Mosaic).
* ``float0_like``: custom-VJP cotangents for integer operands (membership
  indices, positions). jax requires ``float0`` for int-dtype primals.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import numpy as np
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG = -1e9


def default_interpret(interpret: Optional[bool] = None,
                      platform: Optional[str] = None) -> bool:
    """Resolve an ``interpret`` argument: None derives from the platform
    (compiled on TPU, interpret elsewhere); an explicit bool wins.
    ``platform`` overrides the detected backend (attn.attend passes the
    platform it resolved backends against) — this function is the single
    source of the rule.

    ``REPRO_FORCE_INTERPRET=1`` forces interpret mode for derived (None)
    arguments: paired with ``REPRO_ATTN_PLATFORM=tpu`` it lets tests run
    the full TPU backend-resolution path (fused apply + paged decode) on
    a CPU host without crashing into Mosaic. Explicit bools still win.
    """
    if interpret is None:
        if os.environ.get("REPRO_FORCE_INTERPRET", "") not in ("", "0"):
            return True
        return (platform or jax.default_backend()) != "tpu"
    return bool(interpret)


def float0_like(x):
    """Zero cotangent for an integer-dtype primal (custom_vjp bwd)."""
    return np.zeros(np.shape(x), jax.dtypes.float0)
