"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

These are *definitions*, deliberately naive: O(n^2) materialized logits with
fp32 softmax. The framework's XLA paths (core/attention.py etc.) are
separately tested against these same semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BIG_NEG = -1e9


def flash_attention_ref(q, k, v, causal=True):
    """q: (B,H,N,dh); k,v: (B,Hkv,M,dh) -> (B,H,N,dh)."""
    B, H, N, dh = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, N, dh)
    s = jnp.einsum("bhgnd,bhmd->bhgnm", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(dh)
    if causal:
        mask = jnp.arange(N)[:, None] >= jnp.arange(M)[None, :]
        s = jnp.where(mask, s, _BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgnm,bhmd->bhgnd", p.astype(v.dtype), v)
    return o.reshape(B, H, N, dh)


def local_attention_ref(q, k, v, window, causal=True):
    """Blocked local attention: block b attends blocks {b-1, b} (causal)."""
    B, H, N, dh = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    w = min(window, N)
    assert N % w == 0, "ref requires N divisible by window"
    pos = jnp.arange(N)
    blk = pos // w
    diff = blk[:, None] - blk[None, :]
    if causal:
        keep = (diff >= 0) & (diff <= 1) & (pos[:, None] >= pos[None, :])
    else:
        keep = jnp.abs(diff) <= 1          # blocks b-1, b, b+1
    qg = q.reshape(B, Hkv, g, N, dh)
    s = jnp.einsum("bhgnd,bhmd->bhgnm", qg, k).astype(jnp.float32)
    s = jnp.where(keep, s / jnp.sqrt(dh), _BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgnm,bhmd->bhgnd", p.astype(v.dtype), v)
    return o.reshape(B, H, N, dh)


def routed_attention_blocks_ref(qg, kg, vg, pos_q, pos_k, causal=True,
                                valid_k=None):
    """Intra-cluster attention on gathered blocks.

    qg/kg/vg: (B,H,k,w,dh); pos_q/pos_k: (B,H,k,w) int32.
    The causal mask compares *original sequence positions*.
    """
    dh = qg.shape[-1]
    s = jnp.einsum("bhkwd,bhkud->bhkwu", qg, kg).astype(jnp.float32)
    s = s / jnp.sqrt(dh)
    if causal:
        s = jnp.where(pos_q[..., :, None] >= pos_k[..., None, :], s,
                      _BIG_NEG)
    if valid_k is not None:
        s = jnp.where(valid_k[..., None, :], s, _BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhkwu,bhkud->bhkwd", p.astype(vg.dtype), vg)
