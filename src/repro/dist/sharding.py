"""Production SPMD sharding rules for ("data", "model") meshes.

One source of truth for how every pytree in the system is laid out
(DESIGN.md §6 has the full rule table):

  params / optimizer state   Megatron-style tensor parallelism over
      "model": column-parallel up/qkv projections (output dim sharded),
      row-parallel down/out projections (input dim sharded), vocab-
      parallel embedding. With ``fsdp=True`` each 2D weight is
      additionally sharded over the data axes on its non-model dim
      (zero-3; used for the >20B configs, see launch/dryrun.py).
  batches                    leading (batch) dim over the data axes.
  activations                ``make_constrain_fn(mesh, seq_parallel)``
      builds the constraint applied between scan groups in
      models/transformer.apply_stack: batch over "data" and — with
      sequence parallelism — the sequence dim over "model", re-gathered
      by the function's ``.epilogue`` before the LM head.
  decode caches / slot pools  slot axis (position 1) over the data axes
      and head axes over "model" (serve/engine continuous batching).

Rules are name-based over the leaf *path*: adam's m/v moment trees
reuse the param leaf names, so optimizer state inherits the param
layout for free, while adafactor's factored statistics (vr/vc) stay
replicated (they are sublinear-size by construction). Every assignment
is shape-checked — a dim that does not divide its mesh axis falls back
to replicated for that dim. Sharding here is purely a layout choice;
GSPMD semantics guarantee the partitioned program computes the same
function as the single-device one (parity tested in tests/test_dist.py).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Column-parallel 2D cores (d_in, d_out): d_out over "model". The
# contraction dim stays whole — no collective until the row-parallel
# partner. Leading stacked dims (scan groups G, MoE experts E) are
# handled by indexing from the end of the shape.
_COL_PARALLEL = {"wq", "wk", "wv", "w_up", "w_gate", "in_proj", "w_in",
                 "w_gate_branch", "w_a", "w_x", "unembed"}
# Row-parallel (d_in, d_out): d_in over "model" — consumes the
# column-parallel layout with a single psum on the way out.
_ROW_PARALLEL = {"wo", "w_down", "out_proj", "w_out"}
_MODEL_BIAS = {"bq", "bk", "bv"}       # follow their column-parallel weight
_VOCAB_PARALLEL = {"tok"}              # (V, d): padded vocab over "model"
# Small / irregular leaves that stay replicated: norm affines, router
# (d, E) with tiny E, depthwise convs, SSD per-head scalars, gates, and
# adafactor's factored moments (vr/vc drop a dim vs their param, so the
# name-based weight rules must not fire through them).
_REPLICATED = {"vr", "vc", "scale", "bias", "router", "conv_w", "conv_b",
               "A_log", "D", "dt_bias", "b_a", "b_x", "mask_emb",
               "xgate_attn", "xgate_ffn", "count"}

# Decode-cache head axes: attention-backend leaves declare theirs through
# the repro.attn registry (CacheLayout.head_axes, pool coords
# (G, B, head, ...)); the SSD recurrent state is the one non-attention
# cache with a head axis and is appended here.
def _cache_head_axes():
    from repro import attn
    hints = dict(attn.cache_head_axes())
    hints["state"] = 2
    return hints


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------
def _axis_size(mesh, axis) -> int:
    """Devices along ``axis``; axis may be a name, a tuple of names, or
    None. Names absent from the mesh count as size 1."""
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= _axis_size(mesh, a)
        return n
    return dict(mesh.shape).get(axis, 1)


def dp_axes(mesh):
    """The data-parallel axes: multi-pod meshes fold "pod" into them."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _fits(shape, dim, mesh, axis) -> bool:
    size = _axis_size(mesh, axis)
    return size > 1 and shape[dim] % size == 0


def _path_names(path):
    return [e.key for e in path if isinstance(e, jax.tree_util.DictKey)]


# ---------------------------------------------------------------------------
# Parameter / optimizer-state rules
# ---------------------------------------------------------------------------
def _leaf_spec(path, leaf, mesh, fsdp: bool) -> NamedSharding:
    names = _path_names(path)
    shape = tuple(leaf.shape)
    nd = len(shape)
    dp = dp_axes(mesh)
    spec = [None] * nd
    rule = None
    # innermost recognized name wins (optimizer wrappers keep param names
    # as the path suffix; adafactor stats hit _REPLICATED first)
    for name in reversed(names):
        if name in _REPLICATED:
            rule = "repl"
        elif name in _COL_PARALLEL and nd >= 2:
            rule = "col"
        elif name in _ROW_PARALLEL and nd >= 2:
            rule = "row"
        elif name in _MODEL_BIAS and nd >= 1:
            rule = "bias"
        elif name in _VOCAB_PARALLEL and nd >= 2:
            rule = "vocab"
        if rule:
            break
    if rule == "col":
        if _fits(shape, nd - 1, mesh, "model"):
            spec[nd - 1] = "model"
        if fsdp and _fits(shape, nd - 2, mesh, dp):
            spec[nd - 2] = dp
    elif rule == "row":
        if _fits(shape, nd - 2, mesh, "model"):
            spec[nd - 2] = "model"
        if fsdp and _fits(shape, nd - 1, mesh, dp):
            spec[nd - 1] = dp
    elif rule == "bias":
        if _fits(shape, nd - 1, mesh, "model"):
            spec[nd - 1] = "model"
    elif rule == "vocab":
        if _fits(shape, nd - 2, mesh, "model"):
            spec[nd - 2] = "model"
        if fsdp and _fits(shape, nd - 1, mesh, dp):
            spec[nd - 1] = dp
    return NamedSharding(mesh, P(*spec))


def params_sharding(mesh, params, fsdp: bool = False):
    """Name-rule sharding for a param-shaped tree (params, adam moments,
    grads — anything whose leaf paths end in the param names)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: _leaf_spec(p, leaf, mesh, fsdp), params)


def kstate_sharding(mesh, kstate):
    """k-means centroid state, leaves (G, Hr, kc, dh): routing-head axis
    over "model" (aligned with the head-sharded attention), else
    replicated."""
    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 3 and _fits(leaf.shape, 1, mesh, "model"):
            spec[1] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, kstate)


def ef_sharding(mesh, ef_state):
    """Error-feedback residuals (train_step.TrainState.ef_state): leaves
    are (D, *param_shape) — the leading per-device axis goes over the
    data axes, and the remaining dims inherit the param name rules via
    the path suffix (the rules index from the END of the shape, so the
    prepended device dim is transparent to them). No fsdp on the weight
    dims: the data axes are already spent on the device axis."""
    def one(path, leaf):
        # _leaf_spec returns a full-rank spec for this leaf (device dim
        # included, always None there: the name rules index from the end)
        spec = list(_leaf_spec(path, leaf, mesh, fsdp=False).spec)
        if _fits(leaf.shape, 0, mesh, dp_axes(mesh)):
            spec[0] = dp_axes(mesh)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, ef_state)


def train_state_sharding(mesh, ts, fsdp: bool = False):
    """Sharding tree for a TrainState (params, kstate, opt_state, step,
    ef_state).

    ``ts`` may hold arrays or ShapeDtypeStructs (jax.eval_shape output).
    The optimizer state goes through the same name rules as the params:
    adam's m/v mirror the param layout, adafactor's factored stats and
    both counters replicate. The error-feedback residual (None unless
    grad compression is on) keeps its leading device axis over data.
    """
    from repro.train.train_step import TrainState
    return TrainState(
        params=params_sharding(mesh, ts.params, fsdp),
        kstate=kstate_sharding(mesh, ts.kstate),
        opt_state=params_sharding(mesh, ts.opt_state, fsdp),
        step=NamedSharding(mesh, P()),
        ef_state=ef_sharding(mesh, ts.ef_state))


# ---------------------------------------------------------------------------
# Data / activation / cache rules
# ---------------------------------------------------------------------------
def batch_sharding(mesh, batch):
    """Input batches: leading dim over the data axes (when it divides)."""
    dp = dp_axes(mesh)

    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 1 and _fits(leaf.shape, 0, mesh, dp):
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, batch)


def replicated(mesh, tree):
    """Fully replicated sharding tree (metrics, small shared state)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def cache_sharding(mesh, cache, batch: int):
    """Decode caches / engine slot pools: every leaf is (G, B, ...) with
    the slot (batch) axis at position 1 — slots over the data axes and
    the head axes over "model", at the positions the attention backends
    declare for their cache layouts (repro.attn registry hints)."""
    dp = dp_axes(mesh)
    head_axes = _cache_head_axes()

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        spec = [None] * leaf.ndim
        if (leaf.ndim >= 2 and leaf.shape[1] == batch
                and _fits(leaf.shape, 1, mesh, dp)):
            spec[1] = dp
        ax = head_axes.get(name)
        if (ax is not None and leaf.ndim > ax
                and _fits(leaf.shape, ax, mesh, "model")):
            spec[ax] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache)


def make_constrain_fn(mesh, seq_parallel: bool = False,
                      fsdp_prefetch: bool = False, attn_specs=()):
    """Activation constraint for the residual stream, applied between
    scan groups (models/transformer.apply_stack) and at stack entry.

    ``attn_specs``: the model's AttentionSpecs (attn.specs_for_model).
    With ``seq_parallel`` they are validated against the layout: a
    routing spec whose segment fold does not align with the model axis
    (attn.seq_shardable) would silently re-gather the sequence inside
    every balanced top-k — that is rejected loudly here instead of
    showing up as a collective regression.

    x is (B, N, d): batch over the data axes; with ``seq_parallel`` the
    sequence dim is additionally sharded over "model" (Megatron-SP — the
    norm/FFN work between attention blocks runs on 1/TP of the tokens).
    The returned function carries an ``.epilogue`` attribute (only when
    seq_parallel) that re-gathers the sequence dim before the LM head,
    keeping the vocab-parallel logits layout intact.

    With ``fsdp_prefetch`` it additionally carries a ``.gather_params``
    attribute: applied to a scan group's weight slice at group entry
    (models/transformer.apply_stack), it constrains every fsdp-sharded
    weight to its TP-only layout (data axes gathered). That tags the
    zero-3 all-gather at ONE known point at the top of each group body —
    instead of GSPMD materializing shards lazily at first use mid-group —
    which is what lets XLA's latency-hiding scheduler hoist the gather of
    group i+1 over the tail compute of group i.

    Dims that do not divide their axis stay unconstrained — GSPMD picks.
    """
    if seq_parallel and attn_specs:
        from repro import attn
        tp = _axis_size(mesh, "model")
        bad = [s for s in attn_specs if not attn.seq_shardable(s, tp)]
        if bad:
            raise ValueError(
                f"seq_parallel over a {tp}-way model axis, but "
                f"{len(bad)} attention spec(s) route globally "
                f"(RoutingConfig.segments must be a multiple of {tp} for "
                f"shard-local balanced top-k): "
                f"{[f'{s.variant}/segments={s.routing.segments}' for s in bad]}")
    dp = dp_axes(mesh)

    def constrain(x):
        if getattr(x, "ndim", 0) != 3:
            return x
        B, N, _ = x.shape
        spec = P(dp if _fits(x.shape, 0, mesh, dp) else None,
                 "model" if (seq_parallel and _fits(x.shape, 1, mesh,
                                                    "model")) else None,
                 None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    if seq_parallel:
        def epilogue(x):
            spec = P(dp if _fits(x.shape, 0, mesh, dp) else None, None, None)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        constrain.epilogue = epilogue

    if fsdp_prefetch:
        def gather_params(p_group):
            def one(path, leaf):
                if getattr(leaf, "ndim", 0) < 2:
                    return leaf
                return jax.lax.with_sharding_constraint(
                    leaf, _leaf_spec(path, leaf, mesh, fsdp=False))
            return jax.tree_util.tree_map_with_path(one, p_group)
        constrain.gather_params = gather_params
    return constrain
