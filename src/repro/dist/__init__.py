"""Distribution subsystem: SPMD sharding rules for ("data", "model")
meshes and int8 wire compression for gradient collectives.

  sharding     one source of truth for how every pytree in the system is
               partitioned (params/opt state, batches, activations,
               decode caches) — see DESIGN.md §6 for the rule table.
  compression  `int8_psum_mean`, a chunked int8-quantized allreduce that
               keeps fp32 tensors off the interconnect, and
               `int8_ef_psum_mean`, its error-feedback variant whose fp32
               residual (TrainState.ef_state) makes compressed training
               converge like fp32 (DESIGN.md §6).
"""
from repro.dist import compression, sharding  # noqa: F401
