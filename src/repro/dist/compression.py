"""Gradient wire compression: chunked int8-quantized allreduce.

Two entry points, both drop-ins for ``jax.lax.pmean(x, axis_name)``
inside ``shard_map``:

  ``int8_psum_mean(x, axis)``            stateless; each call eats the
      quantization error (~1% relative, fine for one-shot reductions).
  ``int8_ef_psum_mean(x, err, axis)``    error feedback (1-bit Adam
      lineage, Tang et al. 2021): returns ``(mean, new_err)`` where the
      fp32 residual carries exactly what the wire dropped, so the error
      is re-injected next step instead of lost and the time-averaged
      applied mean is unbiased. This is what lets int8 gradient exchange
      converge like fp32 over a training run (DESIGN.md §6).

``int8_psum_mean(x, axis_name)`` moves int8 payloads over the
interconnect instead of fp32:

  1. the local tensor is flattened, padded, and split into ``axis_size``
     equal chunks; each chunk is group-quantized (symmetric int8, one
     fp32 scale per ``group`` values);
  2. one ``all_to_all`` exchanges the int8 chunks (plus the tiny fp32
     scales) so device j holds every device's j-th chunk — a
     reduce-scatter at 1/4 of the fp32 payload width;
  3. each device dequantizes and averages its chunk in fp32, re-quantizes
     the result, and an int8 ``all_gather`` rebuilds the full mean
     everywhere.

Wire bytes per device: ~2·N/4 (+ N/group fp32 scales) versus ~2·N for a
ring fp32 allreduce. The fp32 accumulation happens device-local, so the
only losses are the two quantization hops, each bounded by the per-group
amax/254; with the default group of 128 the end-to-end relative error on
gradient-like tensors is ~1% (checked against the exact fp32 mean, and
the HLO is asserted to carry ``s8[`` collective payloads and no full-
width fp32 tensor, in tests/test_dist.py::test_int8_wire_allreduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-30


def _quantize(x: jax.Array, group: int):
    """(..., M) fp32 -> int8 codes (..., M) + scales (..., M // group)."""
    g = x.reshape(x.shape[:-1] + (x.shape[-1] // group, group))
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale[..., 0]


def _dequantize(q: jax.Array, scale: jax.Array, group: int) -> jax.Array:
    g = q.astype(jnp.float32).reshape(
        q.shape[:-1] + (q.shape[-1] // group, group))
    return (g * scale[..., None]).reshape(q.shape)


def _pad_chunks(x: jax.Array, n: int, group: int):
    """Flatten to fp32 and split into ``n`` equal group-aligned chunks."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % (n * group)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(n, -1), pad         # row j is bound for device j


def _wire_mean(chunks: jax.Array, axis_name: str, group: int):
    """Two-hop int8 mean of per-device ``chunks`` (n, c).

    Returns ``(out, e1, e2)``: the rebuilt full mean (n*c,), the local
    hop-1 quantization error (n, c) — what THIS device failed to put on
    the wire — and the hop-2 re-quantization error (c,) of the mean
    chunk this device owns. Callers without error feedback ignore
    e1/e2 (dead-code-eliminated by XLA).
    """
    q, s = _quantize(chunks, group)
    e1 = chunks - _dequantize(q, s, group)
    q = jax.lax.all_to_all(q, axis_name, 0, 0)       # s8 on the wire
    s = jax.lax.all_to_all(s, axis_name, 0, 0)
    mean = jnp.mean(_dequantize(q, s, group), axis=0)
    q2, s2 = _quantize(mean, group)
    e2 = mean - _dequantize(q2, s2, group)
    q2 = jax.lax.all_gather(q2, axis_name, axis=0, tiled=True)   # s8 again
    s2 = jax.lax.all_gather(s2, axis_name, axis=0, tiled=True)
    return _dequantize(q2, s2, group), e1, e2


def int8_psum_mean(x: jax.Array, axis_name: str, *,
                   group: int = 128) -> jax.Array:
    """Mean of ``x`` over the mapped axis with int8 wire format.

    Call inside ``shard_map``/``pmap`` where ``axis_name`` is bound.
    Shape- and dtype-preserving; accumulation is fp32 regardless of the
    input dtype.
    """
    n = jax.lax.psum(1, axis_name)          # static axis size
    if n == 1:
        return x
    shape, dtype = x.shape, x.dtype
    chunks, pad = _pad_chunks(x, n, group)
    out, _, _ = _wire_mean(chunks, axis_name, group)
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


def int8_ef_psum_mean(x: jax.Array, err: jax.Array, axis_name: str, *,
                      group: int = 128):
    """Error-feedback mean of ``x`` over the mapped axis, int8 wire.

    Compresses ``x + err`` instead of ``x`` and returns
    ``(mean, new_err)`` where ``new_err`` (fp32, shape of ``x``) is
    everything this round dropped:

      * the full hop-1 quantization error (this device's contribution
        that never reached the wire — recovered next round when every
        device re-injects its own, each worth 1/n of the mean);
      * this device's chunk of the hop-2 (mean re-quantization) error,
        scaled by the axis size n: it is lost from the MEAN itself, and
        the next round's averaging divides the re-injection by n again.

    Repeated application makes the time-averaged applied mean unbiased
    — the residual stays bounded by ~one quantization step per element
    instead of the bias accumulating
    (tests/test_dist.py::test_error_feedback_unbiased). On a 1-device
    axis there is no wire and no error: identity passthrough.
    """
    n = jax.lax.psum(1, axis_name)          # static axis size
    if n == 1:
        return x, err
    shape, dtype = x.shape, x.dtype
    comp = x.astype(jnp.float32) + err.astype(jnp.float32).reshape(shape)
    chunks, pad = _pad_chunks(comp, n, group)
    out, e1, e2 = _wire_mean(chunks, axis_name, group)
    j = jax.lax.axis_index(axis_name)
    new_err = e1.at[j].add(n * e2).reshape(-1)
    if pad:
        out, new_err = out[:-pad], new_err[:-pad]
    return (out.reshape(shape).astype(dtype),
            new_err.reshape(err.shape).astype(jnp.float32))
