"""Gradient wire compression: chunked int8-quantized allreduce.

``int8_psum_mean(x, axis_name)`` is a drop-in for
``jax.lax.pmean(x, axis_name)`` inside ``shard_map`` that moves int8
payloads over the interconnect instead of fp32:

  1. the local tensor is flattened, padded, and split into ``axis_size``
     equal chunks; each chunk is group-quantized (symmetric int8, one
     fp32 scale per ``group`` values);
  2. one ``all_to_all`` exchanges the int8 chunks (plus the tiny fp32
     scales) so device j holds every device's j-th chunk — a
     reduce-scatter at 1/4 of the fp32 payload width;
  3. each device dequantizes and averages its chunk in fp32, re-quantizes
     the result, and an int8 ``all_gather`` rebuilds the full mean
     everywhere.

Wire bytes per device: ~2·N/4 (+ N/group fp32 scales) versus ~2·N for a
ring fp32 allreduce. The fp32 accumulation happens device-local, so the
only losses are the two quantization hops, each bounded by the per-group
amax/254; with the default group of 128 the end-to-end relative error on
gradient-like tensors is ~1% (checked against the exact fp32 mean, and
the HLO is asserted to carry ``s8[`` collective payloads and no full-
width fp32 tensor, in tests/test_dist.py::test_int8_wire_allreduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-30


def _quantize(x: jax.Array, group: int):
    """(..., M) fp32 -> int8 codes (..., M) + scales (..., M // group)."""
    g = x.reshape(x.shape[:-1] + (x.shape[-1] // group, group))
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale[..., 0]


def _dequantize(q: jax.Array, scale: jax.Array, group: int) -> jax.Array:
    g = q.astype(jnp.float32).reshape(
        q.shape[:-1] + (q.shape[-1] // group, group))
    return (g * scale[..., None]).reshape(q.shape)


def int8_psum_mean(x: jax.Array, axis_name: str, *,
                   group: int = 128) -> jax.Array:
    """Mean of ``x`` over the mapped axis with int8 wire format.

    Call inside ``shard_map``/``pmap`` where ``axis_name`` is bound.
    Shape- and dtype-preserving; accumulation is fp32 regardless of the
    input dtype.
    """
    n = jax.lax.psum(1, axis_name)          # static axis size
    if n == 1:
        return x
    shape, dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % (n * group)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    chunks = flat.reshape(n, -1)            # row j is bound for device j
    q, s = _quantize(chunks, group)
    q = jax.lax.all_to_all(q, axis_name, 0, 0)       # s8 on the wire
    s = jax.lax.all_to_all(s, axis_name, 0, 0)
    mean = jnp.mean(_dequantize(q, s, group), axis=0)
    q2, s2 = _quantize(mean, group)
    q2 = jax.lax.all_gather(q2, axis_name, axis=0, tiled=True)   # s8 again
    s2 = jax.lax.all_gather(s2, axis_name, axis=0, tiled=True)
    out = _dequantize(q2, s2, group)
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)
