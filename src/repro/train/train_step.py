"""Training step: loss, grads, microbatch accumulation, optimizer update.

`make_train_step(run)` returns a pure `(TrainState, batch) -> (TrainState,
metrics)` suitable for jax.jit / pjit. The k-means routing state rides in
TrainState and is refreshed from the forward pass (functional EMA).
Gradient accumulation scans over microbatches (bounds activation memory on
the train_4k cells); remat policy applies inside the model stack.

With `TrainConfig.grad_compression == "int8_ef"` the returned step is the
`shard_map`-based data-parallel variant (`make_compressed_train_step`):
every device computes grads on its shard of the batch, the cross-device
gradient mean goes over the wire as int8 with an error-feedback residual
carried in `TrainState.ef_state`, and the optimizer update runs replicated.
DESIGN.md §6 documents the wire format and residual placement.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models.model import apply_model, lm_loss, next_token_batch
from repro.obs import routing_stats as obs_rt
from repro.obs.trace import span
from repro.optim import make_optimizer, make_schedule

MOE_LB_COEF = 1e-2
MOE_Z_COEF = 1e-3


class TrainState(NamedTuple):
    params: Any
    kstate: Any
    opt_state: Any
    step: jax.Array
    # fp32 error-feedback residuals for int8 gradient compression: a
    # param-shaped tree whose leaves carry a leading (D,) device axis
    # (device i's residual is leaf[i]; sharded over the data axes by
    # dist/sharding.ef_sharding). None when grad_compression == "none".
    ef_state: Any = None


def _ef_devices(mesh=None) -> int:
    if mesh is not None:
        from repro.dist.sharding import _axis_size, dp_axes
        return _axis_size(mesh, dp_axes(mesh))
    return len(jax.devices())


def init_ef_state(params, num_devices: int):
    """Zero residuals, (D, *param.shape) fp32 per leaf.

    Host-side numpy zeros (lazy calloc pages), NOT jnp: the tree is D x
    total-params fp32 and would otherwise materialize on the default
    device before the caller's sharded device_put gets a chance."""
    import numpy as np
    return jax.tree.map(
        lambda p: np.zeros((num_devices,) + tuple(p.shape), np.float32),
        params)


def init_train_state(run: RunConfig, key: jax.Array,
                     mesh=None) -> TrainState:
    """``mesh`` sizes the error-feedback residual's device axis when
    grad compression is on (default: all local devices)."""
    from repro.models.model import init_model
    params, kstate = init_model(run.model, key)
    opt_init, _ = make_optimizer(run.train)
    ef = (init_ef_state(params, _ef_devices(mesh))
          if run.train.grad_compression == "int8_ef" else None)
    return TrainState(params, kstate, opt_init(params),
                      jnp.zeros((), jnp.int32), ef)


def make_loss_fn(run: RunConfig, impl=None, moe_impl="einsum",
                 constrain_fn: Optional[Callable] = None, mesh=None):
    mc, tc = run.model, run.train

    def loss_fn(params, kstate, batch, drop_rng):
        if mc.family == "encoder":
            inputs, targets = batch, batch["tokens"]
            loss_mask = batch.get("mask_spans")
        else:
            inputs, targets = next_token_batch(batch)
            loss_mask = None
        # needs_grad: this forward is differentiated — attention backend
        # resolution excludes (or, forced, loudly refuses) non-VJP kernels
        logits, new_k, aux = apply_model(
            params, kstate, inputs, mc, update_state=True, impl=impl,
            moe_impl=moe_impl, remat=tc.remat, drop_rng=drop_rng,
            constrain_fn=constrain_fn, mesh=mesh, needs_grad=True)
        pad = inputs.get("pad_mask")
        loss, metrics = lm_loss(logits, targets, pad, tc.z_loss, loss_mask)
        if mc.family == "moe":
            loss = (loss + MOE_LB_COEF * aux["moe_lb_loss"]
                    + MOE_Z_COEF * aux["moe_z_loss"])
        metrics = dict(metrics)
        aux = dict(aux)
        rstats = aux.pop("routing_stats", None)
        metrics.update({k: v for k, v in aux.items()})
        if rstats is not None:
            # routing-health telemetry (RoutingConfig.stats): model-wide
            # scalars ("routing/entropy", ...) + per-layer detail arrays
            # ("rt/{seg}/{layer}/{field}", leading (G,) group axis)
            metrics.update(obs_rt.summarize(rstats))
            metrics.update(obs_rt.flatten(rstats))
        metrics["loss"] = loss
        return loss, (new_k, metrics)

    return loss_fn


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, 0.0))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def make_grad_fn(run: RunConfig, loss_fn,
                 grad_constrain: Optional[Callable] = None):
    """`(params, kstate, batch, drop_rng) -> (grads, new_kstate, metrics)`
    with microbatch accumulation per `TrainConfig.grad_accum`. Shared by
    the plain (GSPMD) and the shard_map/compressed train-step variants —
    inside shard_map it operates on the device-local batch shard."""
    tc = run.train
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    gc = grad_constrain or (lambda g: g)

    def grad_fn(params, kstate, batch, drop_rng):
        A = tc.grad_accum
        if A <= 1:
            (loss, (new_k, metrics)), grads = vg(params, kstate, batch,
                                                 drop_rng)
            return gc(grads), new_k, dict(metrics)

        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), b)

        mb = micro(batch)
        acc_dt = jnp.dtype(tc.accum_dtype)

        def body(carry, xs):
            grads_acc, kst = carry
            (loss, (nk, metrics)), g = vg(params, kst, xs, drop_rng)
            grads_acc = gc(jax.tree.map(
                lambda a, b: a + b.astype(acc_dt), grads_acc, g))
            # metrics leave as stacked ys (meaned below) rather than a
            # carry: the metric *structure* is dynamic (routing-health
            # arrays appear per layer when RoutingConfig.stats is on),
            # so there is no fixed zero-template to initialize a carry
            return (grads_acc, nk), (loss, metrics)

        zeros = gc(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                params))
        (gacc, new_k), (losses, mstack) = jax.lax.scan(
            body, (zeros, kstate), mb)
        grads = jax.tree.map(lambda g: (g / A).astype(jnp.float32)
                             if g.dtype == jnp.float32 else g / A, gacc)
        metrics = {k: v.mean(0) for k, v in mstack.items()}
        metrics["loss"] = losses.mean()
        return grads, new_k, metrics

    return grad_fn


def _finish_step(tc, schedule, opt_update, ts: TrainState, grads, new_k,
                 metrics, new_ef):
    """Shared tail: clip, lr, optimizer update, state assembly."""
    with span("train/optimizer"):
        grads, gn = clip_by_global_norm(grads, tc.grad_clip)
        lr = schedule(ts.step + 1)
        new_params, new_opt = opt_update(grads, ts.opt_state, ts.params, lr)
    metrics["grad_norm"] = gn
    metrics["lr"] = lr
    return (TrainState(new_params, new_k, new_opt, ts.step + 1, new_ef),
            metrics)


def _drop_rng(run: RunConfig, step):
    return (jax.random.fold_in(jax.random.PRNGKey(run.train.seed), step)
            if run.model.dropout > 0 else None)


def make_train_step(run: RunConfig, impl=None, moe_impl="einsum",
                    constrain_fn: Optional[Callable] = None,
                    grad_transform: Optional[Callable] = None,
                    grad_constrain: Optional[Callable] = None,
                    mesh=None):
    """grad_transform: optional hook (e.g. gradient compression) applied to
    the accumulated grads before clipping. grad_constrain: sharding
    constraint pinning the fp32 accumulation buffers to the param layout
    (without it GSPMD may replicate the scan carry — 13x memory on the
    400B config, see EXPERIMENTS.md §Perf). mesh: the data mesh for the
    compressed variant (grad_compression == "int8_ef" dispatches to
    `make_compressed_train_step`; the GSPMD-only hooks are incompatible
    with the shard_map path and raise rather than silently dropping)."""
    if run.train.grad_compression == "int8_ef":
        dropped = [n for n, v in (("constrain_fn", constrain_fn),
                                  ("grad_transform", grad_transform),
                                  ("grad_constrain", grad_constrain))
                   if v is not None]
        if dropped:
            raise ValueError(
                f"{dropped} have no effect inside the shard_map-based "
                "int8_ef train step (no GSPMD partitioning to constrain); "
                "pass None or use grad_compression='none'")
        return make_compressed_train_step(run, impl=impl, moe_impl=moe_impl,
                                          mesh=mesh)
    tc = run.train
    # the mesh reaches attention-backend resolution (repro.attn): a
    # >1-device GSPMD mesh excludes supports_mesh=False kernels. The
    # shard_map/compressed variant stays mesh-less on purpose — inside
    # shard_map every program is single-device.
    loss_fn = make_loss_fn(run, impl, moe_impl, constrain_fn, mesh=mesh)
    _, opt_update = make_optimizer(tc)
    schedule = make_schedule(tc, run.model.d_model)
    grad_fn = make_grad_fn(run, loss_fn, grad_constrain)

    def train_step(ts: TrainState, batch: Dict[str, jax.Array]):
        with span("train/grad"):
            grads, new_k, metrics = grad_fn(ts.params, ts.kstate, batch,
                                            _drop_rng(run, ts.step))
        if grad_transform is not None:
            grads = grad_transform(grads)
        return _finish_step(tc, schedule, opt_update, ts, grads, new_k,
                            metrics, ts.ef_state)

    return train_step


def make_compressed_train_step(run: RunConfig, impl=None,
                               moe_impl="einsum", mesh=None):
    """Data-parallel train step with int8 error-feedback gradient
    compression (DESIGN.md §6).

    The grad computation runs inside `shard_map` over the data axes:
    params/kstate replicated, batch sharded on its leading dim, each
    device differentiating its local shard. The cross-device gradient
    mean then goes through `dist/compression.int8_ef_psum_mean` — int8
    payloads on the wire, per-device fp32 residual threaded through
    `TrainState.ef_state` — and kstate/metrics are pmean-synced (fp32,
    tiny). The optimizer update runs on the replicated mean outside the
    shard_map, so devices stay bit-identical.

    Data-parallel only: a mesh with a >1 "model" axis is rejected (the
    compressed exchange flattens whole gradients; tensor-parallel layouts
    go through the GSPMD path). Dropout uses one shared rng per step
    across devices. On a 1-device mesh the wire vanishes and the step
    degenerates to the exact uncompressed computation.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.compression import int8_ef_psum_mean
    from repro.dist.sharding import _axis_size, dp_axes

    tc = run.train
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    if _axis_size(mesh, "model") > 1:
        raise ValueError(
            "int8_ef grad compression is data-parallel only; got a mesh "
            f"with model axis size {_axis_size(mesh, 'model')}")
    dp = dp_axes(mesh)
    D = _axis_size(mesh, dp)
    if tc.global_batch % max(D, 1):
        raise ValueError(f"global_batch={tc.global_batch} must divide over "
                         f"{D} data-parallel devices")
    loss_fn = make_loss_fn(run, impl, moe_impl, None)
    grad_fn = make_grad_fn(run, loss_fn)
    _, opt_update = make_optimizer(tc)
    schedule = make_schedule(tc, run.model.d_model)

    def pmean_tree(t):
        return jax.tree.map(
            lambda a: jax.lax.pmean(a, dp)
            if jnp.issubdtype(a.dtype, jnp.inexact) else a, t)

    def sync_metrics(metrics):
        # means of per-shard means are exact for equal shard sizes —
        # except count-like entries, which are sums over the shard
        return {k: (jax.lax.psum(v, dp) if k == "tokens"
                    else jax.lax.pmean(v, dp))
                for k, v in metrics.items()}

    # leaves too small to amortize the int8 machinery (norm scales,
    # biases: padding to D*group would exceed the payload saved) take
    # the exact fp32 pmean; their residual stays identically zero
    min_compress = D * 128

    def sharded_grads(params, kstate, ef, batch, drop_rng):
        with span("train/grad"):
            grads, new_k, metrics = grad_fn(params, kstate, batch,
                                            drop_rng)
        gl, tdef = jax.tree_util.tree_flatten(grads)
        el = jax.tree_util.tree_leaves(ef)
        with span("train/exchange"):
            pairs = [int8_ef_psum_mean(g, e[0], dp)
                     if g.size >= min_compress
                     else (jax.lax.pmean(g, dp), e[0])
                     for g, e in zip(gl, el)]
        mean_g = jax.tree_util.tree_unflatten(tdef, [m for m, _ in pairs])
        new_ef = jax.tree_util.tree_unflatten(tdef,
                                              [e[None] for _, e in pairs])
        # kstate EMA / metrics are computed on the local shard; sync the
        # fp32 leaves exactly (tiny payloads — not worth compressing)
        return mean_g, new_ef, pmean_tree(new_k), sync_metrics(metrics)

    smapped = shard_map(
        sharded_grads, mesh=mesh,
        in_specs=(P(), P(), P(dp), P(dp), P()),
        out_specs=(P(), P(dp), P(), P()),
        check_rep=False)

    def train_step(ts: TrainState, batch: Dict[str, jax.Array]):
        lead = {e.shape[0] for e in jax.tree_util.tree_leaves(ts.ef_state)}
        if lead and lead != {D}:
            # a mismatched residual would be silently row-sliced by the
            # shard_map in_spec — wrong EF bookkeeping, the exact bias
            # this machinery exists to cancel
            raise ValueError(
                f"ef_state device axis {sorted(lead)} != mesh data size "
                f"{D}; init_train_state(run, key, mesh=) with this mesh")
        mean_g, new_ef, new_k, metrics = smapped(
            ts.params, ts.kstate, ts.ef_state, batch,
            _drop_rng(run, ts.step))
        return _finish_step(tc, schedule, opt_update, ts, mean_g, new_k,
                            metrics, new_ef)

    return train_step
