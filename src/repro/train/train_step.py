"""Training step: loss, grads, microbatch accumulation, optimizer update.

`make_train_step(run)` returns a pure `(TrainState, batch) -> (TrainState,
metrics)` suitable for jax.jit / pjit. The k-means routing state rides in
TrainState and is refreshed from the forward pass (functional EMA).
Gradient accumulation scans over microbatches (bounds activation memory on
the train_4k cells); remat policy applies inside the model stack.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models.model import apply_model, lm_loss, next_token_batch
from repro.optim import make_optimizer, make_schedule

MOE_LB_COEF = 1e-2
MOE_Z_COEF = 1e-3


class TrainState(NamedTuple):
    params: Any
    kstate: Any
    opt_state: Any
    step: jax.Array


def init_train_state(run: RunConfig, key: jax.Array) -> TrainState:
    from repro.models.model import init_model
    params, kstate = init_model(run.model, key)
    opt_init, _ = make_optimizer(run.train)
    return TrainState(params, kstate, opt_init(params),
                      jnp.zeros((), jnp.int32))


def make_loss_fn(run: RunConfig, impl="xla", moe_impl="einsum",
                 constrain_fn: Optional[Callable] = None):
    mc, tc = run.model, run.train

    def loss_fn(params, kstate, batch, drop_rng):
        if mc.family == "encoder":
            inputs, targets = batch, batch["tokens"]
            loss_mask = batch.get("mask_spans")
        else:
            inputs, targets = next_token_batch(batch)
            loss_mask = None
        logits, new_k, aux = apply_model(
            params, kstate, inputs, mc, update_state=True, impl=impl,
            moe_impl=moe_impl, remat=tc.remat, drop_rng=drop_rng,
            constrain_fn=constrain_fn)
        pad = inputs.get("pad_mask")
        loss, metrics = lm_loss(logits, targets, pad, tc.z_loss, loss_mask)
        if mc.family == "moe":
            loss = (loss + MOE_LB_COEF * aux["moe_lb_loss"]
                    + MOE_Z_COEF * aux["moe_z_loss"])
        metrics = dict(metrics)
        metrics.update({k: v for k, v in aux.items()})
        metrics["loss"] = loss
        return loss, (new_k, metrics)

    return loss_fn


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, 0.0))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def make_train_step(run: RunConfig, impl="xla", moe_impl="einsum",
                    constrain_fn: Optional[Callable] = None,
                    grad_transform: Optional[Callable] = None,
                    grad_constrain: Optional[Callable] = None):
    """grad_transform: optional hook (e.g. gradient compression) applied to
    the accumulated grads before clipping. grad_constrain: sharding
    constraint pinning the fp32 accumulation buffers to the param layout
    (without it GSPMD may replicate the scan carry — 13x memory on the
    400B config, see EXPERIMENTS.md §Perf)."""
    tc = run.train
    loss_fn = make_loss_fn(run, impl, moe_impl, constrain_fn)
    _, opt_update = make_optimizer(tc)
    schedule = make_schedule(tc, run.model.d_model)
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    gc = grad_constrain or (lambda g: g)

    def train_step(ts: TrainState, batch: Dict[str, jax.Array]):
        drop_rng = (jax.random.fold_in(jax.random.PRNGKey(tc.seed), ts.step)
                    if run.model.dropout > 0 else None)
        A = tc.grad_accum
        if A <= 1:
            (loss, (new_k, metrics)), grads = vg(ts.params, ts.kstate, batch,
                                                 drop_rng)
            grads = gc(grads)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]),
                    b)

            mb = micro(batch)

            acc_dt = jnp.dtype(tc.accum_dtype)

            def body(carry, xs):
                grads_acc, kstate, _ = carry
                (loss, (nk, metrics)), g = vg(ts.params, kstate, xs, drop_rng)
                grads_acc = gc(jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), grads_acc, g))
                return (grads_acc, nk, metrics), loss

            zeros = gc(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), ts.params))
            (gacc, new_k, metrics), losses = jax.lax.scan(
                body, (zeros, ts.kstate,
                       _zero_metrics(run)), mb)
            grads = jax.tree.map(lambda g: (g / A).astype(jnp.float32)
                                 if g.dtype == jnp.float32 else g / A, gacc)
            loss = losses.mean()
            metrics = dict(metrics)
            metrics["loss"] = loss
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gn = clip_by_global_norm(grads, tc.grad_clip)
        lr = schedule(ts.step + 1)
        new_params, new_opt = opt_update(grads, ts.opt_state, ts.params, lr)
        metrics["grad_norm"] = gn
        metrics["lr"] = lr
        return TrainState(new_params, new_k, new_opt, ts.step + 1), metrics

    return train_step


def _zero_metrics(run: RunConfig):
    keys = ["nll", "tokens", "loss", "moe_lb_loss", "moe_z_loss",
            "moe_drop_frac"]
    if run.train.z_loss:
        keys.append("z_loss")
    return {k: jnp.zeros((), jnp.float32) for k in keys}
