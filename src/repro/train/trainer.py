"""Production training loop: checkpoint/restart, preemption, stragglers.

Fault-tolerance contract:
  * `Trainer.fit()` resumes from the latest complete checkpoint (atomic
    rename commit — a torn save is invisible), restoring params/opt/kmeans
    state, step counter AND the data-iterator cursor, so a killed-and-
    restarted run produces the same step sequence as an uninterrupted one
    (tested bit-exact in tests/test_ckpt.py).
  * SIGTERM/SIGINT (preemption notice) triggers a final synchronous
    checkpoint before exit — at most `ckpt_every` steps of work lost under
    normal operation, ~0 steps under graceful preemption.
  * Straggler mitigation: per-step wall times feed a rolling median; steps
    slower than `straggler_factor` x median increment a counter and invoke
    `on_straggler` (hook for re-balancing grad-accum microbatches or
    alerting). On a real fleet this is fed per-host; here it is wired and
    tested at the controller level.
  * Elastic: `Trainer` takes the mesh as a constructor arg; restoring a
    checkpoint saved on a different mesh re-shards via CheckpointManager.
"""
from __future__ import annotations

import contextlib
import signal
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.data.synthetic import SyntheticLoader
from repro.obs import JsonlSink, Registry, StepSeries
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step)


class Trainer:
    def __init__(self, run: RunConfig, loader: SyntheticLoader,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 mesh=None, shardings=None, straggler_factor: float = 2.5,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 async_ckpt: bool = True, step_fn=None,
                 obs_jsonl: Optional[str] = None):
        self.run = run
        self.loader = loader
        self.mesh = mesh
        self.ckpt_every = ckpt_every
        self.async_ckpt = async_ckpt
        self.mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.shardings = shardings
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler or (lambda step, t: None)
        self.straggler_count = 0
        self._times: List[float] = []
        self._preempted = False
        fn = step_fn or make_train_step(run)
        self.step_fn = jax.jit(fn, donate_argnums=(0,)) \
            if step_fn is None else step_fn
        self.state: Optional[TrainState] = None
        # per-step metrics live on the obs layer: an append-only history
        # (what metrics_history used to be) plus an optional JSONL sink
        # ("train_step" records, schema-validated in CI's obs-smoke)
        self.obs = Registry()
        self._sink = (JsonlSink(obs_jsonl, source="trainer")
                      if obs_jsonl else None)
        self._series = StepSeries(sink=self._sink, kind="train_step")

    @property
    def metrics_history(self) -> List[Dict[str, Any]]:
        """Per-step host metric dicts (unchanged public view; backed by
        the obs StepSeries since the observability PR)."""
        return self._series.history

    # ------------------------------------------------------------------
    def init_or_restore(self) -> TrainState:
        key = jax.random.PRNGKey(self.run.train.seed)
        state = init_train_state(self.run, key, mesh=self.mesh)
        if self.mgr is not None and self.mgr.latest_step() is not None:
            # checkpoints hold the field-named dict, not the bare tuple,
            # so leaves are keyed "params/...", "ef_state/..." on disk
            shardings = (self.shardings._asdict()
                         if isinstance(self.shardings, TrainState)
                         else self.shardings)
            if any(k.split("/", 1)[0] == "params" for k in self.mgr.keys()):
                d, extra = self.mgr.restore(state._asdict(),
                                            shardings=shardings)
                state = TrainState(**d)
            else:
                # legacy checkpoint (bare-tuple layout, index-keyed
                # leaves) from before the field-named format; it can
                # never hold an ef residual, so restore the 4-field part
                # and keep the freshly-zeroed ef_state
                legacy, extra = self.mgr.restore(
                    state._replace(ef_state=None), shardings=self.shardings)
                state = legacy._replace(ef_state=state.ef_state)
            if "loader" in extra:
                self.loader.restore(extra["loader"])
        elif self.shardings is not None:
            # fresh init on a mesh: commit the rule layout up front so
            # the first step's in_shardings see it (no device-0 transient)
            state = jax.device_put(state, self.shardings)
        self.state = state
        return state

    def close(self) -> None:
        """Flush + close the obs JSONL sink (records are flushed per
        line, so this is only needed for prompt fd release)."""
        if self._sink is not None:
            self._sink.close()

    def _install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not main thread (tests)

    def _checkpoint(self, blocking=False):
        if self.mgr is None or self.state is None:
            return
        self.mgr.save(int(self.state.step), self.state._asdict(),
                      extra={"loader": self.loader.state()},
                      blocking=blocking or not self.async_ckpt)

    def _watch_stragglers(self, step: int, dt: float):
        self._times.append(dt)
        window = self._times[-50:]
        if len(window) >= 5:
            med = statistics.median(window)
            if dt > self.straggler_factor * med:
                self.straggler_count += 1
                self.on_straggler(step, dt / med)

    # ------------------------------------------------------------------
    def fit(self, num_steps: Optional[int] = None) -> Dict[str, Any]:
        with (self.mesh if self.mesh is not None
              else contextlib.nullcontext()):
            return self._fit(num_steps)

    def _fit(self, num_steps: Optional[int] = None) -> Dict[str, Any]:
        if self.state is None:
            self.init_or_restore()
        self._install_preemption_handler()
        target = num_steps if num_steps is not None else self.run.train.steps
        it = iter(self.loader)
        while int(self.state.step) < target and not self._preempted:
            batch = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            # step_time_s is measured BEFORE the host transfer: it times
            # dispatch (+ compute, on synchronous backends), not the
            # blocking device->host copy of the metrics themselves...
            dt = time.perf_counter() - t0
            # ...which happens here as ONE batched device_get of the
            # whole dict instead of a per-leaf float() sync loop
            metrics = jax.device_get(metrics)
            step = int(self.state.step)
            self._watch_stragglers(step, dt)
            metrics["step_time_s"] = dt
            self.obs.histogram("train/step_time_s").record(dt)
            self._series.record(step, metrics)
            if self.mgr is not None and step % self.ckpt_every == 0:
                self._checkpoint()
        # final (or preemption) checkpoint: synchronous
        self._checkpoint(blocking=True)
        if self.mgr is not None:
            self.mgr.wait()
        return {"steps": int(self.state.step),
                "preempted": self._preempted,
                "stragglers": self.straggler_count,
                "final_loss": (self.metrics_history[-1]["loss"]
                               if self.metrics_history else None)}
