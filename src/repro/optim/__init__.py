from repro.optim.adam import adam
from repro.optim.adafactor import adafactor
from repro.optim.schedule import make_schedule


def make_optimizer(tc):
    """tc: TrainConfig -> (init_fn, update_fn) pair."""
    if tc.optimizer == "adam":
        return adam(tc.betas[0], tc.betas[1], tc.eps, tc.weight_decay)
    if tc.optimizer == "adafactor":
        return adafactor()
    raise ValueError(f"unknown optimizer {tc.optimizer}")
