"""LR schedules. The paper uses the Vaswani rsqrt schedule for Adam runs and
linear-warmup + rsqrt-normalized-decay with a 0.01 constant for PG-19."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(tc, d_model: int = 512):
    w = float(max(tc.warmup_steps, 1))

    def vaswani(step):
        t = jnp.maximum(step.astype(jnp.float32), 1.0)
        return (d_model ** -0.5) * jnp.minimum(t ** -0.5, t * w ** -1.5)

    def linear_warmup_rsqrt(step):
        t = jnp.maximum(step.astype(jnp.float32), 1.0)
        warm = jnp.minimum(1.0, t / w)
        # rsqrt_normalized_decay: flat through warmup then ~1/sqrt(t/w)
        decay = jnp.sqrt(w / jnp.maximum(t, w))
        return tc.lr * warm * decay

    def const(step):
        t = jnp.maximum(step.astype(jnp.float32), 1.0)
        return tc.lr * jnp.minimum(1.0, t / w)

    if tc.schedule == "vaswani":
        return vaswani
    if tc.schedule == "linear_warmup_rsqrt":
        return linear_warmup_rsqrt
    if tc.schedule == "const":
        return const
    raise ValueError(f"unknown schedule {tc.schedule}")
