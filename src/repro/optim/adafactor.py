"""Adafactor (Shazeer & Stern 2018) — the paper's PG-19 optimizer.

Sublinear memory: second moments of >=2D params are factored into row/col
statistics; 1D params keep full statistics. Relative step sizes (update
scaled by RMS(param)), RMS-1 update clipping, beta2 schedule 1 - t^-0.8,
no momentum. This is what makes the 400B maverick config fit v5e HBM
(see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS1 = 1e-30
_EPS2 = 1e-3
_CLIP = 1.0


def _factored(shape):
    return len(shape) >= 2


def adafactor(min_dim_size_to_factor: int = 32):
    def init(params):
        def one(p):
            if _factored(p.shape):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"vr": row, "vc": col}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"stats": jax.tree.map(one, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        t = count.astype(jnp.float32)
        beta2 = 1.0 - t ** -0.8

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + _EPS1
            if _factored(p.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(-2)
                # V-hat = vr vc / mean(vr)  (Shazeer-Stern eq. 4-6)
                r = vr / jnp.maximum(vr.mean(-1, keepdims=True), _EPS1)
                u = g32 * jax.lax.rsqrt(r[..., None] * vc[..., None, :]
                                        + _EPS1)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g32 * jax.lax.rsqrt(v + _EPS1)
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + _EPS1)
            u = u / jnp.maximum(1.0, rms_u / _CLIP)
            scale = jnp.maximum(
                jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))), _EPS2)
            new_p = (p.astype(jnp.float32) - lr * scale * u).astype(p.dtype)
            return new_p, new_s

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        sflat = treedef.flatten_up_to(state["stats"])
        out = [upd(g, s, p) for g, s, p in zip(gflat, sflat, flat)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return new_p, {"stats": new_s, "count": count}

    return init, update
