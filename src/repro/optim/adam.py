"""Adam (Kingma & Ba 2015) — the paper's optimizer for all LMs but PG-19.

Functional optax-style API: `init(params) -> state`, `update(grads, state,
params, lr) -> (new_params, new_state)`. Moments are fp32 regardless of
param dtype (params may be bf16: the update is computed in fp32 and cast
back — for very large models pair with adafactor instead).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam(b1=0.9, b2=0.98, eps=1e-9, weight_decay=0.0):
    def init(params):
        f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m / bc1
            vh = v / bc2
            step = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, m, v

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        mflat = treedef.flatten_up_to(state["m"])
        vflat = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return init, update
