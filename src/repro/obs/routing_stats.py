"""Routing-health statistics for content-based sparse attention.

The paper's complexity bound and quality claims both assume the online
k-means stays healthy: balanced occupancy (collapse breaks the O(n^1.5)
cost), live centroids, and a routed pattern that actually captures the
attention mass a dense model would spend. This module computes those
signals *inside* the jitted step, from intermediates the routing layer
already has (scores, balanced membership) — stats-on cost is dominated by
one (P, N) probe softmax with P = ``stats_probes`` rows.

Per routing layer (leaves shaped over that layer's routing heads H):

  occupancy  (H, k)  batch-mean token count per centroid (argmax
                     assignment, padding excluded)
  entropy    (H,)    occupancy entropy in nats; log(k) = perfectly
                     balanced, 0 = collapsed
  dead       (H,)    centroids with zero assigned tokens (batch mean)
  drift      (H,)    mean_k ||mu_t - mu_{t-1}||_2 — centroid movement of
                     this step's EMA update (0 when update_state=False)
  mismatch   (H,)    fraction of tokens whose argmax centroid did NOT
                     select them under balanced top-w membership — how
                     much the load-balancing constraint distorts the
                     nearest-centroid assignment
  recall     (H,)    sampled attention recall: on P strided probe
                     queries, the fraction of full-softmax attention
                     mass (same normalized q/k, same causal/pad masks)
                     that falls on keys the routed pattern can reach

Everything is fp32 and stop_gradient'ed: stats must never change grads.
This module imports jax + stdlib only (obs stays below repro.core in the
import DAG); ``core.routing`` passes its intermediates in.

Host-side helpers at the bottom (``summarize`` / ``flatten`` /
``pages_health``) fold stats trees into scalar metric dicts and read
cluster-page occupancy straight off a serving cache's ``rlen`` leaves.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

_BIG_NEG = -1e9
_EPS = 1e-12

SCALAR_FIELDS = ("entropy", "dead", "drift", "mismatch", "recall")


class RoutingStats(NamedTuple):
    occupancy: jax.Array    # (H, k)
    entropy: jax.Array      # (H,)
    dead: jax.Array         # (H,)
    drift: jax.Array        # (H,)
    mismatch: jax.Array     # (H,)
    recall: jax.Array       # (H,)


def _probe_idx(n: int, probes: int):
    """Static strided probe positions: the last token of each of P
    equal chunks (later tokens have non-trivial causal history)."""
    p = max(1, min(int(probes), n))
    stride = n // p
    return tuple(int((i + 1) * stride - 1) for i in range(p))


def compute_routing_stats(r_q: jax.Array, k_attn: jax.Array,
                          mu_prev: jax.Array, mu_new: jax.Array,
                          scores_q: jax.Array, q_idx: jax.Array,
                          k_idx: jax.Array, positions: jax.Array,
                          pad_mask: Optional[jax.Array], causal: bool,
                          probes: int = 8) -> RoutingStats:
    """All inputs are the routing layer's own intermediates:

    r_q/k_attn (B,H,N,dh) normalized routing vectors / attention keys,
    mu_prev/mu_new (H,k,dh) centroids before/after the EMA update,
    scores_q (B,H,N,k) centroid affinities, q_idx/k_idx (B,H,k,w)
    balanced memberships, positions (B,N), pad_mask (B,N) or None.
    """
    B, H, N, dh = r_q.shape
    kc = scores_q.shape[-1]
    f32 = jnp.float32
    valid = (jnp.ones((B, N), f32) if pad_mask is None
             else pad_mask.astype(f32))                    # (B,N)

    # --- occupancy / entropy / dead (argmax assignment, pad excluded)
    assign = jnp.argmax(scores_q, axis=-1)                 # (B,H,N)
    onehot = jax.nn.one_hot(assign, kc, dtype=f32)         # (B,H,N,k)
    onehot = onehot * valid[:, None, :, None]
    counts = jnp.einsum("bhnk->bhk", onehot)               # (B,H,k)
    total = jnp.maximum(counts.sum(-1, keepdims=True), 1.0)
    p = counts / total
    entropy = -(p * jnp.log(jnp.maximum(p, _EPS))).sum(-1)  # (B,H)
    dead = (counts <= 0.0).astype(f32).sum(-1)              # (B,H)

    # --- centroid drift of this step's EMA update
    drift = jnp.linalg.norm(
        mu_new.astype(f32) - mu_prev.astype(f32), axis=-1).mean(-1)  # (H,)

    # --- balanced-vs-nearest mismatch
    # memb_q[b,h,c,n]: token n selected by cluster c under balanced top-w
    memb_q = jax.nn.one_hot(q_idx, N, dtype=f32).sum(3)    # (B,H,k,N)
    memb_q = (memb_q > 0).astype(f32)
    taken = jnp.take_along_axis(
        memb_q, assign[:, :, None, :], axis=2)[:, :, 0, :]  # (B,H,N)
    n_valid = jnp.maximum(valid.sum(), 1.0)
    mismatch = 1.0 - (taken * valid[:, None, :]).sum((0, 2)) / n_valid

    # --- sampled attention recall on strided probe queries
    pidx = jnp.asarray(_probe_idx(N, probes), jnp.int32)   # (P,) static
    rq_p = jnp.take(r_q, pidx, axis=2).astype(f32)         # (B,H,P,dh)
    logits = jnp.einsum("bhpd,bhnd->bhpn", rq_p,
                        k_attn.astype(f32)) / jnp.sqrt(float(dh))
    keep = jnp.ones(logits.shape, bool)
    if causal:
        pos_p = jnp.take(positions, pidx, axis=1)          # (B,P)
        keep &= (pos_p[:, None, :, None]
                 >= positions[:, None, None, :])
    keep &= valid[:, None, None, :] > 0
    attn = jax.nn.softmax(jnp.where(keep, logits, _BIG_NEG), axis=-1)
    attn = jnp.where(keep.any(-1, keepdims=True), attn, 0.0)
    memb_k = jax.nn.one_hot(k_idx, N, dtype=f32).sum(3)    # (B,H,k,N)
    memb_k = (memb_k > 0).astype(f32)
    memb_q_p = jnp.take(memb_q, pidx, axis=3)              # (B,H,k,P)
    pattern = jnp.einsum("bhcp,bhcn->bhpn", memb_q_p, memb_k) > 0
    captured = (attn * pattern).sum(-1)                    # (B,H,P)
    pv = jnp.take(valid, pidx, axis=1)                     # (B,P)
    recall = ((captured * pv[:, None, :]).sum((0, 2))
              / jnp.maximum(pv.sum(), 1.0))                # (H,)

    return jax.tree.map(jax.lax.stop_gradient, RoutingStats(
        occupancy=counts.mean(0),
        entropy=entropy.mean(0),
        dead=dead.mean(0),
        drift=drift,
        mismatch=mismatch,
        recall=recall))


# ---------------------------------------------------------------------------
# Tree folding (train-step metrics / engine records)
# ---------------------------------------------------------------------------
def stats_leaves(tree) -> list:
    """Every RoutingStats instance anywhere in ``tree``."""
    return [leaf for leaf in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, RoutingStats))
        if isinstance(leaf, RoutingStats)]


def summarize(tree) -> Dict[str, jax.Array]:
    """Model-wide scalar means over every RoutingStats in ``tree``:
    {"routing/entropy": ..., "routing/dead": ..., ...}. Empty dict when
    the tree holds no stats."""
    leaves = stats_leaves(tree)
    if not leaves:
        return {}
    out = {}
    for f in SCALAR_FIELDS:
        vals = jnp.concatenate(
            [getattr(s, f).astype(jnp.float32).ravel() for s in leaves])
        out[f"routing/{f}"] = vals.mean()
    return out


def flatten(seg_stats, prefix: str = "rt") -> Dict[str, jax.Array]:
    """Per-layer detail from the stack's stats structure (a list over
    segments of {layer_index_str: RoutingStats}, leaves stacked over the
    segment's scan groups): "rt/{seg}/{layer}/{field}" -> array."""
    out: Dict[str, jax.Array] = {}
    for si, seg in enumerate(seg_stats):
        for li in sorted(seg):
            st = seg[li]
            for f in SCALAR_FIELDS:
                out[f"{prefix}/{si}/{li}/{f}"] = getattr(st, f)
    return out


# ---------------------------------------------------------------------------
# Serving-side pages health (host, numpy — no trace)
# ---------------------------------------------------------------------------
def pages_health(cache, active=None) -> Optional[Dict[str, Any]]:
    """Cluster-page occupancy health straight off a serving cache.

    Walks ``cache`` (the engine pool or a single lane, host values) for
    ``rlen`` leaves — (G, B, Hr, kc) per-page token counts of the
    cluster-paged routing cache — and returns batch-mean occupancy
    entropy (nats) and dead-page count over ``active`` slots. None when
    the stack has no routing pages or no slot is active.
    """
    import numpy as np
    rlens = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        name = ""
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        if name == "rlen":
            rlens.append(np.asarray(leaf))
    if not rlens:
        return None
    ents, deads = [], []
    for rl in rlens:                       # (G,B,Hr,kc)
        rl = rl.astype(np.float64)
        if active is not None:
            rl = rl[:, np.asarray(active, bool)]
        if rl.size == 0 or rl.shape[1] == 0:
            continue
        tot = rl.sum(-1)                   # (G,B,Hr)
        occupied = tot > 0
        if not occupied.any():
            continue
        p = rl / np.maximum(tot, 1.0)[..., None]
        ent = -(p * np.log(np.maximum(p, _EPS))).sum(-1)
        ents.append(ent[occupied])
        deads.append((rl <= 0).sum(-1)[occupied])
    if not ents:
        return None
    return {"routing/entropy": float(np.concatenate(ents).mean()),
            "routing/dead": float(np.concatenate(deads).mean())}
