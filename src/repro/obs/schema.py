"""The JSONL event-record schema + validating CLI.

Contract (version 1) for every line a ``JsonlSink`` writes:

  required  "v"       int, == SCHEMA_VERSION
            "kind"    non-empty str ("train_step", "engine_tick",
                      "engine_prefill", "engine_summary", ...)
            "t"       unix timestamp, finite number
  optional  "source"  str (which component emitted the line)
            "step"    int >= 0
            "metrics" dict[str, value] where value is None | bool | num |
                      str | (nested) list of values — i.e. strict JSON
                      with finite numbers

Anything else at the top level must itself be a valid metric value.
CI runs ``python -m repro.obs.schema file.jsonl ...`` after the obs-smoke
train/serve runs and fails the job on the first malformed line.

The CLI also takes whole-file ``.json`` records (the committed benchmark
artifacts — ``BENCH_*.json``, ``*_smoke.json``): those are one
pretty-printed JSON document, not schema-v1 event lines, so they are
held to the *value* contract only — strict JSON (bare ``NaN`` /
``Infinity`` rejected, which ``json.loads`` would otherwise accept),
string keys, finite numbers all the way down. CI's docs-check step runs
it over every committed record so a benchmark that starts emitting
non-finite or non-portable JSON fails the push that introduced it.
"""
from __future__ import annotations

import json
import sys
from typing import Any

from repro.obs.metrics import SCHEMA_VERSION


class SchemaError(ValueError):
    pass


def _check_value(v: Any, where: str) -> None:
    if v is None or isinstance(v, (bool, str)):
        return
    if isinstance(v, (int, float)):
        if isinstance(v, float) and v != v:           # NaN
            raise SchemaError(f"{where}: non-finite number")
        if isinstance(v, float) and v in (float("inf"), float("-inf")):
            raise SchemaError(f"{where}: non-finite number")
        return
    if isinstance(v, list):
        for i, x in enumerate(v):
            _check_value(x, f"{where}[{i}]")
        return
    if isinstance(v, dict):
        for k, x in v.items():
            if not isinstance(k, str):
                raise SchemaError(f"{where}: non-string key {k!r}")
            _check_value(x, f"{where}.{k}")
        return
    raise SchemaError(f"{where}: unsupported type {type(v).__name__}")


def validate_record(rec: Any) -> None:
    """Raise SchemaError unless ``rec`` is a valid version-1 record."""
    if not isinstance(rec, dict):
        raise SchemaError(f"record is {type(rec).__name__}, not an object")
    v = rec.get("v")
    if v != SCHEMA_VERSION:
        raise SchemaError(f"v={v!r} != schema version {SCHEMA_VERSION}")
    kind = rec.get("kind")
    if not isinstance(kind, str) or not kind:
        raise SchemaError(f"kind={kind!r} must be a non-empty string")
    t = rec.get("t")
    if not isinstance(t, (int, float)) or isinstance(t, bool) or t != t:
        raise SchemaError(f"t={t!r} must be a finite number")
    if "source" in rec and not isinstance(rec["source"], str):
        raise SchemaError(f"source={rec['source']!r} must be a string")
    if "step" in rec:
        s = rec["step"]
        if not isinstance(s, int) or isinstance(s, bool) or s < 0:
            raise SchemaError(f"step={s!r} must be an int >= 0")
    if "metrics" in rec:
        m = rec["metrics"]
        if not isinstance(m, dict):
            raise SchemaError("metrics must be an object")
        _check_value(m, "metrics")
    for k, v in rec.items():
        if k in ("v", "kind", "t", "source", "step", "metrics"):
            continue
        _check_value(v, k)


def validate_jsonl(path: str) -> int:
    """Validate every line of ``path``; returns the line count, raises
    SchemaError (with line number) on the first invalid record."""
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise SchemaError(f"{path}:{lineno}: not JSON ({e})")
            try:
                validate_record(rec)
            except SchemaError as e:
                raise SchemaError(f"{path}:{lineno}: {e}")
            n += 1
    return n


def _reject_constant(name: str):
    raise SchemaError(f"non-finite constant {name} (strict JSON)")


def validate_json_file(path: str) -> None:
    """Validate a whole-file JSON record (committed bench artifact):
    strict JSON with string keys and finite numbers throughout. Raises
    SchemaError on the first violation."""
    with open(path) as f:
        try:
            doc = json.load(f, parse_constant=_reject_constant)
        except json.JSONDecodeError as e:
            raise SchemaError(f"{path}: not JSON ({e})")
        except SchemaError as e:
            raise SchemaError(f"{path}: {e}")
    try:
        _check_value(doc, "$")
    except SchemaError as e:
        raise SchemaError(f"{path}: {e}")


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.schema FILE.{jsonl,json} [...]",
              file=sys.stderr)
        return 2
    status = 0
    for path in argv:
        if path.endswith(".json"):
            try:
                validate_json_file(path)
            except (OSError, SchemaError) as e:
                print(f"FAIL {e}", file=sys.stderr)
                status = 1
                continue
            print(f"{path}: whole-file record ok (strict JSON, finite)")
            continue
        try:
            n = validate_jsonl(path)
        except (OSError, SchemaError) as e:
            print(f"FAIL {e}", file=sys.stderr)
            status = 1
            continue
        if n == 0:
            print(f"FAIL {path}: no records", file=sys.stderr)
            status = 1
            continue
        print(f"{path}: {n} records ok (schema v{SCHEMA_VERSION})")
    return status


if __name__ == "__main__":
    sys.exit(main())
