"""Trace spans + on-demand profiler capture.

``span(name)`` names a region both ways a JAX program is observed:

  * ``jax.named_scope`` — inside a jit trace it tags the emitted HLO ops,
    so the region shows up named in xplane traces and compiled-module
    dumps (zero runtime cost; pure metadata);
  * ``jax.profiler.TraceAnnotation`` — on the host timeline it brackets
    the python-side region (engine admit/prefill/decode phases, dispatch
    of a train step), visible in the same xplane capture.

Span naming convention (DESIGN.md §10): ``<subsystem>/<phase>`` —
``kernels/flash_attention``, ``train/grad``, ``train/exchange``,
``train/optimizer``, ``engine/admit``, ``engine/prefill``,
``engine/decode``.

``profile(log_dir)`` wraps ``jax.profiler.trace``: a context manager that
writes an xplane trace (viewable in TensorBoard / xprof) covering its
body, or a no-op when ``log_dir`` is falsy — so call sites can thread a
``--profile-dir`` flag straight through.
"""
from __future__ import annotations

import contextlib
import os

import jax


@contextlib.contextmanager
def span(name: str):
    """Name a region in both the HLO metadata and the host timeline."""
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


@contextlib.contextmanager
def profile(log_dir, enabled: bool = True):
    """Capture an xplane profiler trace of the body into ``log_dir``
    (no-op when ``log_dir`` is falsy or ``enabled`` is False)."""
    if not log_dir or not enabled:
        yield
        return
    os.makedirs(log_dir, exist_ok=True)
    with jax.profiler.trace(str(log_dir)):
        yield
