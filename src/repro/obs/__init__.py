"""repro.obs — the unified observability subsystem (DESIGN.md §10).

Three layers, all importable from here:

  metrics   Counter/Gauge/Histogram + Registry, JsonlSink (schema-
            versioned one-line-per-event records), StepSeries (trainer
            history adapter)
  routing   RoutingStats — the routing-health aux pytree computed inside
            the jitted step (occupancy entropy, dead clusters, centroid
            drift, balanced-vs-nearest mismatch, sampled attention
            recall) — plus summarize/flatten folds and the serving-side
            pages_health reader
  trace     span(name) — named_scope + TraceAnnotation around kernels
            and train/engine phases; profile(log_dir) — on-demand xplane
            capture behind --profile-dir flags

This package sits at the bottom of the import DAG (jax + stdlib only):
core/, train/, serve/, kernels/ all report through it, so it must never
import them. Validate emitted JSONL with
``python -m repro.obs.schema file.jsonl``.
"""
from repro.obs import routing_stats  # noqa: F401
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               JsonlSink, Registry, SCHEMA_VERSION,
                               StepSeries, default_registry)
from repro.obs.routing_stats import (RoutingStats,  # noqa: F401
                                     compute_routing_stats, pages_health)
from repro.obs.schema import (SchemaError, validate_jsonl,  # noqa: F401
                              validate_record)
from repro.obs.trace import profile, span  # noqa: F401
