"""Metrics core: counters / gauges / histograms + registry + JSONL sink.

Deliberately leaf-level: imports jax + stdlib only, never repro.* — every
layer (core, train, serve, kernels, benchmarks) reports through this
module, so it must sit below all of them in the import DAG.

Three primitives:

  Counter    monotonically increasing float (``inc``)
  Gauge      last-written value (``set``)
  Histogram  reservoir of observed values with percentile queries
             (p50/p90/p99) — backs the engine latency percentiles and the
             trainer's step-time distribution

``Registry`` is a typed name -> instrument map with ``summary()`` (flat
dict, histograms expanded to count/mean/min/max/p50/p90/p99) and
``to_csv()``. One process-wide default registry exists for code that has
no better home for its instruments; subsystems that own a lifecycle
(EngineMetrics, Trainer) hold their own Registry.

``JsonlSink`` writes one schema-versioned JSON line per event (see
repro.obs.schema for the record contract and the validating CLI);
``StepSeries`` is the trainer-facing adapter: an append-only history of
per-step metric dicts (device values converted to host floats/lists)
that optionally tees every record into a sink.
"""
from __future__ import annotations

import json
import math
import time
from typing import Any, Dict, List, Optional

SCHEMA_VERSION = 1


def _host(v):
    """Device/numpy scalar or array -> JSON-able python value."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if hasattr(v, "ndim"):
        if v.ndim == 0:
            f = float(v)
            return f if math.isfinite(f) else None
        return [_host(x) for x in list(v)]
    if isinstance(v, (list, tuple)):
        return [_host(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _host(x) for k, x in v.items()}
    f = float(v)          # e.g. np.float32 without ndim? be permissive
    return f if math.isfinite(f) else None


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact histogram for the cardinalities we record (requests, steps:
    O(1e4) samples); percentile() is linear-interpolated on the sorted
    sample like numpy's default."""

    __slots__ = ("name", "_vals", "_sorted")

    def __init__(self, name: str):
        self.name = name
        self._vals: List[float] = []
        self._sorted = True

    def record(self, v: float) -> None:
        v = float(v)
        if self._vals and v < self._vals[-1]:
            self._sorted = False
        self._vals.append(v)

    @property
    def count(self) -> int:
        return len(self._vals)

    @property
    def sum(self) -> float:
        return float(sum(self._vals))

    def percentile(self, p: float) -> Optional[float]:
        if not self._vals:
            return None
        if not self._sorted:
            self._vals.sort()
            self._sorted = True
        xs = self._vals
        if len(xs) == 1:
            return xs[0]
        rank = (p / 100.0) * (len(xs) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> Dict[str, Optional[float]]:
        if not self._vals:
            return {"count": 0, "mean": None, "min": None, "max": None,
                    "p50": None, "p90": None, "p99": None}
        return {"count": self.count, "mean": self.sum / self.count,
                "min": min(self._vals), "max": max(self._vals),
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


class Registry:
    """Typed name -> instrument map. Get-or-create accessors; asking for
    an existing name with a different type is a bug and raises."""

    def __init__(self):
        self._items: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        inst = self._items.get(name)
        if inst is None:
            inst = self._items[name] = cls(name)
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} is {type(inst).__name__}, "
                            f"requested as {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        return sorted(self._items)

    def reset(self) -> None:
        self._items.clear()

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name in self.names():
            inst = self._items[name]
            if isinstance(inst, Histogram):
                for k, v in inst.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = inst.value
        return out

    def to_csv(self) -> str:
        lines = ["name,value"]
        for k, v in self.summary().items():
            lines.append(f"{k},{'' if v is None else v}")
        return "\n".join(lines) + "\n"


_DEFAULT = Registry()


def default_registry() -> Registry:
    return _DEFAULT


class JsonlSink:
    """One JSON object per line, schema-versioned (repro.obs.schema).

    Record shape::

        {"v": 1, "kind": "train_step", "t": <unix s>, "source": "...",
         "step": 12, "metrics": {...}}

    Opened in append mode so a train loop and a serve loop may share one
    file; every line is flushed (records are small, loss on crash is the
    failure mode that matters).
    """

    def __init__(self, path: str, source: str = "", clock=time.time):
        self.path = path
        self.source = source
        self.clock = clock
        self._f = open(path, "a")
        self.lines = 0

    def emit(self, kind: str, metrics: Optional[Dict[str, Any]] = None,
             step: Optional[int] = None, **extra) -> Dict[str, Any]:
        rec: Dict[str, Any] = {"v": SCHEMA_VERSION, "kind": str(kind),
                               "t": float(self.clock())}
        if self.source:
            rec["source"] = self.source
        if step is not None:
            rec["step"] = int(step)
        if metrics is not None:
            rec["metrics"] = {str(k): _host(v) for k, v in metrics.items()}
        for k, v in extra.items():
            rec[k] = _host(v)
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        self.lines += 1
        return rec

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class StepSeries:
    """Per-step metric history (list of host-value dicts) + optional sink.

    Replaces the trainer's ad-hoc ``metrics_history`` list: ``record``
    converts device leaves once (scalars -> float, arrays -> nested
    lists) so history entries stay the plain dicts existing consumers
    index, and tees the same record to the JSONL sink when one is
    attached.
    """

    def __init__(self, sink: Optional[JsonlSink] = None,
                 kind: str = "train_step"):
        self.history: List[Dict[str, Any]] = []
        self.sink = sink
        self.kind = kind

    def record(self, step: int, metrics: Dict[str, Any]) -> Dict[str, Any]:
        rec = {str(k): _host(v) for k, v in metrics.items()}
        self.history.append(rec)
        if self.sink is not None:
            self.sink.emit(self.kind, metrics=rec, step=step)
        return rec
