#!/usr/bin/env python
"""Docs anchor/link linter — keeps README.md and docs/ from rotting.

Two checks over README.md, docs/**/*.md, and DESIGN.md:

1. **Section anchors.** Every ``§N`` / ``§N.M`` reference in README.md
   and docs/ must have a matching ``## §N ...`` or ``### §N.M ...``
   heading in DESIGN.md (the docstring convention ``DESIGN.md §N`` is
   how code and guides cite the design reference — a renumbered or
   deleted section must not leave dangling citations).
2. **Relative links.** Every relative markdown link target
   (``[text](path)`` — http/mailto/anchor-only links skipped) must
   exist on disk, resolved against the linking file's directory.

Exit 0 when clean; prints each failure and exits 1 otherwise. CI runs
this in the docs-check step next to the committed-record schema
validation (``python -m repro.obs.schema``).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

SECTION_REF = re.compile(r"§(\d+(?:\.\d+)?)")
SECTION_DEF = re.compile(r"^#{2,3}\s+§(\d+(?:\.\d+)?)\b", re.MULTILINE)
# [text](target) — not images' inner (), not reference-style defs;
# good enough for the hand-written markdown in this repo
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def repo_root() -> Path:
    return Path(__file__).resolve().parent.parent


def doc_files(root: Path):
    yield root / "README.md"
    yield root / "DESIGN.md"
    yield from sorted((root / "docs").glob("**/*.md"))


def check(root: Path) -> list:
    errors = []
    design = root / "DESIGN.md"
    defined = set(SECTION_DEF.findall(design.read_text()))
    if not defined:
        errors.append(f"{design}: no '## §N' headings found")

    for path in doc_files(root):
        if not path.exists():
            errors.append(f"{path}: missing")
            continue
        text = path.read_text()
        rel = path.relative_to(root)

        if path != design:  # DESIGN.md defines sections, others cite them
            for ref in SECTION_REF.findall(text):
                if ref not in defined:
                    errors.append(
                        f"{rel}: cites §{ref} but DESIGN.md has no "
                        f"'## §{ref}' heading (defined: "
                        f"{', '.join(sorted(defined, key=_skey))})")

        for target in MD_LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            dest = (path.parent / target.split("#", 1)[0]).resolve()
            if not dest.exists():
                errors.append(f"{rel}: broken link -> {target}")
    return errors


def _skey(s: str):
    return tuple(int(p) for p in s.split("."))


def main() -> int:
    root = repo_root()
    errors = check(root)
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    if not errors:
        n = sum(1 for _ in doc_files(root))
        print(f"docs check OK ({n} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
