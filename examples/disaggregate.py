"""Disaggregated serving: a prefill pool and a decode pool exchanging
sessions through the KV transport (DESIGN.md §11.5).

The prefill-pool engine runs ``prefill_only``: every admitted request
prefills, samples its first token, and parks; ``export_session`` then
ships the lane + request state through the transport as one checksummed
blob. The decode-pool engine ``import_session``s each blob and decodes
it to completion. Token streams are bit-identical to one monolithic
engine — counter-based sampling keys and byte-exact lane round trips
make the continuation engine-independent.

Modes:

  (default)                  both pools in this process, loopback
                             transport, parity-checked against a
                             monolithic engine
  --tcp                      same, but the pools meet at a localhost
                             TCP blob peer (real sockets, same parity)
  --role decode --port P     THIS process hosts the blob peer on port P,
                             imports every session a prefill process
                             announces, decodes, and checks the token
                             streams against the manifest's expected
                             outputs (exit 0 iff bit-identical)
  --role prefill --connect HOST:PORT
                             THIS process computes the expected outputs
                             monolithically, then prefill-exports every
                             session to the peer plus a manifest blob

The two --role modes are the two-process harness CI runs: start the
decode process first, then the prefill process, and the decode process's
exit code is the bit-parity verdict.

Run:  PYTHONPATH=src python examples/disaggregate.py [--tcp]
"""
import argparse
import json
import sys
import time

import numpy as np

import jax

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.engine import InferenceEngine, Request
from repro.serve.kvstore import KVStore, StoreConfig
from repro.serve.kvstore.remote import (LoopbackTransport, TCPStoreServer,
                                        TCPTransport)

MANIFEST = "manifest"                   # blob announcing the shipped uids


def build_model(small: bool):
    cfg = ModelConfig(
        name="rt-disagg", family="dense",
        num_layers=2 if small else 4, d_model=128 if small else 256,
        num_heads=4 if small else 8, num_kv_heads=2 if small else 4,
        d_ff=256 if small else 512, vocab_size=1024,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=8, local_window=32),
        dtype="float32")
    params, kstate = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params, kstate


def make_requests(cfg, n=6):
    rng = np.random.RandomState(1)
    prompt_lens = (16, 32, 48)
    return [Request(uid=uid,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=prompt_lens[uid % 3]).tolist(),
                    max_new_tokens=8 + 4 * (uid % 3))
            for uid in range(n)]


def run_monolithic(cfg, params, kstate, reqs, max_slots, max_len):
    eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len)
    out = eng.run(reqs)
    eng.close()
    return out


def run_prefill_pool(cfg, params, kstate, reqs, max_slots, max_len,
                     transport):
    """Prefill + export every request; returns the exported blob names."""
    eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len, prefill_only=True,
                          kvstore=KVStore(StoreConfig(remote=transport)))
    for r in reqs:
        eng.submit(r)
    while eng.has_work():
        eng.step()
    names = [eng.export_session(r.uid) for r in reqs
             if r.state == "PARKED"]
    eng.close()
    return names


def run_decode_pool(cfg, params, kstate, names, max_slots, max_len,
                    transport):
    eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len,
                          kvstore=KVStore(StoreConfig(
                              remote=transport, async_transfers=True)))
    handles = [eng.import_session(n) for n in names]
    while eng.has_work():
        eng.step()
    eng.close()
    return {h.uid: h.output for h in handles}


def single_process(args) -> int:
    cfg, params, kstate = build_model(small=args.small)
    max_slots, max_len = 2, 128
    reqs = make_requests(cfg)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{len(reqs)} requests, prefill pool -> decode pool")

    ref = run_monolithic(cfg, params, kstate, make_requests(cfg),
                         max_slots, max_len)
    server = TCPStoreServer() if args.tcp else None
    try:
        if args.tcp:
            mk = lambda: TCPTransport(server.host, server.port)
            print(f"transport: tcp localhost:{server.port}")
        else:
            loop = LoopbackTransport()
            mk = lambda: loop
            print("transport: loopback")
        names = run_prefill_pool(cfg, params, kstate, reqs, max_slots,
                                 max_len, mk())
        print(f"prefill pool exported {len(names)} sessions")
        out = run_decode_pool(cfg, params, kstate, names, max_slots,
                              max_len, mk())
    finally:
        if server is not None:
            server.close()
    for r in reqs:                      # finished during prefill (eos)
        out.setdefault(r.uid, list(r.output))
    identical = out == ref
    for uid in sorted(out):
        print(f"  uid {uid}: {out[uid]}")
    print(f"bit-identical to monolithic engine: {identical}")
    return 0 if identical else 1


def role_prefill(args) -> int:
    host, port = args.connect.rsplit(":", 1)
    transport = TCPTransport(host, int(port))
    print(f"prefill pool: waiting for decode peer at {host}:{port}")
    transport.wait_until_ready(timeout_s=120)
    cfg, params, kstate = build_model(small=args.small)
    max_slots, max_len = 2, 128
    reqs = make_requests(cfg)
    expected = run_monolithic(cfg, params, kstate, make_requests(cfg),
                              max_slots, max_len)
    names = run_prefill_pool(cfg, params, kstate, reqs, max_slots,
                             max_len, transport)
    for r in reqs:                      # finished during prefill (eos)
        if r.uid not in {int(n.rsplit("/", 1)[1]) for n in names}:
            expected.pop(r.uid, None)
    manifest = {"sessions": names,
                "expected": {str(u): t for u, t in expected.items()}}
    transport.put(MANIFEST, json.dumps(manifest).encode())
    print(f"prefill pool: exported {len(names)} sessions + manifest")
    return 0


def role_decode(args) -> int:
    server = TCPStoreServer(port=args.port)
    transport = TCPTransport(server.host, server.port)
    print(f"decode pool: blob peer listening on {server.host}:{server.port}")
    cfg, params, kstate = build_model(small=args.small)  # overlaps the wait
    deadline = time.monotonic() + args.timeout_s
    while not transport.exists(MANIFEST):
        if time.monotonic() > deadline:
            print("FAIL: no manifest arrived before the timeout",
                  file=sys.stderr)
            server.close()
            return 1
        time.sleep(0.25)
    manifest = json.loads(transport.get(MANIFEST).decode())
    names = manifest["sessions"]
    expected = {int(u): t for u, t in manifest["expected"].items()}
    print(f"decode pool: importing {len(names)} sessions")
    out = run_decode_pool(cfg, params, kstate, names, 2, 128, transport)
    server.close()
    identical = out == expected
    print(f"decode pool: token streams bit-identical to the prefill "
          f"process's monolithic reference: {identical}")
    return 0 if identical else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tcp", action="store_true",
                    help="single process, but through a localhost TCP peer")
    ap.add_argument("--role", choices=("prefill", "decode"), default=None,
                    help="two-process mode: which pool this process is")
    ap.add_argument("--port", type=int, default=0,
                    help="decode role: port for the blob peer (0=ephemeral)")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="prefill role: the decode process's blob peer")
    ap.add_argument("--timeout-s", type=float, default=300.0,
                    help="decode role: how long to wait for the manifest")
    ap.add_argument("--small", action="store_true",
                    help="tiny model (CI two-process smoke)")
    args = ap.parse_args(argv)
    if args.role == "prefill":
        if not args.connect:
            ap.error("--role prefill needs --connect HOST:PORT")
        return role_prefill(args)
    if args.role == "decode":
        return role_decode(args)
    return single_process(args)


if __name__ == "__main__":
    sys.exit(main())
