"""Continuous-batching serving with the slot-pooled routing KV cache.

Twelve requests with mixed prompt lengths, generation lengths, and sampling
settings arrive staggered over time. The engine admits each into a free
cache lane (FCFS + token budget), decodes every active lane in ONE jitted
step (cluster-paged routing cache: O(window + cap) per token), retires
finished requests, and reuses their lanes for later arrivals — no request
ever waits for a batch-mate to finish.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

import jax

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.engine import InferenceEngine, Request, SamplingParams


def main():
    cfg = ModelConfig(
        name="rt-serve", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=1024,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=8, local_window=32),
        dtype="float32")
    params, kstate = init_model(cfg, jax.random.PRNGKey(0))

    n_req, max_slots = 12, 4
    rng = np.random.RandomState(1)
    prompt_lens = (24, 48, 96, 192)
    gen_lens = (8, 16, 24, 32)
    requests = []
    for uid in range(n_req):
        sampling = (SamplingParams() if uid % 3 == 0 else
                    SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                                   seed=uid))
        requests.append(Request(
            uid=uid,
            prompt=rng.randint(0, cfg.vocab_size,
                               size=prompt_lens[uid % 4]).tolist(),
            max_new_tokens=gen_lens[(3 * uid + 1) % 4],
            sampling=sampling,
            arrival_step=2 * uid))
    max_len = max(prompt_lens) + max(gen_lens)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{n_req} staggered requests over {max_slots} slots "
          f"(max_len={max_len})")

    eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len, token_budget=4 * max_len)
    outputs = eng.run(requests)

    print(f"{'uid':>3} {'arrive':>6} {'slot':>4} {'prompt':>6} {'gen':>4} "
          f"{'ttft_ms':>8}  first tokens")
    for r in requests:
        st = eng.metrics.requests[r.uid]
        print(f"{r.uid:>3} {st.arrival_step:>6} {st.slot:>4} "
              f"{st.prompt_len:>6} {st.n_generated:>4} "
              f"{st.ttft_s*1e3:>8.0f}  {outputs[r.uid][:6]}")

    s = eng.metrics.summary()
    print(f"decode: {s['decode_tokens']} tokens in {s['decode_steps']} steps "
          f"({s['decode_tokens_per_s']:.0f} tok/s, "
          f"occupancy {s['mean_occupancy']:.2f}/{max_slots}); "
          f"prefill: {s['prefill_tokens']} tokens")


if __name__ == "__main__":
    main()
