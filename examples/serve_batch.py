"""Batched serving with the cluster-paged routing KV cache.

Prefills a batch of 8 requests and decodes 32 tokens each through the
Routing Transformer serving path (local ring cache + argmax-routed cluster
pages, O(window + cap) per step instead of O(context)). Prints per-phase
throughput.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.serving import init_cache, make_serve_step, prefill


def main():
    B, PREFIX, GEN = 8, 192, 32
    cfg = ModelConfig(
        name="rt-serve", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=512, vocab_size=1024,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=8, local_window=32),
        dtype="float32")
    params, kstate = init_model(cfg, jax.random.PRNGKey(0))
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"batch={B} prefix={PREFIX} gen={GEN}")

    toks = jax.random.randint(jax.random.PRNGKey(1), (B, PREFIX), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, B, max_len=PREFIX + GEN)

    t0 = time.perf_counter()
    logits, cache = prefill(params, kstate, cache, {"tokens": toks}, cfg)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {B * PREFIX} tokens in {t_prefill*1e3:.0f} ms "
          f"({B * PREFIX / t_prefill:.0f} tok/s)")

    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1], -1)
    # warmup compile
    _ = serve(params, kstate, cache, tok, jnp.full((B,), PREFIX, jnp.int32))
    t0 = time.perf_counter()
    cur = cache
    for t in range(PREFIX, PREFIX + GEN):
        lg, cur = serve(params, kstate, cur, tok,
                        jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(lg, -1)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    print(f"decode: {B * GEN} tokens in {t_decode*1e3:.0f} ms "
          f"({B * GEN / t_decode:.0f} tok/s, "
          f"{t_decode / GEN * 1e3:.1f} ms/step)")

    # show the routing cache filled up
    rlen = cur[0]["0"]["rlen"]
    print(f"cluster page occupancy (layer group 0): "
          f"min={int(rlen.min())} max={int(rlen.max())} "
          f"sum/head={int(rlen.sum(-1).mean())} (== tokens seen)")


if __name__ == "__main__":
    main()
