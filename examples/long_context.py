"""The paper's headline claim on your CPU: routing attention is
O(n^1.5 d) while full attention is O(n^2 d).

Runs one attention layer at growing sequence lengths and prints measured
wall time + the FLOPs model; the routing curve grows ~n^1.5, full ~n^2.

Run:  PYTHONPATH=src python examples/long_context.py
"""
import math
import time

import jax
import jax.numpy as jnp

from repro.configs.base import RoutingConfig
from repro.core.attention import full_attention
from repro.core.kmeans import init_kmeans
from repro.core.routing import routed_attention


def bench(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def main():
    B, H, dh = 1, 4, 64
    print(f"{'n':>7} {'k=sqrt(n)':>9} {'full ms':>9} {'routing ms':>11} "
          f"{'speedup':>8}")
    full_t = {}
    for n in (1024, 2048, 4096, 8192):
        ks = jax.random.split(jax.random.PRNGKey(n), 2)
        q = jax.random.normal(ks[0], (B, H, n, dh))
        v = jax.random.normal(ks[1], (B, H, n, dh))
        k_clusters = 2 ** round(math.log2(math.sqrt(n)))
        st = init_kmeans(jax.random.PRNGKey(0), H, k_clusters, dh)
        cfg = RoutingConfig(num_clusters=k_clusters)

        f_full = jax.jit(lambda q, v: full_attention(q, q, v, causal=True,
                                                     chunk=1024))
        f_rout = jax.jit(lambda q, v, mu: routed_attention(
            q, None, v, type(st)(mu=mu), cfg, update_state=False).out)
        t_full = bench(f_full, q, v)
        t_rout = bench(f_rout, q, v, st.mu)
        full_t[n] = t_full
        print(f"{n:>7} {k_clusters:>9} {t_full*1e3:>9.1f} "
              f"{t_rout*1e3:>11.1f} {t_full/t_rout:>7.1f}x")
    # scaling exponents from the two endpoints
    ns = sorted(full_t)
    print("\nfull-attention time scaling exponent "
          f"(expect ~2): "
          f"{math.log(full_t[ns[-1]]/full_t[ns[0]])/math.log(ns[-1]/ns[0]):.2f}")


if __name__ == "__main__":
    main()
