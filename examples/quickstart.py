"""Quickstart: train a tiny Routing Transformer (half local heads, half
content-routed heads, per the paper) on a synthetic Markov language and
generate from it with the cluster-paged serving cache.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                TrainConfig)
from repro.data.synthetic import SyntheticLoader
from repro.serve.serving import init_cache, make_serve_step, prefill
from repro.train.train_step import init_train_state, make_train_step


def main():
    cfg = ModelConfig(
        name="rt-quickstart", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=64,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=4, local_window=16),
        dtype="float32")
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=16, seq_len=64, steps=60, lr=3e-3, schedule="const",
        warmup_steps=5))

    print(f"model: {cfg.name}, {cfg.param_count()/1e3:.0f}K params, "
          f"{cfg.num_heads//2} local + {cfg.num_heads//2} routing heads")
    ts = init_train_state(run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(run))
    loader = SyntheticLoader("markov", cfg.vocab_size, 16, 64)
    for i, batch in zip(range(run.train.steps), loader):
        ts, m = step(ts, {k: jnp.asarray(v) for k, v in batch.items()})
        if i % 10 == 0 or i == run.train.steps - 1:
            print(f"step {i:3d}  loss {float(m['loss']):.3f}  "
                  f"grad_norm {float(m['grad_norm']):.2f}")

    # --- generate: prefill a prompt, decode greedily with the
    # cluster-paged routing cache; a trained model should assign high
    # likelihood to its own continuations under the Markov transition table
    prompt = jnp.asarray(next(iter(loader))["tokens"][:1, :32])
    cache = init_cache(cfg, 1, max_len=96)
    logits, cache = prefill(ts.params, ts.kstate, cache,
                            {"tokens": prompt}, cfg)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1], -1)
    out = [int(tok[0])]
    logp = []
    for t in range(prompt.shape[1], prompt.shape[1] + 16):
        lg, cache = serve(ts.params, ts.kstate, cache, tok,
                          jnp.array([t], jnp.int32))
        logp.append(float(jax.nn.log_softmax(lg)[0, int(jnp.argmax(lg))]))
        tok = jnp.argmax(lg, -1)
        out.append(int(tok[0]))
    print("prompt tail :", [int(x) for x in prompt[0, -8:]])
    print("generated   :", out)
    import numpy as np
    print(f"mean greedy logprob: {np.mean(logp):.2f} "
          f"(untrained would be ~{-np.log(cfg.vocab_size):.2f})")
    assert np.mean(logp) > -np.log(cfg.vocab_size) + 1.0


if __name__ == "__main__":
    main()
