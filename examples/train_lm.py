"""End-to-end training driver: any `--arch` from the registry, with
checkpoint/restart, preemption handling, and straggler monitoring.

Default trains a ~100M-param Routing Transformer (the paper's PG-19
architecture at reduced width) for a few hundred steps on the synthetic
Markov stream. Kill it mid-run and re-run the same command: it resumes
from the last checkpoint bit-exactly.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --reduced
"""
import argparse

from repro.configs import ARCHS, get_config, reduced_config
from repro.configs.base import (RunConfig, TrainConfig, with_overrides,
                                RoutingConfig, ModelConfig)
from repro.data.synthetic import SyntheticLoader
from repro.train.trainer import Trainer


def default_100m() -> ModelConfig:
    # pg19-shaped Routing Transformer, ~100M params, CPU-trainable
    return ModelConfig(
        name="rt-100m", family="dense", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=32000,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=8, local_window=128,
                              routing_heads=2, routing_layers=(6, 7)),
        attn_window=128, position="rope", dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rt-100m",
                    choices=["rt-100m"] + sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test reduction of --arch")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.arch == "rt-100m":
        cfg = default_100m()
    elif args.reduced:
        cfg = reduced_config(args.arch)
    else:
        cfg = get_config(args.arch)
    cfg = with_overrides(cfg, dtype="float32")
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=args.batch, seq_len=args.seq, steps=args.steps,
        lr=2e-4 if cfg.param_count() > 5e7 else 1e-3,
        schedule="linear_warmup_rsqrt", warmup_steps=100,
        optimizer="adam", remat="full"))
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} ckpt={args.ckpt_dir}")

    loader = SyntheticLoader("markov", min(cfg.vocab_size, 512),
                             args.batch, args.seq)
    tr = Trainer(run, loader, ckpt_dir=args.ckpt_dir,
                 ckpt_every=args.ckpt_every,
                 on_straggler=lambda s, r: print(
                     f"  [straggler] step {s} was {r:.1f}x median"))
    tr.init_or_restore()
    start = int(tr.state.step)
    if start:
        print(f"resumed from checkpoint at step {start}")
    out = tr.fit(args.steps)
    hist = tr.metrics_history
    if hist:
        print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"over {len(hist)} steps "
              f"(median step {sorted(h['step_time_s'] for h in hist)[len(hist)//2]*1e3:.0f} ms)")
    print(f"done: {out}")


if __name__ == "__main__":
    main()
