"""Shared test helpers. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses (test_dist.py,
the engine-mesh parity test) via `run_forced_devices`."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# CI runs the multi-device lane as a matrix over device counts (2, 8);
# tests must derive mesh shapes from len(jax.devices()), not hardcode 8
FORCED_DEVICES = int(os.environ.get("REPRO_TEST_DEVICE_COUNT", "8"))


def run_forced_devices(code: str, devices: int = 0,
                       timeout: int = 900) -> str:
    """Run ``code`` in a subprocess with a forced multi-device host
    platform (default: the CI matrix's $REPRO_TEST_DEVICE_COUNT, else 8).
    The main pytest process keeps its single-device view (required by
    the smoke tests), so anything needing >1 device goes through here."""
    devices = devices or FORCED_DEVICES
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def tree_maxdiff(t1, t2) -> float:
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32)
                                   - jnp.asarray(b, jnp.float32)).max()),
        t1, t2)
    return jax.tree_util.tree_reduce(max, d, 0.0)


def tree_abssum(t) -> float:
    d = jax.tree.map(lambda a: float(jnp.abs(jnp.asarray(a, jnp.float32)
                                             ).sum()), t)
    return jax.tree_util.tree_reduce(lambda a, b: a + b, d, 0.0)
