"""Shared test helpers. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses (test_dist.py)."""
import jax
import jax.numpy as jnp


def tree_maxdiff(t1, t2) -> float:
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32)
                                   - jnp.asarray(b, jnp.float32)).max()),
        t1, t2)
    return jax.tree_util.tree_reduce(max, d, 0.0)


def tree_abssum(t) -> float:
    d = jax.tree.map(lambda a: float(jnp.abs(jnp.asarray(a, jnp.float32)
                                             ).sum()), t)
    return jax.tree_util.tree_reduce(lambda a, b: a + b, d, 0.0)
