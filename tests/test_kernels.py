"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True),
the custom-VJP gradient-parity suite, fused-vs-gathered routing parity, and
the gather-free HLO guarantee of the fused kernel."""
import re

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(3)
TOL = {"float32": 2e-5, "bfloat16": 3e-2}
GRAD_TOL = 1e-3


def _grad_maxdiff(g1, g2):
    return max(float(jnp.abs(a - b).max()) for a, b in zip(g1, g2))


def _mk(shape, dtype, key):
    return jax.random.normal(key, shape, dtype=jnp.dtype(dtype))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,Hkv,N,dh,bq,bk,causal", [
    (2, 4, 2, 256, 64, 128, 128, True),
    (1, 2, 1, 128, 32, 64, 32, True),
    (2, 4, 4, 128, 128, 64, 64, False),
    (1, 8, 2, 512, 64, 128, 64, True),
])
def test_flash_attention_sweep(dtype, B, H, Hkv, N, dh, bq, bk, causal):
    ks = jax.random.split(KEY, 3)
    q = _mk((B, H, N, dh), dtype, ks[0])
    k = _mk((B, Hkv, N, dh), dtype, ks[1])
    v = _mk((B, Hkv, N, dh), dtype, ks[2])
    o = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    r = ref.flash_attention_ref(q, k, v, causal=causal)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,Hkv,N,dh,w,causal", [
    (2, 4, 2, 256, 64, 64, True),
    (1, 2, 1, 128, 32, 32, False),
    (2, 2, 2, 256, 128, 128, True),
])
def test_local_attention_sweep(dtype, B, H, Hkv, N, dh, w, causal):
    ks = jax.random.split(KEY, 3)
    q = _mk((B, H, N, dh), dtype, ks[0])
    k = _mk((B, Hkv, N, dh), dtype, ks[1])
    v = _mk((B, Hkv, N, dh), dtype, ks[2])
    o = ops.local_attention(q, k, v, window=w, causal=causal)
    r = ref.local_attention_ref(q, k, v, window=w, causal=causal)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,kc,w,dh,bq,bk,causal,valid", [
    (2, 2, 4, 128, 64, 64, 64, True, False),
    (1, 2, 2, 64, 32, 32, 32, False, True),
    (1, 1, 8, 128, 128, 128, 64, True, False),
    (2, 2, 2, 64, 64, 32, 64, False, False),
])
def test_routed_blocks_sweep(dtype, B, H, kc, w, dh, bq, bk, causal, valid):
    ks = jax.random.split(KEY, 6)
    qg = _mk((B, H, kc, w, dh), dtype, ks[0])
    kg = _mk((B, H, kc, w, dh), dtype, ks[1])
    vg = _mk((B, H, kc, w, dh), dtype, ks[2])
    pq = jax.random.randint(ks[3], (B, H, kc, w), 0, 4096)
    pk = pq if causal else jax.random.randint(ks[4], (B, H, kc, w), 0, 4096)
    vk = jax.random.bernoulli(ks[5], 0.85, (B, H, kc, w)) if valid else None
    o = ops.routed_attention_blocks(qg, kg, vg, pq, pk, causal=causal,
                                    valid_k=vk, bq=bq, bk=bk)
    r = ref.routed_attention_blocks_ref(qg, kg, vg, pq, pk, causal=causal,
                                        valid_k=vk)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


def test_routing_module_pallas_equals_xla():
    from repro.configs.base import RoutingConfig
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    B, H, N, dh = 2, 4, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    st = init_kmeans(ks[2], H, 4, dh)
    cfg = RoutingConfig(num_clusters=4)
    o_x = routed_attention(q, None, v, st, cfg, impl="xla").out
    o_p = routed_attention(q, None, v, st, cfg, impl="pallas").out
    o_f = routed_attention(q, None, v, st, cfg, impl="pallas_fused").out
    assert float(jnp.abs(o_x - o_p).max()) < 1e-5
    assert float(jnp.abs(o_x - o_f).max()) < 1e-5


# ---------------------------------------------------------------------------
# Gradient parity: every kernel's custom VJP vs jax.grad of the XLA math
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_grad_parity(causal):
    B, H, Hkv, N, dh = 2, 4, 2, 256, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, Hkv, N, dh))
    v = jax.random.normal(ks[2], (B, Hkv, N, dh))
    wt = jax.random.normal(ks[3], (B, H, N, dh))
    f = lambda q, k, v: (ops.flash_attention(q, k, v, causal=causal)
                         * wt).sum()
    fr = lambda q, k, v: (ref.flash_attention_ref(q, k, v, causal=causal)
                          * wt).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


@pytest.mark.parametrize("causal", [True, False])
def test_local_attention_grad_parity(causal):
    B, H, Hkv, N, dh, w = 2, 4, 2, 256, 64, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, Hkv, N, dh))
    v = jax.random.normal(ks[2], (B, Hkv, N, dh))
    wt = jax.random.normal(ks[3], (B, H, N, dh))
    f = lambda q, k, v: (ops.local_attention(q, k, v, window=w,
                                             causal=causal) * wt).sum()
    fr = lambda q, k, v: (ref.local_attention_ref(q, k, v, window=w,
                                                  causal=causal) * wt).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


def _routing_case(case):
    """(cfg, k_or_None, pad_mask) for a named routing parity case."""
    from repro.configs.base import RoutingConfig
    B, N = 2, 256
    pm = jnp.broadcast_to(jnp.arange(N)[None, :] < N - 37, (B, N))
    k = jax.random.normal(jax.random.PRNGKey(11), (B, 4, N, 64))
    return {
        "causal_shared": (RoutingConfig(num_clusters=4), None, None),
        "causal_shared_padded": (RoutingConfig(num_clusters=4), None, pm),
        "noncausal_separate": (RoutingConfig(num_clusters=4, causal=False,
                                             share_qk=False), k, None),
        "noncausal_padded": (RoutingConfig(num_clusters=4, causal=False,
                                           share_qk=False), k, pm),
        "segmented": (RoutingConfig(num_clusters=4, segments=2), None,
                      None),
    }[case]


@pytest.mark.parametrize("impl", ["pallas", "pallas_fused"])
@pytest.mark.parametrize("case", ["causal_shared", "causal_shared_padded",
                                  "noncausal_separate", "noncausal_padded",
                                  "segmented"])
def test_routing_grad_parity(impl, case):
    """Kernel VJPs (gathered and fused) vs jax.grad of the XLA reference
    through the full routing module, on every mask/sharing regime."""
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    B, H, N, dh = 2, 4, 256, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    wt = jax.random.normal(ks[3], (B, H, N, dh))
    st = init_kmeans(ks[2], H, 4, dh)
    cfg, k, pm = _routing_case(case)

    def loss(impl):
        def f(q, k, v):
            out = routed_attention(q, k, v, st, cfg, pad_mask=pm,
                                   update_state=False, impl=impl).out
            return (out * wt).sum()
        return f

    args = (0, 2) if k is None else (0, 1, 2)
    g = jax.grad(loss(impl), argnums=args)(q, k, v)
    gr = jax.grad(loss("xla"), argnums=args)(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


def test_routed_blocks_kernel_grad_parity():
    """Gathered-kernel VJP vs the module reference directly at the kernel
    interface (random memberships incl. degenerate no-attendable-key
    rows, which must produce zero output and zero gradient)."""
    from repro.core.routing import _block_attention
    B, H, N, dh, kc, w = 2, 2, 256, 64, 4, 64
    ks = jax.random.split(KEY, 7)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, H, N, dh))
    v = jax.random.normal(ks[2], (B, H, N, dh))
    qi = jnp.sort(jax.random.randint(ks[3], (B, H, kc, w), 0, N), axis=-1)
    ki = jnp.sort(jax.random.randint(ks[4], (B, H, kc, w), 0, N), axis=-1)
    wt = jax.random.normal(ks[5], (B, H, kc, w, dh))
    pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))

    def gath(x, idx):
        return jnp.take_along_axis(x, idx.reshape(B, H, -1, 1),
                                   axis=2).reshape(B, H, kc, w, dh)

    def posg(idx):
        return jnp.take_along_axis(
            jnp.broadcast_to(pos[:, None], (B, H, N)),
            idx.reshape(B, H, -1), axis=2).reshape(B, H, kc, w)

    pq, pk = posg(qi), posg(ki)

    def f(q, k, v):
        og = ops.routed_attention_blocks(gath(q, qi), gath(k, ki),
                                         gath(v, ki), pq, pk, causal=True,
                                         bq=32, bk=32)
        return (og * wt).sum()

    def fr(q, k, v):
        og, _ = _block_attention(gath(q, qi), gath(k, ki), gath(v, ki),
                                 pq, pk, True, None, False)
        return (og * wt).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


# ---------------------------------------------------------------------------
# Fused kernel: forward parity with the gathered kernel + gather-free HLO
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shared,causal,valid", [
    (False, True, False), (False, False, True),
    (True, True, False), (True, True, True),
])
def test_fused_forward_matches_gathered_kernel(shared, causal, valid):
    """Bit-level forward parity: the fused kernel's in-VMEM row pulls see
    exactly the tiles XLA would have gathered."""
    B, H, N, dh, kc, w = 2, 2, 256, 64, 4, 64
    ks = jax.random.split(KEY, 6)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, H, N, dh))
    v = jax.random.normal(ks[2], (B, H, N, dh))
    qi = jnp.sort(jax.random.randint(ks[3], (B, H, kc, w), 0, N), axis=-1)
    ki = qi if shared else jnp.sort(
        jax.random.randint(ks[4], (B, H, kc, w), 0, N), axis=-1)
    pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))
    kvalid = jax.random.bernoulli(ks[5], 0.9, (B, N)) if valid else None
    kk = q if shared else k

    def gath(x, idx):
        return jnp.take_along_axis(x, idx.reshape(B, H, -1, 1),
                                   axis=2).reshape(B, H, kc, w, dh)

    def seqg(x, idx):
        return jnp.take_along_axis(
            jnp.broadcast_to(x[:, None], (B, H, N)),
            idx.reshape(B, H, -1), axis=2).reshape(B, H, kc, w)

    vk = None if kvalid is None else seqg(kvalid, ki)
    og = ops.routed_attention_blocks(gath(q, qi), gath(kk, ki),
                                     gath(v, ki), seqg(pos, qi),
                                     seqg(pos, ki), causal=causal,
                                     valid_k=vk, bq=32, bk=32)
    of = ops.routed_attention_fused(q, None if shared else k, v, qi, ki,
                                    pos, causal=causal, kvalid=kvalid,
                                    bq=32, bk=32)
    assert float(jnp.abs(og - of).max()) < 1e-6


def _dh_gather_ranks(fn, *args):
    """Ranks of every gather op in ``fn``'s optimized HLO whose result
    ends in the head dim (the signature of a gathered q/k/v copy)."""
    text = jax.jit(fn).lower(*args).compile().as_text()
    ranks = []
    for m in re.finditer(r"=\s*\w+\[([0-9,]*)\][^\n]*?\bgather\(", text):
        dims = [int(d) for d in m.group(1).split(",") if d]
        if dims and dims[-1] == 64:          # dh of the test shapes
            ranks.append(len(dims))
    return ranks


def test_fused_hlo_has_no_gathered_qkv():
    """The acceptance guarantee of the fused path: zero gathered
    (B,H,k,w,dh)-shaped q/k/v intermediates in its HLO. The only
    dh-trailing gathers allowed are the kernel's rank-2 in-VMEM tile
    pulls; the gathered impl is the positive control (rank-4 HBM
    gathers present)."""
    from repro.configs.base import RoutingConfig
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    B, H, N, dh = 1, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    st = init_kmeans(ks[2], H, 4, dh)
    cfg = RoutingConfig(num_clusters=4)

    def run(impl):
        return lambda q, v: routed_attention(q, None, v, st, cfg,
                                             update_state=False,
                                             impl=impl).out

    fused_ranks = _dh_gather_ranks(run("pallas_fused"), q, v)
    gathered_ranks = _dh_gather_ranks(run("pallas"), q, v)
    assert all(r <= 2 for r in fused_ranks), fused_ranks
    assert any(r >= 4 for r in gathered_ranks), gathered_ranks


# ---------------------------------------------------------------------------
# Paged fused kernel: double-buffered sequence-plane DMA (the VMEM pager)
# ---------------------------------------------------------------------------
def _fused_inputs(B, H, N, dh, kc, w, *, shared, valid, key=KEY):
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = None if shared else jax.random.normal(ks[1], (B, H, N, dh))
    qi = jnp.sort(jax.random.randint(ks[3], (B, H, kc, w), 0, N), axis=-1)
    ki = qi if shared else jnp.sort(
        jax.random.randint(ks[4], (B, H, kc, w), 0, N), axis=-1)
    v = jax.random.normal(ks[2], (B, H, N, dh))
    pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))
    kvalid = jax.random.bernoulli(ks[5], 0.9, (B, N)) if valid else None
    return q, k, v, qi, ki, pos, kvalid


@pytest.mark.parametrize("shared,causal,valid", [
    (True, True, False), (True, True, True),
    (False, False, False), (False, True, True),
])
def test_paged_fused_matches_unpaged_bitwise(shared, causal, valid):
    """The paged memory plan changes how rows reach VMEM (per-row DMA vs
    whole-plane residency), not what is computed on them: forward output
    must be bit-identical to the unpaged kernel."""
    B, H, N, dh, kc, w = 2, 2, 512, 32, 2, 256
    q, k, v, qi, ki, pos, kvalid = _fused_inputs(B, H, N, dh, kc, w,
                                                 shared=shared, valid=valid)
    up = ops.routed_attention_fused(q, k, v, qi, ki, pos, causal=causal,
                                    kvalid=kvalid, paged=False)
    pg = ops.routed_attention_fused(q, k, v, qi, ki, pos, causal=causal,
                                    kvalid=kvalid, paged=True)
    assert bool(jnp.array_equal(up, pg)), float(jnp.abs(up - pg).max())


@pytest.mark.parametrize("w", [128, 256, 384])
def test_paged_double_buffer_chunk_counts(w):
    """Double-buffer epilogue/prologue correctness at 1, 2 and an odd
    number of tiles per cluster window (nq = nk = w/128 in {1, 2, 3}) —
    the degenerate single-tile case never issues a prefetch, the odd
    case ends on the opposite buffer slot it started on. Forward must
    stay bitwise; the three-kernel backward must match the unpaged VJP."""
    B, H, N, dh, kc = 1, 2, 768, 32, 2
    q, _, v, qi, ki, pos, _ = _fused_inputs(B, H, N, dh, kc, w,
                                            shared=True, valid=False)
    wt = jax.random.normal(jax.random.PRNGKey(7), (B, H, kc, w, dh))

    def loss(paged):
        return lambda q, v: (ops.routed_attention_fused(
            q, None, v, qi, ki, pos, causal=True, paged=paged) * wt).sum()

    up = ops.routed_attention_fused(q, None, v, qi, ki, pos, causal=True,
                                    paged=False)
    pg = ops.routed_attention_fused(q, None, v, qi, ki, pos, causal=True,
                                    paged=True)
    assert bool(jnp.array_equal(up, pg))
    g = jax.grad(loss(True), argnums=(0, 1))(q, v)
    gr = jax.grad(loss(False), argnums=(0, 1))(q, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


@pytest.mark.parametrize("case", ["causal_shared", "padded",
                                  "noncausal_separate", "segmented"])
def test_paged_fused_beyond_cliff_parity(case):
    """The acceptance case: N*dh beyond the old whole-plane VMEM budget
    (8448*128 > FUSED_RESIDENT_ELEMS), where the unpaged kernel could
    not run on real hardware. Forward and gradient parity vs the XLA
    reference through the full routing module, across mask regimes."""
    from repro.configs.base import RoutingConfig
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    from repro.kernels.common import FUSED_RESIDENT_ELEMS
    B, H, N, dh, kc = 1, 1, 8448, 128, 33
    assert N * dh > FUSED_RESIDENT_ELEMS
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    wt = jax.random.normal(ks[3], (B, H, N, dh))
    st = init_kmeans(ks[2], H, kc, dh)
    pm = (jnp.broadcast_to(jnp.arange(N)[None, :] < N - 300, (B, N))
          if case == "padded" else None)
    if case == "noncausal_separate":
        cfg = RoutingConfig(num_clusters=kc, causal=False, share_qk=False)
        k = jax.random.normal(jax.random.PRNGKey(11), (B, H, N, dh))
    else:
        cfg = RoutingConfig(num_clusters=kc,
                            segments=2 if case == "segmented" else 1)
        k = None
    # "pallas_fused" auto-switches to the paged plan at this size; the
    # segmented case folds segments into batch (halving the per-call N
    # below the budget), so it forces the paged plan explicitly.
    impl = ("pallas_fused_paged" if case == "segmented" else "pallas_fused")

    def loss(impl):
        def f(q, k, v):
            out = routed_attention(q, k, v, st, cfg, pad_mask=pm,
                                   update_state=False, impl=impl).out
            return (out * wt).sum()
        return f

    args = (0, 2) if k is None else (0, 1, 2)
    o = routed_attention(q, k, v, st, cfg, pad_mask=pm,
                         update_state=False, impl=impl).out
    orf = routed_attention(q, k, v, st, cfg, pad_mask=pm,
                           update_state=False, impl="xla").out
    assert float(jnp.abs(o - orf).max()) < TOL["float32"]
    g = jax.grad(loss(impl), argnums=args)(q, k, v)
    gr = jax.grad(loss("xla"), argnums=args)(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


def _spy_paged_grid_specs(monkeypatch, calls):
    """Route pl.pallas_call through a spy that records the grid_spec of
    every paged kernel build (scalar-prefetch signature: 4 operands)."""
    import repro.kernels.routing_attention as ra
    orig = ra.pl.pallas_call

    def spy(kernel, *a, **kw):
        gs = kw.get("grid_spec")
        if gs is not None and getattr(gs, "num_scalar_prefetch", 0) == 4:
            calls.append(gs)
        return orig(kernel, *a, **kw)

    monkeypatch.setattr(ra.pl, "pallas_call", spy)


def _scratch_shapes(grid_spec):
    return [(type(s).__name__,) + tuple(getattr(s, "shape", ()))
            for s in grid_spec.scratch_shapes]


def test_paged_vmem_scratch_independent_of_seq_len(monkeypatch):
    """Structural VMEM bound: the paged kernels' scratch allocations
    (tiles + accumulators + DMA semaphores) are functions of (bq, bk,
    dh) only — identical between N and 4N — and the q/k/v operands stay
    in ANY memory space (no N-sized VMEM window in any BlockSpec)."""
    calls = []
    _spy_paged_grid_specs(monkeypatch, calls)

    def build(n):
        kc = n // 128
        q, _, v, qi, ki, pos, _ = _fused_inputs(1, 1, n, 64, kc, 128,
                                                shared=True, valid=False)

        def loss(q, v):
            return (ops.routed_attention_fused(q, None, v, qi, ki, pos,
                                               causal=True, paged=True)
                    ** 2).sum()

        jax.grad(loss, argnums=(0, 1))(q, v)
        got, calls[:] = list(calls), []
        return got

    small, big = build(256), build(1024)
    # forward (x2: once for the value path, once inside the VJP), dq, dkv
    assert len(small) == len(big) and len(big) >= 3
    for gs_s, gs_b in zip(small, big):
        assert _scratch_shapes(gs_s) == _scratch_shapes(gs_b)
        for name, *shape in _scratch_shapes(gs_b):
            assert 1024 not in shape, (name, shape)
        anys = [sp for sp in gs_b.in_specs
                if getattr(sp, "block_shape", None) is None]
        assert len(anys) >= 2    # q and v (k aliases q: shared-QK case)


def test_fused_auto_pages_past_residency_budget(monkeypatch):
    """paged=None switches memory plan on the N*dh residency budget —
    exactly at FUSED_RESIDENT_ELEMS stays resident, one element past it
    pages — and the switch structurally reaches the DMA kernel."""
    import repro.kernels.routing_attention as ra
    from repro.kernels import common
    assert common.fused_paged_default(8192, 128) is False
    assert common.fused_paged_default(8192, 129) is True
    assert common.fused_paged_default(64, 64, paged=True) is True
    assert common.fused_paged_default(1 << 20, 128, paged=False) is False

    calls = []
    _spy_paged_grid_specs(monkeypatch, calls)
    monkeypatch.setattr(common, "FUSED_RESIDENT_ELEMS", 1024)
    q, _, v, qi, ki, pos, _ = _fused_inputs(1, 1, 256, 32, 2, 128,
                                            shared=True, valid=False)
    # bypass the jit wrapper: its trace cache keys on shapes, not on the
    # monkeypatched budget
    ra.routed_attention_fused(q, None, v, qi, ki, pos, causal=True,
                              interpret=True)
    assert calls, "paged=None did not route past the shrunk budget"


def test_interpret_default_derived_from_platform(monkeypatch):
    from repro.kernels import common
    assert common.default_interpret(None) == (jax.default_backend()
                                              != "tpu")
    assert common.default_interpret(True) is True
    assert common.default_interpret(False) is False
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert common.default_interpret(None) is False


# ---------------------------------------------------------------------------
# Train path: impl="pallas" is legal under jax.grad end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["pallas", "pallas_fused"])
def test_train_step_on_pallas_kernels_decreases_loss(impl):
    """make_train_step(impl=...) runs a 20-step loss-decreasing fit with
    the Pallas kernels on the train path (interpret mode on CPU) — no
    silent fallback to the XLA reference."""
    from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                    TrainConfig)
    from repro.data.synthetic import SyntheticLoader
    from repro.train.train_step import init_train_state, make_train_step
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=64,
                      attention="routing",
                      routing=RoutingConfig(num_clusters=4),
                      dtype="float32")
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=8, seq_len=64, steps=20, lr=3e-3, schedule="const",
        warmup_steps=5, remat="none"))
    ts = init_train_state(run, KEY)
    step = jax.jit(make_train_step(run, impl=impl))
    loader = SyntheticLoader("markov", cfg.vocab_size, 8, 64)
    losses = []
    for _, b in zip(range(run.train.steps), loader):
        ts, m = step(ts, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
