"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True),
the custom-VJP gradient-parity suite, fused-vs-gathered routing parity, and
the gather-free HLO guarantee of the fused kernel."""
import re

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(3)
TOL = {"float32": 2e-5, "bfloat16": 3e-2}
GRAD_TOL = 1e-3


def _grad_maxdiff(g1, g2):
    return max(float(jnp.abs(a - b).max()) for a, b in zip(g1, g2))


def _mk(shape, dtype, key):
    return jax.random.normal(key, shape, dtype=jnp.dtype(dtype))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,Hkv,N,dh,bq,bk,causal", [
    (2, 4, 2, 256, 64, 128, 128, True),
    (1, 2, 1, 128, 32, 64, 32, True),
    (2, 4, 4, 128, 128, 64, 64, False),
    (1, 8, 2, 512, 64, 128, 64, True),
])
def test_flash_attention_sweep(dtype, B, H, Hkv, N, dh, bq, bk, causal):
    ks = jax.random.split(KEY, 3)
    q = _mk((B, H, N, dh), dtype, ks[0])
    k = _mk((B, Hkv, N, dh), dtype, ks[1])
    v = _mk((B, Hkv, N, dh), dtype, ks[2])
    o = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    r = ref.flash_attention_ref(q, k, v, causal=causal)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,Hkv,N,dh,w,causal", [
    (2, 4, 2, 256, 64, 64, True),
    (1, 2, 1, 128, 32, 32, False),
    (2, 2, 2, 256, 128, 128, True),
])
def test_local_attention_sweep(dtype, B, H, Hkv, N, dh, w, causal):
    ks = jax.random.split(KEY, 3)
    q = _mk((B, H, N, dh), dtype, ks[0])
    k = _mk((B, Hkv, N, dh), dtype, ks[1])
    v = _mk((B, Hkv, N, dh), dtype, ks[2])
    o = ops.local_attention(q, k, v, window=w, causal=causal)
    r = ref.local_attention_ref(q, k, v, window=w, causal=causal)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,kc,w,dh,bq,bk,causal,valid", [
    (2, 2, 4, 128, 64, 64, 64, True, False),
    (1, 2, 2, 64, 32, 32, 32, False, True),
    (1, 1, 8, 128, 128, 128, 64, True, False),
    (2, 2, 2, 64, 64, 32, 64, False, False),
])
def test_routed_blocks_sweep(dtype, B, H, kc, w, dh, bq, bk, causal, valid):
    ks = jax.random.split(KEY, 6)
    qg = _mk((B, H, kc, w, dh), dtype, ks[0])
    kg = _mk((B, H, kc, w, dh), dtype, ks[1])
    vg = _mk((B, H, kc, w, dh), dtype, ks[2])
    pq = jax.random.randint(ks[3], (B, H, kc, w), 0, 4096)
    pk = pq if causal else jax.random.randint(ks[4], (B, H, kc, w), 0, 4096)
    vk = jax.random.bernoulli(ks[5], 0.85, (B, H, kc, w)) if valid else None
    o = ops.routed_attention_blocks(qg, kg, vg, pq, pk, causal=causal,
                                    valid_k=vk, bq=bq, bk=bk)
    r = ref.routed_attention_blocks_ref(qg, kg, vg, pq, pk, causal=causal,
                                        valid_k=vk)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


def test_routing_module_pallas_equals_xla():
    from repro.configs.base import RoutingConfig
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    B, H, N, dh = 2, 4, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    st = init_kmeans(ks[2], H, 4, dh)
    cfg = RoutingConfig(num_clusters=4)
    o_x = routed_attention(q, None, v, st, cfg, impl="xla").out
    o_p = routed_attention(q, None, v, st, cfg, impl="pallas").out
    o_f = routed_attention(q, None, v, st, cfg, impl="pallas_fused").out
    assert float(jnp.abs(o_x - o_p).max()) < 1e-5
    assert float(jnp.abs(o_x - o_f).max()) < 1e-5


# ---------------------------------------------------------------------------
# Gradient parity: every kernel's custom VJP vs jax.grad of the XLA math
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_grad_parity(causal):
    B, H, Hkv, N, dh = 2, 4, 2, 256, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, Hkv, N, dh))
    v = jax.random.normal(ks[2], (B, Hkv, N, dh))
    wt = jax.random.normal(ks[3], (B, H, N, dh))
    f = lambda q, k, v: (ops.flash_attention(q, k, v, causal=causal)
                         * wt).sum()
    fr = lambda q, k, v: (ref.flash_attention_ref(q, k, v, causal=causal)
                          * wt).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


@pytest.mark.parametrize("causal", [True, False])
def test_local_attention_grad_parity(causal):
    B, H, Hkv, N, dh, w = 2, 4, 2, 256, 64, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, Hkv, N, dh))
    v = jax.random.normal(ks[2], (B, Hkv, N, dh))
    wt = jax.random.normal(ks[3], (B, H, N, dh))
    f = lambda q, k, v: (ops.local_attention(q, k, v, window=w,
                                             causal=causal) * wt).sum()
    fr = lambda q, k, v: (ref.local_attention_ref(q, k, v, window=w,
                                                  causal=causal) * wt).sum()
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


def _routing_case(case):
    """(cfg, k_or_None, pad_mask) for a named routing parity case."""
    from repro.configs.base import RoutingConfig
    B, N = 2, 256
    pm = jnp.broadcast_to(jnp.arange(N)[None, :] < N - 37, (B, N))
    k = jax.random.normal(jax.random.PRNGKey(11), (B, 4, N, 64))
    return {
        "causal_shared": (RoutingConfig(num_clusters=4), None, None),
        "causal_shared_padded": (RoutingConfig(num_clusters=4), None, pm),
        "noncausal_separate": (RoutingConfig(num_clusters=4, causal=False,
                                             share_qk=False), k, None),
        "noncausal_padded": (RoutingConfig(num_clusters=4, causal=False,
                                           share_qk=False), k, pm),
        "segmented": (RoutingConfig(num_clusters=4, segments=2), None,
                      None),
    }[case]


@pytest.mark.parametrize("impl", ["pallas", "pallas_fused"])
@pytest.mark.parametrize("case", ["causal_shared", "causal_shared_padded",
                                  "noncausal_separate", "noncausal_padded",
                                  "segmented"])
def test_routing_grad_parity(impl, case):
    """Kernel VJPs (gathered and fused) vs jax.grad of the XLA reference
    through the full routing module, on every mask/sharing regime."""
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    B, H, N, dh = 2, 4, 256, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    wt = jax.random.normal(ks[3], (B, H, N, dh))
    st = init_kmeans(ks[2], H, 4, dh)
    cfg, k, pm = _routing_case(case)

    def loss(impl):
        def f(q, k, v):
            out = routed_attention(q, k, v, st, cfg, pad_mask=pm,
                                   update_state=False, impl=impl).out
            return (out * wt).sum()
        return f

    args = (0, 2) if k is None else (0, 1, 2)
    g = jax.grad(loss(impl), argnums=args)(q, k, v)
    gr = jax.grad(loss("xla"), argnums=args)(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


def test_routed_blocks_kernel_grad_parity():
    """Gathered-kernel VJP vs the module reference directly at the kernel
    interface (random memberships incl. degenerate no-attendable-key
    rows, which must produce zero output and zero gradient)."""
    from repro.core.routing import _block_attention
    B, H, N, dh, kc, w = 2, 2, 256, 64, 4, 64
    ks = jax.random.split(KEY, 7)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, H, N, dh))
    v = jax.random.normal(ks[2], (B, H, N, dh))
    qi = jnp.sort(jax.random.randint(ks[3], (B, H, kc, w), 0, N), axis=-1)
    ki = jnp.sort(jax.random.randint(ks[4], (B, H, kc, w), 0, N), axis=-1)
    wt = jax.random.normal(ks[5], (B, H, kc, w, dh))
    pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))

    def gath(x, idx):
        return jnp.take_along_axis(x, idx.reshape(B, H, -1, 1),
                                   axis=2).reshape(B, H, kc, w, dh)

    def posg(idx):
        return jnp.take_along_axis(
            jnp.broadcast_to(pos[:, None], (B, H, N)),
            idx.reshape(B, H, -1), axis=2).reshape(B, H, kc, w)

    pq, pk = posg(qi), posg(ki)

    def f(q, k, v):
        og = ops.routed_attention_blocks(gath(q, qi), gath(k, ki),
                                         gath(v, ki), pq, pk, causal=True,
                                         bq=32, bk=32)
        return (og * wt).sum()

    def fr(q, k, v):
        og, _ = _block_attention(gath(q, qi), gath(k, ki), gath(v, ki),
                                 pq, pk, True, None, False)
        return (og * wt).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    assert _grad_maxdiff(g, gr) < GRAD_TOL


# ---------------------------------------------------------------------------
# Fused kernel: forward parity with the gathered kernel + gather-free HLO
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shared,causal,valid", [
    (False, True, False), (False, False, True),
    (True, True, False), (True, True, True),
])
def test_fused_forward_matches_gathered_kernel(shared, causal, valid):
    """Bit-level forward parity: the fused kernel's in-VMEM row pulls see
    exactly the tiles XLA would have gathered."""
    B, H, N, dh, kc, w = 2, 2, 256, 64, 4, 64
    ks = jax.random.split(KEY, 6)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    k = jax.random.normal(ks[1], (B, H, N, dh))
    v = jax.random.normal(ks[2], (B, H, N, dh))
    qi = jnp.sort(jax.random.randint(ks[3], (B, H, kc, w), 0, N), axis=-1)
    ki = qi if shared else jnp.sort(
        jax.random.randint(ks[4], (B, H, kc, w), 0, N), axis=-1)
    pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))
    kvalid = jax.random.bernoulli(ks[5], 0.9, (B, N)) if valid else None
    kk = q if shared else k

    def gath(x, idx):
        return jnp.take_along_axis(x, idx.reshape(B, H, -1, 1),
                                   axis=2).reshape(B, H, kc, w, dh)

    def seqg(x, idx):
        return jnp.take_along_axis(
            jnp.broadcast_to(x[:, None], (B, H, N)),
            idx.reshape(B, H, -1), axis=2).reshape(B, H, kc, w)

    vk = None if kvalid is None else seqg(kvalid, ki)
    og = ops.routed_attention_blocks(gath(q, qi), gath(kk, ki),
                                     gath(v, ki), seqg(pos, qi),
                                     seqg(pos, ki), causal=causal,
                                     valid_k=vk, bq=32, bk=32)
    of = ops.routed_attention_fused(q, None if shared else k, v, qi, ki,
                                    pos, causal=causal, kvalid=kvalid,
                                    bq=32, bk=32)
    assert float(jnp.abs(og - of).max()) < 1e-6


def _dh_gather_ranks(fn, *args):
    """Ranks of every gather op in ``fn``'s optimized HLO whose result
    ends in the head dim (the signature of a gathered q/k/v copy)."""
    text = jax.jit(fn).lower(*args).compile().as_text()
    ranks = []
    for m in re.finditer(r"=\s*\w+\[([0-9,]*)\][^\n]*?\bgather\(", text):
        dims = [int(d) for d in m.group(1).split(",") if d]
        if dims and dims[-1] == 64:          # dh of the test shapes
            ranks.append(len(dims))
    return ranks


def test_fused_hlo_has_no_gathered_qkv():
    """The acceptance guarantee of the fused path: zero gathered
    (B,H,k,w,dh)-shaped q/k/v intermediates in its HLO. The only
    dh-trailing gathers allowed are the kernel's rank-2 in-VMEM tile
    pulls; the gathered impl is the positive control (rank-4 HBM
    gathers present)."""
    from repro.configs.base import RoutingConfig
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    B, H, N, dh = 1, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    st = init_kmeans(ks[2], H, 4, dh)
    cfg = RoutingConfig(num_clusters=4)

    def run(impl):
        return lambda q, v: routed_attention(q, None, v, st, cfg,
                                             update_state=False,
                                             impl=impl).out

    fused_ranks = _dh_gather_ranks(run("pallas_fused"), q, v)
    gathered_ranks = _dh_gather_ranks(run("pallas"), q, v)
    assert all(r <= 2 for r in fused_ranks), fused_ranks
    assert any(r >= 4 for r in gathered_ranks), gathered_ranks


def test_interpret_default_derived_from_platform(monkeypatch):
    from repro.kernels import common
    assert common.default_interpret(None) == (jax.default_backend()
                                              != "tpu")
    assert common.default_interpret(True) is True
    assert common.default_interpret(False) is False
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert common.default_interpret(None) is False


# ---------------------------------------------------------------------------
# Train path: impl="pallas" is legal under jax.grad end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["pallas", "pallas_fused"])
def test_train_step_on_pallas_kernels_decreases_loss(impl):
    """make_train_step(impl=...) runs a 20-step loss-decreasing fit with
    the Pallas kernels on the train path (interpret mode on CPU) — no
    silent fallback to the XLA reference."""
    from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                    TrainConfig)
    from repro.data.synthetic import SyntheticLoader
    from repro.train.train_step import init_train_state, make_train_step
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=64,
                      attention="routing",
                      routing=RoutingConfig(num_clusters=4),
                      dtype="float32")
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=8, seq_len=64, steps=20, lr=3e-3, schedule="const",
        warmup_steps=5, remat="none"))
    ts = init_train_state(run, KEY)
    step = jax.jit(make_train_step(run, impl=impl))
    loader = SyntheticLoader("markov", cfg.vocab_size, 8, 64)
    losses = []
    for _, b in zip(range(run.train.steps), loader):
        ts, m = step(ts, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
