"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(3)
TOL = {"float32": 2e-5, "bfloat16": 3e-2}


def _mk(shape, dtype, key):
    return jax.random.normal(key, shape, dtype=jnp.dtype(dtype))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,Hkv,N,dh,bq,bk,causal", [
    (2, 4, 2, 256, 64, 128, 128, True),
    (1, 2, 1, 128, 32, 64, 32, True),
    (2, 4, 4, 128, 128, 64, 64, False),
    (1, 8, 2, 512, 64, 128, 64, True),
])
def test_flash_attention_sweep(dtype, B, H, Hkv, N, dh, bq, bk, causal):
    ks = jax.random.split(KEY, 3)
    q = _mk((B, H, N, dh), dtype, ks[0])
    k = _mk((B, Hkv, N, dh), dtype, ks[1])
    v = _mk((B, Hkv, N, dh), dtype, ks[2])
    o = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    r = ref.flash_attention_ref(q, k, v, causal=causal)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,Hkv,N,dh,w,causal", [
    (2, 4, 2, 256, 64, 64, True),
    (1, 2, 1, 128, 32, 32, False),
    (2, 2, 2, 256, 128, 128, True),
])
def test_local_attention_sweep(dtype, B, H, Hkv, N, dh, w, causal):
    ks = jax.random.split(KEY, 3)
    q = _mk((B, H, N, dh), dtype, ks[0])
    k = _mk((B, Hkv, N, dh), dtype, ks[1])
    v = _mk((B, Hkv, N, dh), dtype, ks[2])
    o = ops.local_attention(q, k, v, window=w, causal=causal)
    r = ref.local_attention_ref(q, k, v, window=w, causal=causal)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,H,kc,w,dh,bq,bk,causal,valid", [
    (2, 2, 4, 128, 64, 64, 64, True, False),
    (1, 2, 2, 64, 32, 32, 32, False, True),
    (1, 1, 8, 128, 128, 128, 64, True, False),
    (2, 2, 2, 64, 64, 32, 64, False, False),
])
def test_routed_blocks_sweep(dtype, B, H, kc, w, dh, bq, bk, causal, valid):
    ks = jax.random.split(KEY, 6)
    qg = _mk((B, H, kc, w, dh), dtype, ks[0])
    kg = _mk((B, H, kc, w, dh), dtype, ks[1])
    vg = _mk((B, H, kc, w, dh), dtype, ks[2])
    pq = jax.random.randint(ks[3], (B, H, kc, w), 0, 4096)
    pk = pq if causal else jax.random.randint(ks[4], (B, H, kc, w), 0, 4096)
    vk = jax.random.bernoulli(ks[5], 0.85, (B, H, kc, w)) if valid else None
    o = ops.routed_attention_blocks(qg, kg, vg, pq, pk, causal=causal,
                                    valid_k=vk, bq=bq, bk=bk)
    r = ref.routed_attention_blocks_ref(qg, kg, vg, pq, pk, causal=causal,
                                        valid_k=vk)
    err = float(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32)).max())
    assert err < TOL[dtype], err


def test_routing_module_pallas_equals_xla():
    from repro.configs.base import RoutingConfig
    from repro.core.kmeans import init_kmeans
    from repro.core.routing import routed_attention
    B, H, N, dh = 2, 4, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    st = init_kmeans(ks[2], H, 4, dh)
    cfg = RoutingConfig(num_clusters=4)
    o_x = routed_attention(q, None, v, st, cfg, impl="xla").out
    o_p = routed_attention(q, None, v, st, cfg, impl="pallas").out
    assert float(jnp.abs(o_x - o_p).max()) < 1e-5
