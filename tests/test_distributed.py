"""Multi-host launch scaffolding (launch/distributed.py): env/flag
coordinator discovery, validation, and the single-process fallback.
The actual jax.distributed.initialize call is monkeypatched — spinning a
real coordinator needs multiple processes, which CI exercises only
through the fallback path (the one laptops run too)."""
import pytest

from repro.launch import distributed as dist


def test_detect_nothing_configured_is_single_process():
    assert dist.detect(env={}) is None


def test_detect_from_env():
    spec = dist.detect(env={dist.ENV_COORDINATOR: "host0:9876",
                            dist.ENV_NUM_PROCESSES: "4",
                            dist.ENV_PROCESS_ID: "2"})
    assert spec == dist.LaunchSpec("host0:9876", 4, 2)


def test_flags_override_env():
    spec = dist.detect(env={dist.ENV_COORDINATOR: "stale:1",
                            dist.ENV_NUM_PROCESSES: "2",
                            dist.ENV_PROCESS_ID: "1"},
                       coordinator="fresh:2", num_processes=8,
                       process_id=7)
    assert spec == dist.LaunchSpec("fresh:2", 8, 7)


def test_missing_rank_raises():
    # defaulting a missing rank to 0 would make EVERY host claim
    # process 0 and hang the coordinator handshake
    with pytest.raises(ValueError, match="explicit rank"):
        dist.detect(env={dist.ENV_COORDINATOR: "host0:9876",
                         dist.ENV_NUM_PROCESSES: "2"})
    # REPRO_PROCESS_ID=$RANK with $RANK unset exports "": same error,
    # not a bare int('') crash
    with pytest.raises(ValueError, match="explicit rank"):
        dist.detect(env={dist.ENV_COORDINATOR: "host0:9876",
                         dist.ENV_NUM_PROCESSES: "2",
                         dist.ENV_PROCESS_ID: ""})


def test_half_configured_launch_raises():
    # NUM_PROCESSES without a coordinator: a typo'd env must never
    # silently train on 1/N of the fleet
    with pytest.raises(ValueError, match="coordinator"):
        dist.detect(env={dist.ENV_NUM_PROCESSES: "4"})
    with pytest.raises(ValueError):
        dist.detect(env={dist.ENV_COORDINATOR: "host0:9876"})


def test_spec_validation():
    with pytest.raises(ValueError, match="process_id"):
        dist.LaunchSpec("host0:9876", 4, 4)
    with pytest.raises(ValueError, match="host:port"):
        dist.LaunchSpec("no-port", 4, 0)
    # single process needs no coordinator
    assert dist.LaunchSpec("", 1, 0).num_processes == 1


def test_initialize_single_process_fallback():
    assert dist.initialize(env={}) is False


def test_initialize_calls_jax_distributed(monkeypatch):
    import jax
    calls = {}

    def fake_init(coordinator_address, num_processes, process_id):
        calls.update(addr=coordinator_address, n=num_processes,
                     pid=process_id)

    monkeypatch.setattr(jax.distributed, "initialize", fake_init)
    ran = dist.initialize(env={dist.ENV_COORDINATOR: "host0:9876",
                               dist.ENV_NUM_PROCESSES: "2",
                               dist.ENV_PROCESS_ID: "1"})
    assert ran is True
    assert calls == {"addr": "host0:9876", "n": 2, "pid": 1}


def test_process_info_single_process():
    info = dist.process_info()
    assert info["process_index"] == 0
    assert info["process_count"] == 1
    assert info["global_devices"] >= 1


def test_make_process_mesh_clamps():
    mesh = dist.make_process_mesh(64, 64)   # wildly oversubscribed
    assert mesh.shape["data"] * mesh.shape["model"] >= 1
    assert set(mesh.axis_names) == {"data", "model"}
