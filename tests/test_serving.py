"""Serving: prefill+decode == teacher-forced forward (exact for
full/local/ssd/rglru/moe/vlm; mechanism checks for routing heads)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, RoutingConfig
from repro.core.kmeans import normalize_routing
from repro.models.model import init_model, apply_model
from repro.serve.serving import init_cache, make_serve_step, prefill

KEY = jax.random.PRNGKey(0)
B, T, TP = 2, 48, 32
BASE = dict(num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
            vocab_size=64, dtype="float32")


def _run(cfg, extra=None, exact=True, tol=1e-3):
    params, kstate = init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, **(extra or {})}
    full, _, _ = apply_model(params, kstate, batch, cfg, update_state=False)
    cache = init_cache(cfg, B, max_len=T + 8)
    pre = {k: (v[:, :TP] if v.ndim >= 2 and v.shape[1] == T else v)
           for k, v in batch.items()}
    lg_p, cache = prefill(params, kstate, cache, pre, cfg)
    errs = [float(jnp.abs(lg_p - full[:, :TP]).max())]
    step = jax.jit(make_serve_step(cfg))
    for t in range(TP, T):
        lg, cache = step(params, kstate, cache, toks[:, t],
                         jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
        assert bool(jnp.isfinite(lg).all())
    if exact:
        assert max(errs) < tol, errs
    return cache


def test_decode_full():
    _run(ModelConfig(name="f", family="dense", attention="full", **BASE))


def test_decode_local():
    _run(ModelConfig(name="l", family="dense", attention="local",
                     attn_window=16, **BASE))


def test_decode_ssm():
    _run(ModelConfig(name="s", family="ssm", num_layers=3, d_model=64,
                     num_heads=4, d_ff=0, vocab_size=64, ssm_state=16,
                     ssm_chunk=16, dtype="float32"))


@pytest.mark.slow
def test_decode_hybrid():
    _run(ModelConfig(name="h", family="hybrid", num_layers=6, d_model=64,
                     num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=64,
                     attention="local", hybrid_pattern=("rglru", "rglru",
                                                        "attn"),
                     attn_window=16, dtype="float32"))


@pytest.mark.slow
def test_decode_moe():
    _run(ModelConfig(name="m", family="moe", moe_experts=4, moe_interleave=2,
                     moe_capacity_factor=8.0, **BASE))


def test_decode_vlm():
    img = jax.random.normal(KEY, (B, 17, 64))
    _run(ModelConfig(name="v", family="vlm", num_image_tokens=17, **BASE),
         extra={"image_embeds": img})


def test_decode_routing_mechanism():
    """Routing decode: finite logits + the cluster-paged cache is coherent:
    page lengths sum to the number of decoded+prefilled tokens per head."""
    cfg = ModelConfig(name="r", family="dense", attention="local+routing",
                      routing=RoutingConfig(num_clusters=4, local_window=16),
                      **BASE)
    cache = _run(cfg, exact=False)
    # every layer's rlen sums to T (each token went to exactly one page)
    for seg in cache:
        for slot in seg.values():
            if "rlen" in slot:
                totals = slot["rlen"].sum(-1)        # (G,B,Hr)
                assert bool((totals == T).all()), totals


def _routing_probe_spec(kc=2):
    from repro.attn import AttentionSpec
    return AttentionSpec(variant="routing", num_heads=1, num_kv_heads=1,
                         head_dim=8, causal=True,
                         routing=RoutingConfig(num_clusters=kc))


def test_routing_decode_attends_own_cluster_only():
    """Single-layer probe: the decode step's attention output must equal a
    hand-computed softmax over (tokens in the query's argmax page + self)."""
    from repro import attn as A
    B_, Hr, dh, kc, cap = 1, 1, 8, 2, 4
    ks = jax.random.split(KEY, 4)
    rk = jnp.zeros((B_, Hr, kc, cap, dh))
    rv = jnp.zeros((B_, Hr, kc, cap, dh))
    # fill page 0 with 3 keys
    keys = normalize_routing(jax.random.normal(ks[0], (B_, Hr, 3, dh)))
    vals = jax.random.normal(ks[1], (B_, Hr, 3, dh))
    rk = rk.at[:, :, 0, :3].set(keys)
    rv = rv.at[:, :, 0, :3].set(vals)
    rlen = jnp.zeros((B_, Hr, kc), jnp.int32).at[:, :, 0].set(3)
    mu = jnp.stack([keys[0, 0].mean(0), -keys[0, 0].mean(0)])[None]  # (1,2,8)
    q = jax.random.normal(ks[2], (B_, Hr, 1, dh)) * 0.1 + keys[:, :, :1]
    v_new = jax.random.normal(ks[3], (B_, Hr, 1, dh))
    cache = {"rk": rk, "rv": rv, "rlen": rlen}
    out = A.attend(_routing_probe_spec(kc), q, None, v_new, state=mu,
                   cache=cache, pos=jnp.array([10]))
    o, nc = out.out, out.cache
    r = normalize_routing(q)[:, :, 0]
    logits = jnp.concatenate([
        jnp.einsum("bhd,bhcd->bhc", r, keys),
        jnp.einsum("bhd,bhd->bh", r, r)[..., None]], -1) / jnp.sqrt(dh)
    attn = jax.nn.softmax(logits, -1)
    allv = jnp.concatenate([vals, v_new[:, :, 0][:, :, None]], 2)
    ref = jnp.einsum("bhc,bhcd->bhd", attn, allv)
    assert float(jnp.abs(o[:, :, 0] - ref).max()) < 1e-5
    assert int(nc["rlen"][0, 0, 0]) == 4        # appended to page 0


def test_routing_decode_masks_unwritten_page_slots():
    """N=1 decode vs a long partially-filled page: slots beyond rlen are
    poisoned with huge values and must not leak into the output — the
    page-validity mask is the routing decode's causal mask (everything in
    a page is past; everything beyond rlen never existed)."""
    from repro import attn as A
    B_, Hr, dh, kc, cap = 1, 1, 8, 2, 16
    ks = jax.random.split(KEY, 4)
    keys = normalize_routing(jax.random.normal(ks[0], (B_, Hr, 5, dh)))
    vals = jax.random.normal(ks[1], (B_, Hr, 5, dh))
    rk = jnp.zeros((B_, Hr, kc, cap, dh)).at[:, :, 0, :5].set(keys)
    rv = jnp.zeros((B_, Hr, kc, cap, dh)).at[:, :, 0, :5].set(vals)
    # poison every slot past rlen on BOTH pages: keys that would dominate
    # the softmax and values that would blow up the output
    rk_p = rk.at[:, :, :, 5:].set(1e4)
    rv_p = rv.at[:, :, :, 5:].set(1e4)
    rlen = jnp.zeros((B_, Hr, kc), jnp.int32).at[:, :, 0].set(5)
    mu = jnp.stack([keys[0, 0].mean(0), -keys[0, 0].mean(0)])[None]
    q = keys[:, :, 2:3] + 0.05 * jax.random.normal(ks[2], (B_, Hr, 1, dh))
    v_new = jax.random.normal(ks[3], (B_, Hr, 1, dh))
    spec = _routing_probe_spec(kc)
    pos = jnp.array([523])                      # deep into a long decode
    clean = A.attend(spec, q, None, v_new, state=mu,
                     cache={"rk": rk, "rv": rv, "rlen": rlen}, pos=pos)
    poisoned = A.attend(spec, q, None, v_new, state=mu,
                        cache={"rk": rk_p, "rv": rv_p, "rlen": rlen},
                        pos=pos)
    assert float(jnp.abs(clean.out - poisoned.out).max()) == 0.0
    assert bool(jnp.isfinite(poisoned.out).all())


def test_full_decode_positions_vs_long_cache():
    """N=1 query at position t against a long append cache: entries the
    cache holds at positions > t (poisoned here) are causally masked via
    the positions plumbing, and the output matches full_attention over
    the true prefix."""
    from repro import attn as A
    from repro.core.attention import full_attention
    B_, H, dh, M, t = 2, 2, 16, 64, 37
    ks = jax.random.split(KEY, 3)
    k_all = jax.random.normal(ks[0], (B_, H, M, dh))
    v_all = jax.random.normal(ks[1], (B_, H, M, dh))
    q = jax.random.normal(ks[2], (B_, H, 1, dh))
    spec = A.AttentionSpec(variant="full", num_heads=H, num_kv_heads=H,
                           head_dim=dh, causal=True)   # no rope: raw parity
    cache = A.init_decode_cache(spec, B_, M, jnp.float32)
    # prefix < t is real; positions >= t hold junk a causal decode must
    # never see (stale lane contents in a reused slot-pool lane)
    cache["k"] = cache["k"].at[:, :, :t].set(k_all[:, :, :t]) \
                           .at[:, :, t + 1:].set(1e4)
    cache["v"] = cache["v"].at[:, :, :t].set(v_all[:, :, :t]) \
                           .at[:, :, t + 1:].set(1e4)
    pos = jnp.full((B_,), t, jnp.int32)
    out = A.attend(spec, q, k_all[:, :, t:t + 1], v_all[:, :, t:t + 1],
                   cache=cache, pos=pos)
    ref = full_attention(q, k_all[:, :, :t + 1], v_all[:, :, :t + 1],
                         causal=True, positions=pos[:, None])
    assert float(jnp.abs(out.out - ref).max()) < 1e-5
    # the new token was appended at its position
    assert float(jnp.abs(out.cache["k"][:, :, t] - k_all[:, :, t]).max()) \
        < 1e-6


def test_batched_requests_different_positions():
    """Rows decode at different positions (continuous batching shape)."""
    cfg = ModelConfig(name="f2", family="dense", attention="full", **BASE)
    params, kstate = init_model(cfg, KEY)
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    full, _, _ = apply_model(params, kstate, {"tokens": toks}, cfg,
                             update_state=False)
    cache = init_cache(cfg, B, max_len=T + 8)
    _, cache = prefill(params, kstate, cache, {"tokens": toks[:, :TP]}, cfg)
    step = jax.jit(make_serve_step(cfg))
    # row 0 decodes token TP, row 1 re-decodes token TP (same pos) -- then
    # advance rows *independently* via per-row pos vector
    pos = jnp.array([TP, TP], jnp.int32)
    lg, cache = step(params, kstate, cache, toks[:, TP], pos)
    assert float(jnp.abs(lg - full[:, TP]).max()) < 1e-3
