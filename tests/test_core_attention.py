"""Core attention math: full/chunked/local/routing vs dense oracles."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import RoutingConfig
from repro.core import attention, kmeans, local, routing

KEY = jax.random.PRNGKey(7)


def _qkv(B=2, H=4, Hkv=2, N=128, dh=32, key=KEY):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, H, N, dh)),
            jax.random.normal(ks[1], (B, Hkv, N, dh)),
            jax.random.normal(ks[2], (B, Hkv, N, dh)))


class TestFullAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("chunk", [16, 32, 100])
    def test_chunked_matches_dense(self, causal, chunk):
        q, k, v = _qkv()
        o1 = attention.full_attention(q, k, v, causal=causal)
        o2 = attention.full_attention(q, k, v, causal=causal, chunk=chunk)
        assert float(jnp.abs(o1 - o2).max()) < 1e-5

    def test_pad_mask(self):
        q, k, v = _qkv()
        pm = jnp.arange(128)[None, :] < 64
        pm = jnp.broadcast_to(pm, (2, 128))
        o1 = attention.full_attention(q, k, v, causal=True, pad_mask=pm)
        o2 = attention.full_attention(q[:, :, :64], k[:, :, :64],
                                      v[:, :, :64], causal=True)
        assert float(jnp.abs(o1[:, :, :64] - o2).max()) < 1e-5

    def test_decode_positions(self):
        """Single query at position t == row t of the full forward."""
        q, k, v = _qkv(N=64)
        o_full = attention.full_attention(q, k, v, causal=True)
        t = 37
        o_t = attention.full_attention(
            q[:, :, t:t + 1], k, v, causal=True,
            positions=jnp.full((2, 1), t))
        assert float(jnp.abs(o_t[:, :, 0] - o_full[:, :, t]).max()) < 1e-5


class TestLocalAttention:
    @pytest.mark.parametrize("w", [16, 32, 64])
    def test_blocked_semantics(self, w):
        q, k, v = _qkv(N=128)
        o = local.local_attention(q, k, v, window=w, causal=True)
        pos = jnp.arange(128)
        blk = pos // w
        diff = blk[:, None] - blk[None, :]
        keep = (diff >= 0) & (diff <= 1) & (pos[:, None] >= pos[None, :])
        qg = q.reshape(2, 2, 2, 128, 32)
        s = jnp.einsum("bhgnd,bhmd->bhgnm", qg, k) / jnp.sqrt(32)
        s = jnp.where(keep, s, -1e9)
        ref = jnp.einsum("bhgnm,bhmd->bhgnd", jax.nn.softmax(s, -1),
                         v).reshape(2, 4, 128, 32)
        assert float(jnp.abs(o - ref).max()) < 1e-5

    def test_ragged_length_pads(self):
        q, k, v = _qkv(N=100)       # not a multiple of the window
        o = local.local_attention(q, k, v, window=32, causal=True)
        assert o.shape == (2, 4, 100, 32)
        assert bool(jnp.isfinite(o).all())


class TestRoutingAttention:
    @pytest.mark.parametrize("share_qk,causal", [(True, True),
                                                 (False, False),
                                                 (False, True)])
    def test_vs_dense_oracle(self, share_qk, causal):
        B, H, N, dh = 2, 4, 128, 32
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (B, H, N, dh))
        k = jax.random.normal(ks[1], (B, H, N, dh))
        v = jax.random.normal(ks[2], (B, H, N, dh))
        st = kmeans.init_kmeans(ks[3], H, 4, dh)
        cfg = RoutingConfig(num_clusters=4, share_qk=share_qk, causal=causal)
        out = routing.routed_attention(q, None if share_qk else k, v, st,
                                       cfg).out
        ref = routing.routing_attention_dense_oracle(
            q, None if share_qk else k, v, st, cfg)
        assert float(jnp.abs(out - ref).max()) < 1e-4

    def test_padding_never_selected(self):
        B, H, N, dh = 2, 2, 64, 16
        q = jax.random.normal(KEY, (B, H, N, dh))
        st = kmeans.init_kmeans(KEY, H, 2, dh)
        pm = jnp.arange(N)[None, :] < 40
        pm = jnp.broadcast_to(pm, (B, N))
        out = routing.routed_attention(
            q, None, q, st, RoutingConfig(num_clusters=2, window=16),
            pad_mask=pm, return_attn=True)
        assert int(out.q_idx.max()) < 40

    def test_window_larger_than_seq_clips(self):
        q = jax.random.normal(KEY, (1, 2, 16, 8))
        st = kmeans.init_kmeans(KEY, 2, 4, 8)
        out = routing.routed_attention(
            q, None, q, st, RoutingConfig(num_clusters=4, window=999))
        assert out.out.shape == (1, 2, 16, 8)

    def test_complexity_window(self):
        """w defaults to n/k (the paper's balanced assignment size)."""
        q = jax.random.normal(KEY, (1, 2, 64, 8))
        st = kmeans.init_kmeans(KEY, 2, 8, 8)
        out = routing.routed_attention(
            q, None, q, st, RoutingConfig(num_clusters=8), return_attn=True)
        assert out.q_idx.shape == (1, 2, 8, 8)      # k=8, w=64/8=8

    def test_scatter_modes(self):
        q = jax.random.normal(KEY, (1, 2, 64, 8))
        st = kmeans.init_kmeans(KEY, 2, 4, 8)
        for mode in ("mean", "last"):
            out = routing.routed_attention(
                q, None, q, st,
                RoutingConfig(num_clusters=4, scatter_mode=mode))
            assert bool(jnp.isfinite(out.out).all())


class TestKMeans:
    def test_normalize_routing_norm(self):
        x = jax.random.normal(KEY, (4, 2, 32, 16)) * 5 + 3
        r = kmeans.normalize_routing(x)
        norms = jnp.linalg.norm(r, axis=-1)
        assert float(jnp.abs(norms - jnp.sqrt(16)).max()) < 1e-2

    def test_ema_pulls_centroids_toward_data(self):
        """k-means objective improves: average best-centroid affinity of
        *clusterable* data rises after EMA updates on that data."""
        import numpy as np
        rng = np.random.RandomState(0)
        centers = rng.randn(2, 8) * 3
        pts = np.stack([centers[i % 2] + rng.randn(8) * 0.1
                        for i in range(64)])
        r = kmeans.normalize_routing(
            jnp.asarray(pts, jnp.float32).reshape(1, 1, 64, 8))
        st = kmeans.init_kmeans(jax.random.PRNGKey(4), 1, 2, 8)
        st2 = st
        for _ in range(200):
            st2 = kmeans.ema_update(st2, r, decay=0.8)
        aff0 = float(kmeans.cluster_scores(r, st.mu).max(-1).mean())
        aff1 = float(kmeans.cluster_scores(r, st2.mu).max(-1).mean())
        assert aff1 > aff0 + 0.5, (aff0, aff1)

    def test_padding_excluded_from_update(self):
        st = kmeans.init_kmeans(KEY, 1, 2, 8)
        r = kmeans.normalize_routing(jax.random.normal(KEY, (2, 1, 16, 8)))
        pm = jnp.zeros((2, 16), bool)           # everything is padding
        st2 = kmeans.ema_update(st, r, mask=pm)
        assert float(jnp.abs(st2.mu - st.mu).max()) == 0.0

    def test_empty_cluster_keeps_centroid(self):
        st = kmeans.init_kmeans(KEY, 1, 4, 8)
        # all data close to centroid 0 => clusters 1..3 unchanged
        r = jnp.broadcast_to(st.mu[0, 0][None, None, None, :], (1, 1, 32, 8))
        st2 = kmeans.ema_update(st, r, decay=0.5)
        assert float(jnp.abs(st2.mu[0, 1:] - st.mu[0, 1:]).max()) == 0.0
        assert float(jnp.abs(st2.mu[0, 0] - st.mu[0, 0]).max()) > 0.0


class TestSegmentedRouting:
    """Beyond-paper shard-local routing (RoutingConfig.segments)."""

    def test_equals_per_segment_global(self):
        B, H, N, dh, ns = 2, 4, 256, 32, 4
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, N, dh))
        v = jax.random.normal(ks[1], (B, H, N, dh))
        st = kmeans.init_kmeans(ks[2], H, 4, dh)
        o_seg = routing.routed_attention(
            q, None, v, st, RoutingConfig(num_clusters=4, segments=ns)).out
        outs = []
        for s in range(ns):
            sl = slice(s * (N // ns), (s + 1) * (N // ns))
            pos = jnp.broadcast_to(
                jnp.arange(sl.start, sl.stop, dtype=jnp.int32),
                (B, N // ns))
            outs.append(routing.routed_attention(
                q[:, :, sl], None, v[:, :, sl], st,
                RoutingConfig(num_clusters=4), positions=pos).out)
        assert float(jnp.abs(o_seg - jnp.concatenate(outs, 2)).max()) < 1e-6

    def test_falls_back_when_indivisible(self):
        q = jax.random.normal(KEY, (1, 2, 60, 8))     # 60 % 4 == 0 but
        st = kmeans.init_kmeans(KEY, 2, 4, 8)         # 60/8 segs < k
        out = routing.routed_attention(
            q, None, q, st, RoutingConfig(num_clusters=4, segments=8))
        assert out.out.shape == (1, 2, 60, 8)

    def test_centroids_shared_and_updated(self):
        q = jax.random.normal(KEY, (1, 2, 128, 8))
        st = kmeans.init_kmeans(KEY, 2, 4, 8)
        out = routing.routed_attention(
            q, None, q, st, RoutingConfig(num_clusters=4, segments=4))
        assert out.state.mu.shape == st.mu.shape
        assert float(jnp.abs(out.state.mu - st.mu).max()) > 0
