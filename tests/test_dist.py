"""Distribution tests that need >1 device: spawned as subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single-device view (required by the smoke tests)."""
import pytest

from conftest import run_forced_devices as _run

pytest.importorskip(
    "repro.dist", reason="repro.dist is not part of this build")

pytestmark = pytest.mark.slow        # spawns 8-device subprocesses


def test_sharded_train_step_matches_single_device():
    _run("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ModelConfig, RoutingConfig, RunConfig, TrainConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.dist import sharding as shd
from repro.data.synthetic import SyntheticLoader

cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=64, attention="local+routing",
                  routing=RoutingConfig(num_clusters=4, local_window=16),
                  dtype="float32")
run = RunConfig(model=cfg, train=TrainConfig(global_batch=8, seq_len=64,
                lr=1e-3, schedule="const", warmup_steps=1))
ts = init_train_state(run, jax.random.PRNGKey(0))
b = next(iter(SyntheticLoader("markov", 64, 8, 64)))
b = {k: jnp.asarray(v) for k, v in b.items()}

# single device reference
ts1, m1 = jax.jit(make_train_step(run))(jax.tree.map(lambda x: x, ts), b)

# 2x4 mesh, full production sharding rules
mesh = jax.make_mesh((2, 4), ("data", "model"))
ts_spec = shd.train_state_sharding(mesh, jax.eval_shape(lambda: ts))
b_spec = shd.batch_sharding(mesh, b)
fn = make_train_step(run, constrain_fn=shd.make_constrain_fn(mesh, True))
with mesh:
    ts_sh = jax.device_put(ts, ts_spec)
    b_sh = jax.device_put(b, b_spec)
    ts2, m2 = jax.jit(fn, in_shardings=(ts_spec, b_spec),
                      donate_argnums=(0,))(ts_sh, b_sh)
d = abs(float(m1["loss"]) - float(m2["loss"]))
assert d < 1e-4, f"loss mismatch {d}"
import numpy as np
pd = max(float(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(bb, jnp.float32)).max())
         for a, bb in zip(jax.tree.leaves(ts1.params), jax.tree.leaves(ts2.params)))
assert pd < 5e-4, f"param mismatch {pd}"
print("sharded == single-device OK", d, pd)
""")


def test_int8_wire_allreduce():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.dist.compression import int8_psum_mean
import functools

mesh = jax.make_mesh((8,), ("data",))
# per-device distinct gradients: global (8, D) with rows = device shards
g = jnp.asarray(np.random.RandomState(0).randn(8, 4096).astype(np.float32))

@functools.partial(shard_map, mesh=mesh, in_specs=P("data", None),
                   out_specs=P("data", None), check_rep=False)
def mean_grad(x):
    return int8_psum_mean(x[0], "data")[None]

out = jax.jit(mean_grad)(g)
ref = jnp.mean(g, axis=0)
err = float(jnp.abs(out[0] - ref).max()) / float(jnp.abs(ref).max())
assert err < 0.02, f"int8 allreduce error {err}"

# wire format: the all_to_all / all_gather payloads must be s8
txt = jax.jit(mean_grad).lower(g).compile().as_text()
assert "s8[" in txt, "expected int8 collective payloads in HLO"
fp32_coll = [l for l in txt.splitlines()
             if ("all-to-all" in l or "all-gather" in l) and "f32[8,4096]" in l]
assert not fp32_coll, "full fp32 tensor went over the wire"
print("int8 wire allreduce OK, rel err", err)
""")


def test_elastic_reshard_across_meshes(tmp_path):
    _run(f"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt.checkpoint import CheckpointManager

mgr = CheckpointManager({str(tmp_path)!r})
state = {{"w": jnp.arange(64.0).reshape(8, 8)}}
mesh8 = jax.make_mesh((8,), ("data",))
sh8 = {{"w": NamedSharding(mesh8, P("data", None))}}
state8 = jax.device_put(state, sh8)
mgr.save(1, state8)

# restore onto a *different* mesh shape (elastic scale-down to 4x2 tp)
mesh42 = jax.make_mesh((4, 2), ("data", "model"))
sh42 = {{"w": NamedSharding(mesh42, P("data", "model"))}}
restored, _ = mgr.restore(state, shardings=sh42)
assert restored["w"].sharding == sh42["w"]
assert float(jnp.abs(restored["w"] - state["w"]).max()) == 0.0
print("elastic reshard OK")
""")


def test_dryrun_builders_small_mesh():
    """The exact dryrun builder path (shardings, eval_shape, lower+compile)
    on an 8-device mesh with a reduced config."""
    _run("""
import jax, functools
from repro.configs import reduced_config
from repro.configs.base import ShapeCell, RunConfig, TrainConfig
from repro.dist import sharding as shd
from repro.launch import dryrun as dr

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = reduced_config("granite-8b")
cell = ShapeCell("tiny_train", 64, 8, "train")
with mesh:
    jfn, args = dr.build_train("granite-8b", cfg, cell, mesh)
    compiled = jfn.lower(*args).compile()
rec = dr.analyze(compiled)
assert rec["flops_per_device"] > 0
assert rec["peak_device_bytes"] > 0
cell_d = ShapeCell("tiny_decode", 64, 8, "decode")
with mesh:
    jfn, args = dr.build_decode("granite-8b", cfg, cell_d, mesh)
    compiled = jfn.lower(*args).compile()
rec2 = dr.analyze(compiled)
assert rec2["peak_device_bytes"] > 0
print("dryrun builders OK:", rec["collectives"]["total_bytes"], rec2["collectives"]["total_bytes"])
""")


def test_collective_bytes_parser():
    from repro.launch import dryrun as dr
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[2,128]{1,0} %x), dimensions={0}
  %ar = bf16[64]{0} all-reduce(bf16[64]{0} %y), to_apply=%add
  %a2a.1 = (s8[8,4]{1,0}, s8[8,4]{1,0}) all-to-all(s8[8,4]{1,0} %a, s8[8,4]{1,0} %b)
  %rs = f32[4,32]{1,0} reduce-scatter(f32[32,32]{1,0} %z), dimensions={0}
  %notacoll = f32[2,2]{1,0} add(f32[2,2] %p, f32[2,2] %q)
"""
    out = dr.collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 128 * 4
    assert out["all-reduce"]["bytes"] == 64 * 2
    assert out["all-to-all"]["bytes"] == 2 * 8 * 4
    assert out["reduce-scatter"]["bytes"] == 4 * 32 * 4
    assert out["total_bytes"] == sum(
        out[k]["bytes"] for k in ("all-gather", "all-reduce", "all-to-all",
                                  "reduce-scatter", "collective-permute"))


def test_cell_status_matrix():
    from repro.launch import dryrun as dr
    assert dr.cell_status("hubert-xlarge", "decode_32k", "native") \
        == "skip_encoder_no_decode"
    assert dr.cell_status("granite-8b", "long_500k", "native").startswith(
        "skip_native_quadratic")
    assert dr.cell_status("granite-8b", "long_500k", "routing") == "run"
    assert dr.cell_status("mamba2-780m", "long_500k", "native") == "run"
    assert dr.cell_status("recurrentgemma-9b", "long_500k", "native") == "run"
    assert dr.cell_status("mamba2-780m", "train_4k", "routing") \
        == "skip_routing_inapplicable_ssm"
