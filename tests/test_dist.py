"""Distribution tests that need >1 device: spawned as subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=N so the main pytest
process keeps its single-device view. N comes from the CI matrix
($REPRO_TEST_DEVICE_COUNT in {2, 8}, conftest.FORCED_DEVICES), so mesh
shapes inside the snippets are derived from len(jax.devices())."""
import pytest

from conftest import run_forced_devices as _run

pytest.importorskip(
    "repro.dist", reason="repro.dist is not part of this build")

pytestmark = pytest.mark.slow        # spawns multi-device subprocesses


def test_sharded_train_step_matches_single_device():
    _run("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ModelConfig, RoutingConfig, RunConfig, TrainConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.data.synthetic import SyntheticLoader

cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=64, attention="local+routing",
                  routing=RoutingConfig(num_clusters=4, local_window=16),
                  dtype="float32")
run = RunConfig(model=cfg, train=TrainConfig(global_batch=8, seq_len=64,
                lr=1e-3, schedule="const", warmup_steps=1))
ts = init_train_state(run, jax.random.PRNGKey(0))
b = next(iter(SyntheticLoader("markov", 64, 8, 64)))
b = {k: jnp.asarray(v) for k, v in b.items()}

# single device reference
ts1, m1 = jax.jit(make_train_step(run))(jax.tree.map(lambda x: x, ts), b)

# full production sharding rules on the largest (data, model) mesh that
# fits (2x4 on the 8-device lane, 1x2 on the 2-device lane), exercising
# fsdp sharding + the prefetch gather tagging alongside seq parallelism
mesh = make_host_mesh(2, 4)
ts_spec = shd.train_state_sharding(mesh, jax.eval_shape(lambda: ts),
                                   fsdp=True)
b_spec = shd.batch_sharding(mesh, b)
fn = make_train_step(run, constrain_fn=shd.make_constrain_fn(
    mesh, True, fsdp_prefetch=True))
with mesh:
    ts_sh = jax.device_put(ts, ts_spec)
    b_sh = jax.device_put(b, b_spec)
    ts2, m2 = jax.jit(fn, in_shardings=(ts_spec, b_spec),
                      donate_argnums=(0,))(ts_sh, b_sh)
d = abs(float(m1["loss"]) - float(m2["loss"]))
assert d < 1e-4, f"loss mismatch {d}"
import numpy as np
pd = max(float(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(bb, jnp.float32)).max())
         for a, bb in zip(jax.tree.leaves(ts1.params), jax.tree.leaves(ts2.params)))
assert pd < 5e-4, f"param mismatch {pd}"
print("sharded == single-device OK", dict(mesh.shape), d, pd)
""")


def test_int8_wire_allreduce():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.dist.compression import int8_psum_mean
import functools

n = len(jax.devices())
mesh = jax.make_mesh((n,), ("data",))
# per-device distinct gradients: global (n, D) with rows = device shards
g = jnp.asarray(np.random.RandomState(0).randn(n, 4096).astype(np.float32))

@functools.partial(shard_map, mesh=mesh, in_specs=P("data", None),
                   out_specs=P("data", None), check_rep=False)
def mean_grad(x):
    return int8_psum_mean(x[0], "data")[None]

out = jax.jit(mean_grad)(g)
ref = jnp.mean(g, axis=0)
err = float(jnp.abs(out[0] - ref).max()) / float(jnp.abs(ref).max())
assert err < 0.02, f"int8 allreduce error {err}"

# wire format: the all_to_all / all_gather payloads must be s8
txt = jax.jit(mean_grad).lower(g).compile().as_text()
assert "s8[" in txt, "expected int8 collective payloads in HLO"
fp32_coll = [l for l in txt.splitlines()
             if ("all-to-all" in l or "all-gather" in l)
             and f"f32[{n},4096]" in l]
assert not fp32_coll, "full fp32 tensor went over the wire"
print("int8 wire allreduce OK, rel err", err)
""")


def test_error_feedback_unbiased():
    """The EF residual makes the TIME-AVERAGED compressed mean converge
    to the exact fp32 mean, while the stateless int8 mean keeps its
    one-shot quantization bias forever; the residual stays bounded at
    ~one quantization step per element instead of accumulating."""
    _run("""
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.dist.compression import int8_ef_psum_mean, int8_psum_mean

n = len(jax.devices())
mesh = jax.make_mesh((n,), ("data",))
g = jnp.asarray(np.random.RandomState(0).randn(n, 4096).astype(np.float32))
true = jnp.mean(g, axis=0)

@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P("data", None), P("data", None)),
                   out_specs=(P("data", None), P("data", None)),
                   check_rep=False)
def ef_step(x, e):
    m, ne = int8_ef_psum_mean(x[0], e[0], "data")
    return m[None], ne[None]

@functools.partial(shard_map, mesh=mesh, in_specs=P("data", None),
                   out_specs=P("data", None), check_rep=False)
def plain(x):
    return int8_psum_mean(x[0], "data")[None]

T = 64
jf = jax.jit(ef_step)
e = jnp.zeros_like(g)
acc = jnp.zeros_like(true)
for _ in range(T):
    m, e = jf(g, e)
    acc = acc + m[0]
ef_err = float(jnp.linalg.norm(acc / T - true))
noef_err = float(jnp.linalg.norm(jax.jit(plain)(g)[0] - true))
assert ef_err < 0.3 * noef_err, (ef_err, noef_err)
assert float(jnp.abs(e).max()) < 0.3, "residual grew beyond a quant step"
print(f"error feedback OK: time-avg err {ef_err:.4f} vs "
      f"stateless {noef_err:.4f}, residual max {float(jnp.abs(e).max()):.4f}")
""")


def test_int8_ef_train_parity_and_wire():
    """The acceptance gate: 200 synthetic-LM train steps with
    grad_compression="int8_ef" land within 2% of the fp32 baseline's
    final loss, and the compiled train step's gradient exchange rides
    s8 collective payloads (fp32 collectives may carry only the
    1/128-sized quantization scales)."""
    _run("""
import re
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, RoutingConfig, RunConfig, TrainConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.data.synthetic import SyntheticLoader

cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=64, attention="local+routing",
                  routing=RoutingConfig(num_clusters=4, local_window=16),
                  dtype="float32")
def rc(comp):
    return RunConfig(model=cfg, train=TrainConfig(
        global_batch=8, seq_len=64, steps=200, lr=3e-3, schedule="const",
        warmup_steps=5, grad_compression=comp))

run_f, run_c = rc("none"), rc("int8_ef")
mesh = jax.make_mesh((len(jax.devices()),), ("data",))
ts_f = init_train_state(run_f, jax.random.PRNGKey(0))
ts_c = init_train_state(run_c, jax.random.PRNGKey(0), mesh=mesh)
step_f = jax.jit(make_train_step(run_f))
step_c = jax.jit(make_train_step(run_c, mesh=mesh))

# --- wire format: parse the collective INSTRUCTIONS' result dtypes ---
b0 = {k: jnp.asarray(v)
      for k, v in next(iter(SyntheticLoader("markov", 64, 8, 64))).items()}
txt = step_c.lower(ts_c, b0).compile().as_text()
pat = re.compile(r"=\\s*\\(?(\\w+)\\[([0-9,]*)\\][^=]*"
                 r"\\b(all-to-all|all-gather|reduce-scatter)\\(")
elems = {}
for line in txt.splitlines():
    m = pat.search(line)
    if m:
        dims = [int(d) for d in m.group(2).split(",") if d]
        n_el = int(np.prod(dims)) if dims else 1
        elems.setdefault(m.group(1), []).append(n_el)
assert "s8" in elems, f"no s8 collective payloads, got {sorted(elems)}"
s8_max = max(elems["s8"])
f32_max = max(elems.get("f32", [0]))
assert f32_max <= s8_max // 64, (
    f"fp32 collective payload {f32_max} elems vs s8 {s8_max}: "
    "gradient tensors must cross the wire as int8")

# --- 200-step parity: same data stream, fp32 vs compressed ---
def fit(step, ts):
    loader = SyntheticLoader("markov", 64, 8, 64)
    losses = []
    for _, b in zip(range(200), loader):
        ts, m = step(ts, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    return losses

lf = fit(step_f, ts_f)
lc = fit(step_c, ts_c)
f_end, c_end = float(np.mean(lf[-10:])), float(np.mean(lc[-10:]))
gap = abs(c_end - f_end) / f_end
assert gap < 0.02, f"loss gap {gap:.4f} (fp32 {f_end:.4f} vs int8_ef {c_end:.4f})"
print(f"int8_ef parity OK on {len(jax.devices())} devices: "
      f"fp32 {f_end:.4f} vs compressed {c_end:.4f} (gap {gap:.4%}), "
      f"s8 wire max {s8_max} elems, f32 max {f32_max}")
""")


def test_elastic_reshard_across_meshes(tmp_path):
    _run(f"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt.checkpoint import CheckpointManager
from repro.launch.mesh import make_host_mesh

n = len(jax.devices())
mgr = CheckpointManager({str(tmp_path)!r})
state = {{"w": jnp.arange(64.0).reshape(8, 8)}}
mesh_dp = jax.make_mesh((n,), ("data",))
sh_dp = {{"w": NamedSharding(mesh_dp, P("data" if 8 % n == 0 else None,
                                        None))}}
state_dp = jax.device_put(state, sh_dp)
mgr.save(1, state_dp)

# restore onto a *different* mesh shape (elastic reshard onto data x tp)
mesh2 = make_host_mesh(n // 2, 2)
sh2 = {{"w": NamedSharding(mesh2, P("data", "model"))}}
restored, _ = mgr.restore(state, shardings=sh2)
assert restored["w"].sharding == sh2["w"]
assert float(jnp.abs(restored["w"] - state["w"]).max()) == 0.0
print("elastic reshard OK", dict(mesh_dp.shape), "->", dict(mesh2.shape))
""")


def test_dryrun_builders_small_mesh():
    """The exact dryrun builder path (shardings, eval_shape, lower+compile)
    on a multi-device mesh with a reduced config."""
    _run("""
import jax, functools
from repro.configs import reduced_config
from repro.configs.base import ShapeCell, RunConfig, TrainConfig
from repro.dist import sharding as shd
from repro.launch import dryrun as dr
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(2, 4)
cfg = reduced_config("granite-8b")
cell = ShapeCell("tiny_train", 64, 8, "train")
with mesh:
    jfn, args = dr.build_train("granite-8b", cfg, cell, mesh)
    compiled = jfn.lower(*args).compile()
rec = dr.analyze(compiled)
assert rec["flops_per_device"] > 0
assert rec["peak_device_bytes"] > 0
cell_d = ShapeCell("tiny_decode", 64, 8, "decode")
with mesh:
    jfn, args = dr.build_decode("granite-8b", cfg, cell_d, mesh)
    compiled = jfn.lower(*args).compile()
rec2 = dr.analyze(compiled)
assert rec2["peak_device_bytes"] > 0
print("dryrun builders OK:", rec["collectives"]["total_bytes"], rec2["collectives"]["total_bytes"])
""")


def test_collective_bytes_parser():
    from repro.launch import dryrun as dr
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[2,128]{1,0} %x), dimensions={0}
  %ar = bf16[64]{0} all-reduce(bf16[64]{0} %y), to_apply=%add
  %a2a.1 = (s8[8,4]{1,0}, s8[8,4]{1,0}) all-to-all(s8[8,4]{1,0} %a, s8[8,4]{1,0} %b)
  %rs = f32[4,32]{1,0} reduce-scatter(f32[32,32]{1,0} %z), dimensions={0}
  %notacoll = f32[2,2]{1,0} add(f32[2,2] %p, f32[2,2] %q)
"""
    out = dr.collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 128 * 4
    assert out["all-reduce"]["bytes"] == 64 * 2
    assert out["all-to-all"]["bytes"] == 2 * 8 * 4
    assert out["reduce-scatter"]["bytes"] == 4 * 32 * 4
    assert out["total_bytes"] == sum(
        out[k]["bytes"] for k in ("all-gather", "all-reduce", "all-to-all",
                                  "reduce-scatter", "collective-permute"))


def test_cell_status_matrix():
    from repro.launch import dryrun as dr
    assert dr.cell_status("hubert-xlarge", "decode_32k", "native") \
        == "skip_encoder_no_decode"
    assert dr.cell_status("granite-8b", "long_500k", "native").startswith(
        "skip_native_quadratic")
    assert dr.cell_status("granite-8b", "long_500k", "routing") == "run"
    assert dr.cell_status("mamba2-780m", "long_500k", "native") == "run"
    assert dr.cell_status("recurrentgemma-9b", "long_500k", "native") == "run"
    assert dr.cell_status("mamba2-780m", "train_4k", "routing") \
        == "skip_routing_inapplicable_ssm"
