"""Observability subsystem: metrics core, JSONL schema, routing-health
invariants, the stats-off no-op guarantee, and the telemetry smokes.

The load-bearing test is the HLO byte-identity pair: RoutingConfig.stats
is a *static* python conditional, so stats=False must compile the exact
program the field's default compiles — telemetry that is off can never
perturb numerics, layouts, or fusion decisions.
"""
from __future__ import annotations

import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                TrainConfig)
from repro.core.kmeans import KMeansState, init_kmeans
from repro.core.routing import routed_attention
from repro.obs import (Counter, Gauge, Histogram, JsonlSink, Registry,
                       SCHEMA_VERSION, StepSeries)
from repro.obs.routing_stats import RoutingStats, pages_health, summarize
from repro.obs.schema import SchemaError, validate_jsonl, validate_record
from repro.obs.trace import profile, span


# ---------------------------------------------------------------------------
# metrics core
# ---------------------------------------------------------------------------
def test_registry_instruments():
    reg = Registry()
    reg.counter("steps").inc()
    reg.counter("steps").inc(2)
    reg.gauge("lr").set(3e-4)
    h = reg.histogram("lat")
    for v in (4.0, 1.0, 2.0, 3.0):
        h.record(v)
    s = reg.summary()
    assert s["steps"] == 3.0
    assert s["lr"] == pytest.approx(3e-4)
    assert s["lat.count"] == 4 and s["lat.min"] == 1.0 and s["lat.max"] == 4.0
    # linear interpolation on the sorted sample, numpy semantics
    assert h.percentile(50) == pytest.approx(
        float(np.percentile([1, 2, 3, 4], 50)))
    assert h.percentile(90) == pytest.approx(
        float(np.percentile([1, 2, 3, 4], 90)))
    csv = reg.to_csv()
    assert csv.startswith("name,value\n") and "steps,3.0" in csv


def test_registry_type_mismatch_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_histogram_empty_and_singleton():
    h = Histogram("h")
    assert h.percentile(50) is None
    assert h.summary()["count"] == 0
    h.record(7.0)
    assert h.percentile(99) == 7.0


# ---------------------------------------------------------------------------
# JSONL sink + schema
# ---------------------------------------------------------------------------
def test_jsonl_roundtrip_and_validation(tmp_path):
    path = str(tmp_path / "obs.jsonl")
    with JsonlSink(path, source="test") as sink:
        sink.emit("train_step", metrics={"loss": jnp.float32(1.5),
                                         "vec": jnp.arange(3.0)}, step=0)
        sink.emit("engine_tick", metrics={"active_slots": 2.0}, step=1,
                  uid=7)
    assert validate_jsonl(path) == 2
    recs = [json.loads(ln) for ln in open(path)]
    assert recs[0]["v"] == SCHEMA_VERSION
    assert recs[0]["metrics"]["loss"] == 1.5          # device -> host float
    assert recs[0]["metrics"]["vec"] == [0.0, 1.0, 2.0]
    assert recs[1]["uid"] == 7


def test_schema_rejects_tampered_lines(tmp_path):
    good = {"v": SCHEMA_VERSION, "kind": "x", "t": 0.0}
    validate_record(good)
    for bad in ({**good, "v": 99},            # wrong schema version
                {**good, "kind": ""},         # empty kind
                {**good, "t": float("nan")},  # non-finite timestamp
                {**good, "step": -1},
                {**good, "metrics": {"a": float("inf")}}):
        with pytest.raises(SchemaError):
            validate_record(bad)
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps(good) + "\n")
        f.write("not json\n")
    with pytest.raises(SchemaError):
        validate_jsonl(path)


def test_schema_whole_file_json_mode(tmp_path):
    """CI's docs-check runs the CLI over committed bench records: whole
    .json files are held to strict finite JSON (bare NaN rejected even
    though json.loads accepts it)."""
    from repro.obs.schema import main as schema_main
    from repro.obs.schema import validate_json_file
    ok = tmp_path / "BENCH_x.json"
    ok.write_text(json.dumps({"speedup": 2.5, "backends":
                              {"routing": "pallas_paged"}, "note": None}))
    validate_json_file(str(ok))
    assert schema_main([str(ok)]) == 0
    for payload in ('{"x": NaN}',            # json.loads-accepted, invalid
                    '{"x": Infinity}',
                    '{"x": 1,}'):            # not JSON at all
        bad = tmp_path / "bad.json"
        bad.write_text(payload)
        with pytest.raises(SchemaError):
            validate_json_file(str(bad))
        assert schema_main([str(bad)]) == 1


def test_committed_records_and_docs_pass_checks():
    """The repo's own committed artifacts/docs satisfy the CI docs-check
    step (anchor linter + whole-file record validation)."""
    import importlib.util
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    from repro.obs.schema import validate_json_file
    records = ([root / "BENCH_routing.json"]
               + sorted((root / "benchmarks").glob("*smoke*.json")))
    assert records
    for rec in records:
        validate_json_file(str(rec))
    spec = importlib.util.spec_from_file_location(
        "check_docs", root / "tools" / "check_docs.py")
    check_docs = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(check_docs)
    assert check_docs.check(root) == []


def test_step_series_history(tmp_path):
    path = str(tmp_path / "s.jsonl")
    series = StepSeries(sink=JsonlSink(path), kind="train_step")
    series.record(0, {"loss": jnp.float32(2.0)})
    series.record(1, {"loss": jnp.float32(1.0)})
    assert [r["loss"] for r in series.history] == [2.0, 1.0]
    assert validate_jsonl(path) == 2


# ---------------------------------------------------------------------------
# routing-health invariants (full routed_attention, stats on)
# ---------------------------------------------------------------------------
def _routing_inputs(B=2, H=2, N=128, dh=32, kc=4, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, N, dh))
    v = jax.random.normal(ks[1], (B, H, N, dh))
    st = init_kmeans(ks[2], H, kc, dh)
    return q, v, st


def test_routing_stats_invariants():
    B, H, N, kc = 2, 2, 128, 4
    q, v, st = _routing_inputs(B=B, H=H, N=N, kc=kc)
    cfg = RoutingConfig(num_clusters=kc, stats=True)
    out = routed_attention(q, None, v, st, cfg, update_state=True)
    s = jax.device_get(out.stats)
    assert isinstance(out.stats, RoutingStats)
    # occupancy: batch-mean token counts sum to N per head (no padding)
    assert s.occupancy.shape == (H, kc)
    np.testing.assert_allclose(s.occupancy.sum(-1), N, rtol=1e-5)
    # dead = centroids with zero occupancy
    np.testing.assert_allclose(s.dead, (s.occupancy <= 0).sum(-1), atol=1e-5)
    assert np.all(s.entropy >= -1e-5)
    assert np.all(s.entropy <= math.log(kc) + 1e-5)
    assert np.all((s.mismatch >= -1e-5) & (s.mismatch <= 1 + 1e-5))
    assert np.all((s.recall >= -1e-5) & (s.recall <= 1 + 1e-5))
    assert np.all(s.drift > 0)          # EMA moved the centroids
    # update_state=False freezes the centroids -> zero drift
    out2 = routed_attention(q, None, v, st, cfg, update_state=False)
    np.testing.assert_allclose(jax.device_get(out2.stats.drift), 0.0,
                               atol=1e-7)


def test_routing_stats_padding_excluded():
    B, H, N, kc = 2, 2, 128, 4
    q, v, st = _routing_inputs(B=B, H=H, N=N, kc=kc)
    pad = jnp.arange(N)[None, :] < (N // 2)
    pad = jnp.broadcast_to(pad, (B, N))
    cfg = RoutingConfig(num_clusters=kc, stats=True)
    out = routed_attention(q, None, v, st, cfg, pad_mask=pad,
                           update_state=False)
    s = jax.device_get(out.stats)
    np.testing.assert_allclose(s.occupancy.sum(-1), N // 2, rtol=1e-5)


def test_routing_stats_detect_collapse():
    """All tokens routed to one centroid -> entropy ~0, dead = k-1."""
    B, H, N, dh, kc = 1, 1, 64, 32, 4
    vec = jnp.linspace(-1.0, 1.0, dh)            # fixed routing direction
    q = jnp.broadcast_to(vec, (B, H, N, dh))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, H, N, dh))
    from repro.core.kmeans import normalize_routing
    r = normalize_routing(vec[None])[0]           # what routing sees
    mu = jnp.stack([r] + [-r] * (kc - 1))[None]   # (H,kc,dh): mu[0] wins
    st = KMeansState(mu=mu.astype(jnp.float32))
    cfg = RoutingConfig(num_clusters=kc, stats=True)
    out = routed_attention(q, None, v, st, cfg, update_state=False)
    s = jax.device_get(out.stats)
    assert float(s.entropy[0]) == pytest.approx(0.0, abs=1e-5)
    assert float(s.dead[0]) == kc - 1
    assert float(s.occupancy[0, 0]) == N


def test_summarize_folds_tree():
    q, v, st = _routing_inputs()
    cfg = RoutingConfig(num_clusters=4, stats=True)
    stats = routed_attention(q, None, v, st, cfg, update_state=False).stats
    summ = summarize([{"0": stats}, {}])
    assert set(summ) == {f"routing/{f}" for f in
                         ("entropy", "dead", "drift", "mismatch", "recall")}
    assert float(summ["routing/entropy"]) == pytest.approx(
        float(jnp.mean(stats.entropy)), rel=1e-6)
    assert summarize([{}, {}]) == {}


def test_pages_health_reads_rlen():
    rlen = np.zeros((1, 2, 1, 4), np.int32)     # (G,B,Hr,kc)
    rlen[0, 0, 0] = [10, 10, 10, 10]            # balanced slot
    rlen[0, 1, 0] = [40, 0, 0, 0]               # collapsed slot
    h = pages_health([{"rlen": rlen}])
    assert h["routing/entropy"] == pytest.approx(
        (math.log(4) + 0.0) / 2, abs=1e-6)
    assert h["routing/dead"] == pytest.approx(1.5)
    # active mask drops the collapsed slot
    h0 = pages_health([{"rlen": rlen}], active=np.array([True, False]))
    assert h0["routing/dead"] == 0.0
    assert pages_health([{"k": np.zeros((1, 2, 1, 4))}]) is None
    assert pages_health([{"rlen": rlen}],
                        active=np.array([False, False])) is None


# ---------------------------------------------------------------------------
# stats off must be a true no-op: byte-identical HLO
# ---------------------------------------------------------------------------
def test_stats_off_hlo_identical_routed_attention():
    q, v, st = _routing_inputs()
    # lower the FULL output pytree: with stats off the stats slot is a
    # python None, so the traced program must be the default program to
    # the byte; returning only .out would let trace-time DCE hide a
    # stats computation that actually changed the jaxpr
    def lower(cfg):
        return jax.jit(lambda q, v: routed_attention(
            q, None, v, st, cfg, update_state=True)).lower(q, v).as_text()
    default = lower(RoutingConfig(num_clusters=4))
    off = lower(RoutingConfig(num_clusters=4, stats=False))
    on = lower(RoutingConfig(num_clusters=4, stats=True))
    assert off == default
    assert on != off                    # positive control: the knob acts


def _tiny_run(stats: bool) -> RunConfig:
    cfg = ModelConfig(name="obs-test", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, attention="local+routing",
                      routing=RoutingConfig(num_clusters=4, local_window=16,
                                            stats=stats),
                      dtype="float32")
    return RunConfig(model=cfg, train=TrainConfig(global_batch=2, seq_len=64,
                                                  steps=5, lr=1e-3))


def test_stats_off_hlo_identical_train_step():
    from repro.train.train_step import init_train_state, make_train_step
    batch = {"tokens": np.zeros((2, 64), np.int32)}
    state = init_train_state(_tiny_run(False), jax.random.PRNGKey(0))
    def lower(run):
        return jax.jit(make_train_step(run)).lower(state, batch).as_text()
    off, on = lower(_tiny_run(False)), lower(_tiny_run(True))
    assert off == lower(_tiny_run(False))       # deterministic lowering
    assert on != off


def test_train_step_metrics_carry_routing_stats():
    from repro.train.train_step import init_train_state, make_train_step
    run = _tiny_run(True)
    state = init_train_state(run, jax.random.PRNGKey(0))
    batch = {"tokens": np.random.RandomState(0).randint(
        0, 256, size=(2, 64)).astype(np.int32)}
    _, metrics = jax.jit(make_train_step(run))(state, batch)
    m = jax.device_get(metrics)
    assert 0.0 <= float(m["routing/entropy"]) <= math.log(4) + 1e-5
    assert "rt/0/0/entropy" in m                # per-layer detail
    # stats-off keeps the metric dict exactly as before
    state0 = init_train_state(_tiny_run(False), jax.random.PRNGKey(0))
    _, m0 = jax.jit(make_train_step(_tiny_run(False)))(state0, batch)
    assert not any(k.startswith(("routing/", "rt/")) for k in m0)


# ---------------------------------------------------------------------------
# end-to-end smokes: trainer + engine telemetry as schema-valid JSONL
# ---------------------------------------------------------------------------
def test_trainer_obs_jsonl(tmp_path):
    from repro.data.synthetic import SyntheticLoader
    from repro.train.trainer import Trainer
    path = str(tmp_path / "train.jsonl")
    run = _tiny_run(True)
    tr = Trainer(run, SyntheticLoader("markov", 256, 2, 64), obs_jsonl=path)
    out = tr.fit(3)
    tr.close()
    assert out["steps"] == 3
    assert len(tr.metrics_history) == 3
    assert validate_jsonl(path) == 3
    rec = json.loads(open(path).readline())
    assert rec["kind"] == "train_step" and rec["source"] == "trainer"
    assert 0.0 <= rec["metrics"]["routing/entropy"] <= math.log(4) + 1e-5
    assert rec["metrics"]["step_time_s"] > 0
    assert tr.obs.histogram("train/step_time_s").count == 3


def test_engine_obs_jsonl(tmp_path):
    from repro.models.model import init_model
    from repro.serve.engine import InferenceEngine, Request
    cfg = _tiny_run(False).model
    params, kstate = init_model(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "engine.jsonl")
    eng = InferenceEngine(cfg, params, kstate, max_slots=2, max_len=32,
                          obs_jsonl=path, routing_stats=True)
    eng.run([Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=4),
             Request(uid=1, prompt=[5, 6, 7, 8, 9], max_new_tokens=3)])
    summ = eng.metrics.summary()
    eng.close()
    assert validate_jsonl(path) >= 3
    kinds = [json.loads(ln)["kind"] for ln in open(path)]
    assert kinds.count("engine_prefill") == 2
    assert "engine_tick" in kinds and kinds[-1] == "engine_summary"
    pre = next(json.loads(ln) for ln in open(path)
               if json.loads(ln)["kind"] == "engine_prefill")
    assert 0.0 <= pre["metrics"]["routing/entropy"] <= math.log(4) + 1e-5
    tick = next((json.loads(ln) for ln in open(path)
                 if json.loads(ln)["kind"] == "engine_tick"
                 and "routing/entropy" in json.loads(ln)["metrics"]), None)
    assert tick is not None             # pages health on active slots
    assert tick["metrics"]["routing/drift"] == 0.0  # frozen centroids
    # percentile satellites ride on the same histograms
    assert "ttft_p50_s" in summ and "decode_step_p99_s" in summ
    assert summ["ttft_p50_s"] <= summ["ttft_p99_s"]


def test_engine_stats_do_not_change_outputs():
    """routing_stats is pure telemetry: identical greedy outputs."""
    from repro.models.model import init_model
    from repro.serve.engine import InferenceEngine, Request
    cfg = _tiny_run(False).model
    params, kstate = init_model(cfg, jax.random.PRNGKey(0))
    reqs = lambda: [Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=4)]
    out_plain = InferenceEngine(cfg, params, kstate, max_slots=1,
                                max_len=16).run(reqs())
    out_stats = InferenceEngine(cfg, params, kstate, max_slots=1,
                                max_len=16, routing_stats=True).run(reqs())
    assert out_plain == out_stats


# ---------------------------------------------------------------------------
# trace spans + profiler capture
# ---------------------------------------------------------------------------
def test_span_names_hlo_and_nests():
    def f(x):
        with span("test/outer"):
            with span("test/inner"):
                return x * 2.0
    # named_scope lands in op metadata, which the compiled module prints
    hlo = jax.jit(f).lower(jnp.ones((4,))).compile().as_text()
    assert "test/outer" in hlo and "inner" in hlo
    assert float(f(jnp.asarray(2.0))) == 4.0    # eager path works too


def test_profile_writes_capture(tmp_path):
    d = str(tmp_path / "prof")
    with profile(d):
        jax.block_until_ready(jnp.ones((8, 8)) @ jnp.ones((8, 8)))
    found = [os.path.join(r, f) for r, _, fs in os.walk(d) for f in fs]
    assert found, "profiler capture wrote no files"
    with profile(None):                 # falsy dir -> no-op
        pass
    assert not os.path.exists(str(tmp_path / "none"))


def test_schema_cli(tmp_path, capsys):
    from repro.obs.schema import main as schema_main
    path = str(tmp_path / "ok.jsonl")
    with JsonlSink(path, source="cli") as sink:
        sink.emit("x", metrics={"a": 1.0})
    assert schema_main([path]) == 0
    assert "1 records ok" in capsys.readouterr().out
    bad = str(tmp_path / "bad.jsonl")
    open(bad, "w").write("{}\n")
    assert schema_main([bad]) == 1
