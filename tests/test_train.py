"""Training substrate: loss decreases, grad-accum equivalence, optimizers,
schedules, the paper-mechanism check (routing beats random routing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tree_maxdiff
from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                TrainConfig, with_overrides)
from repro.data.synthetic import SyntheticLoader, copy_batch, markov_batch
from repro.optim import adafactor, adam, make_schedule
from repro.train.train_step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _small_run(attention="local+routing", steps=25, **kw):
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=64, attention=attention,
                      routing=RoutingConfig(num_clusters=4, local_window=16),
                      dtype="float32")
    tc = dict(global_batch=8, seq_len=64, steps=steps, lr=3e-3,
              schedule="const", warmup_steps=5)
    tc.update(kw)
    return RunConfig(model=cfg, train=TrainConfig(**tc))


def _fit(run, task="markov"):
    ts = init_train_state(run, KEY)
    step = jax.jit(make_train_step(run))
    loader = SyntheticLoader(task, run.model.vocab_size,
                             run.train.global_batch, run.train.seq_len)
    losses = []
    for _, b in zip(range(run.train.steps), loader):
        ts, m = step(ts, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    return losses, ts


@pytest.mark.slow
def test_loss_decreases_routing_transformer():
    losses, _ = _fit(_small_run())
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


@pytest.mark.slow
def test_grad_accum_equivalence():
    """A=2 accumulation == A=1 on the same global batch (fp32, tight tol)."""
    r1 = _small_run(steps=1, grad_accum=1, attention="full")
    r2 = _small_run(steps=1, grad_accum=2, attention="full")
    ts1 = init_train_state(r1, KEY)
    ts2 = jax.tree.map(lambda x: x, ts1)
    b = next(iter(SyntheticLoader("markov", 64, 8, 64)))
    b = {k: jnp.asarray(v) for k, v in b.items()}
    ts1, m1 = jax.jit(make_train_step(r1))(ts1, b)
    ts2, m2 = jax.jit(make_train_step(r2))(ts2, b)
    # losses averaged over microbatches differ only by masking order; the
    # parameter update must agree to numerical tolerance
    assert tree_maxdiff(ts1.params, ts2.params) < 5e-5


@pytest.mark.slow
def test_remat_matches_no_remat():
    r1 = _small_run(steps=1, remat="none", attention="full")
    r2 = _small_run(steps=1, remat="full", attention="full")
    ts = init_train_state(r1, KEY)
    b = {k: jnp.asarray(v) for k, v in
         next(iter(SyntheticLoader("markov", 64, 8, 64))).items()}
    o1, m1 = jax.jit(make_train_step(r1))(jax.tree.map(lambda x: x, ts), b)
    o2, m2 = jax.jit(make_train_step(r2))(ts, b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    assert tree_maxdiff(o1.params, o2.params) < 5e-5


def test_adam_quadratic_convergence():
    init, upd = adam(0.9, 0.999, 1e-8)
    w = {"x": jnp.array([4.0, -2.0])}
    st = init(w)
    for _ in range(200):
        w, st = upd({"x": 2 * w["x"]}, st, w, 0.1)
    assert float(jnp.abs(w["x"]).max()) < 1e-2


def test_adafactor_factored_stats_shapes():
    init, upd = adafactor()
    w = {"m": jnp.ones((8, 16)), "v": jnp.ones((4,))}
    st = init(w)
    assert st["stats"]["m"]["vr"].shape == (8,)
    assert st["stats"]["m"]["vc"].shape == (16,)
    assert st["stats"]["v"]["v"].shape == (4,)
    w2, st2 = upd(jax.tree.map(jnp.ones_like, w), st, w, 0.01)
    assert tree_maxdiff(w, w2) > 0


def test_schedules_shapes():
    tc = TrainConfig(lr=1e-3, warmup_steps=10, schedule="vaswani")
    for name in ("vaswani", "linear_warmup_rsqrt", "const"):
        fn = make_schedule(with_overrides(tc, schedule=name), 64)
        vals = [float(fn(jnp.asarray(s))) for s in [1, 5, 10, 100, 1000]]
        assert all(v > 0 for v in vals)
        assert vals[-1] <= vals[2] * 1.01 or name == "const"


def test_grad_clipping_caps_norm():
    from repro.train.train_step import clip_by_global_norm, global_norm
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) > 100.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_copy_task_routing_beats_random_mechanism():
    """Paper Table 1 mechanism: content-based routing (MIPS) selects
    higher-dot-product pairs than random assignment."""
    from repro.core.kmeans import init_kmeans, normalize_routing
    from repro.core.routing import balanced_topk, cluster_scores
    rng = np.random.RandomState(0)
    # data with planted cluster structure
    centers = rng.randn(4, 16) * 2
    x = jnp.asarray(np.concatenate(
        [centers[i % 4] + rng.randn(16) * 0.2 for i in range(64)]
    ).reshape(1, 1, 64, 16), dtype=jnp.float32)
    r = normalize_routing(x)
    st = init_kmeans(jax.random.PRNGKey(1), 1, 4, 16)
    from repro.core.kmeans import ema_update
    for _ in range(30):
        st = ema_update(st, r, decay=0.7)
    idx = balanced_topk(cluster_scores(r, st.mu), 16)
    # mean intra-cluster dot of routed pairs vs random pairs
    gathered = jnp.take_along_axis(
        jnp.broadcast_to(r, (1, 1, 64, 16)), idx.reshape(1, 1, -1, 1), 2
    ).reshape(1, 1, 4, 16, 16)
    intra = jnp.einsum("bhkwd,bhkud->bhkwu", gathered, gathered).mean()
    rnd = jax.random.permutation(jax.random.PRNGKey(2), 64)[:16 * 4]
    rg = r[:, :, rnd].reshape(1, 1, 4, 16, 16)
    rand_intra = jnp.einsum("bhkwd,bhkud->bhkwu", rg, rg).mean()
    assert float(intra) > float(rand_intra) + 0.5


def test_grad_compression_validated_at_construction():
    """Bad grad_compression fails in TrainConfig.__init__, not as a
    KeyError minutes into a jitted train step."""
    from repro.configs.base import GRAD_COMPRESSION_MODES
    assert TrainConfig(grad_compression="int8_ef").grad_compression \
        == "int8_ef"
    with pytest.raises(ValueError, match="grad_compression"):
        TrainConfig(grad_compression="int4")
    with pytest.raises(ValueError):
        with_overrides(TrainConfig(), grad_compression="fp8")
    assert "none" in GRAD_COMPRESSION_MODES


def test_compressed_step_rejects_gspmd_hooks_and_bad_ef():
    """The shard_map path can't honor GSPMD hooks (silently dropping
    them would no-op user intent), and an ef_state sized for a different
    device count must fail loudly, not get row-sliced into wrong EF
    bookkeeping."""
    run = _small_run(steps=1, grad_compression="int8_ef")
    with pytest.raises(ValueError, match="grad_transform"):
        make_train_step(run, grad_transform=lambda g: g)
    with pytest.raises(ValueError, match="constrain_fn"):
        make_train_step(run, constrain_fn=lambda x: x)
    ts = init_train_state(run, KEY)
    bad = ts._replace(ef_state=jax.tree.map(
        lambda e: jnp.zeros((3,) + e.shape[1:], e.dtype), ts.ef_state))
    b = next(iter(SyntheticLoader("markov", 64, 8, 64)))
    b = {k: jnp.asarray(v) for k, v in b.items()}
    with pytest.raises(ValueError, match="device axis"):
        make_train_step(run)(bad, b)


def test_compressed_step_single_device_smoke():
    """grad_compression="int8_ef" on a 1-device mesh: the wire vanishes
    (identity passthrough in int8_ef_psum_mean), the step runs, and the
    residual stays exactly zero — laptops/CI pay no compression tax."""
    run = _small_run(steps=2, grad_compression="int8_ef")
    ts = init_train_state(run, KEY)
    assert ts.ef_state is not None
    step = jax.jit(make_train_step(run))
    b = next(iter(SyntheticLoader("markov", 64, 8, 64)))
    b = {k: jnp.asarray(v) for k, v in b.items()}
    ts, m = step(ts, b)
    assert np.isfinite(float(m["loss"]))
    assert all(float(jnp.abs(e).max()) == 0.0
               for e in jax.tree.leaves(ts.ef_state))


@pytest.mark.slow
def test_compressed_matches_plain_on_one_device():
    """On a 1-device data mesh the compressed variant must be the exact
    uncompressed computation (same grads, same update)."""
    r_plain = _small_run(steps=1, attention="full")
    r_comp = _small_run(steps=1, attention="full",
                        grad_compression="int8_ef")
    ts_p = init_train_state(r_plain, KEY)
    ts_c = init_train_state(r_comp, KEY)
    b = next(iter(SyntheticLoader("markov", 64, 8, 64)))
    b = {k: jnp.asarray(v) for k, v in b.items()}
    ts_p, m_p = jax.jit(make_train_step(r_plain))(ts_p, b)
    ts_c, m_c = jax.jit(make_train_step(r_comp))(ts_c, b)
    assert abs(float(m_p["loss"]) - float(m_c["loss"])) < 1e-6
    assert tree_maxdiff(ts_p.params, ts_c.params) < 1e-6


def test_encoder_masked_prediction_loss():
    cfg = ModelConfig(family="encoder", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32,
                      is_causal=False, position="none", dtype="float32")
    run = RunConfig(model=cfg, train=TrainConfig(global_batch=2, seq_len=32))
    ts = init_train_state(run, KEY)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, 32),
             "features": jax.random.normal(KEY, (B, S, 32)),
             "mask_spans": jax.random.bernoulli(KEY, 0.3, (B, S))}
    ts2, m = jax.jit(make_train_step(run))(ts, batch)
    assert np.isfinite(float(m["loss"]))


def test_segmented_routing_trains():
    """Beyond-paper shard-local routing wired end-to-end: loss decreases."""
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=64, attention="local+routing",
                      routing=RoutingConfig(num_clusters=4, local_window=16,
                                            segments=4),
                      dtype="float32")
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=8, seq_len=64, steps=15, lr=3e-3, schedule="const",
        warmup_steps=3))
    losses, _ = _fit(run)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
