"""Backend-parity matrix for the repro.attn registry.

Every registered (variant, impl) pair must match the reference (xla)
backend within tolerance on causal / GQA / padded / decode cases, and a
capability-mismatched ``impl=`` override must raise loudly. This file is
run with deselect-free collection by the CI kernel-parity step (Pallas
backends execute in interpret mode on CPU), so a new backend cannot land
unregistered or untested.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attn as A
from repro.attn import registry
from repro.attn.registry import Backend, Capabilities
from repro.configs.base import ModelConfig, RoutingConfig
from repro.core.kmeans import init_kmeans

from conftest import FORCED_DEVICES, run_forced_devices

KEY = jax.random.PRNGKey(42)
TOL = 2e-5

# One representative spec per variant. Shapes are chosen so every Pallas
# kernel's block constraints hold (N % 128 == 0, cluster window 128).
N, DH = 256, 32
ROUTING = RoutingConfig(num_clusters=2)


def _spec(variant, *, causal=True, gqa=False):
    H, Hkv = (4, 2) if gqa else (4, 4)
    kw = dict(num_heads=H, num_kv_heads=Hkv, head_dim=DH, causal=causal)
    if variant == "full":
        return A.AttentionSpec(variant="full", **kw)
    if variant == "local":
        return A.AttentionSpec(variant="local", window=64, **kw)
    rc = ROUTING if causal else RoutingConfig(num_clusters=2, causal=False,
                                              share_qk=False)
    if variant == "routing":
        return A.AttentionSpec(variant="routing", routing=rc, **kw)
    return A.AttentionSpec(variant="local+routing", routing=rc, window=64,
                           routing_heads=2, **kw)


def _inputs(spec, key=KEY, n=N):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (2, spec.num_heads, n, spec.head_dim))
    k = jax.random.normal(ks[1], (2, spec.num_kv_heads, n, spec.head_dim))
    v = jax.random.normal(ks[2], (2, spec.num_kv_heads, n, spec.head_dim))
    Hr = spec.routing_heads or spec.num_heads
    mu = (init_kmeans(ks[3], Hr, spec.routing.num_clusters,
                      spec.head_dim).mu if spec.routing is not None
          else None)
    return q, k, v, mu


def _maxdiff(a, b):
    return float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))
                 .max())


def _case_kwargs(case, n=N):
    if case == "padded":
        pm = jnp.broadcast_to(jnp.arange(n)[None, :] < n - 48, (2, n))
        return {"pad_mask": pm}
    return {}


NON_REFERENCE = [b for b in A.registered() if b.impl != "xla"]


@pytest.mark.parametrize("case", ["causal", "gqa", "padded"])
@pytest.mark.parametrize("backend", NON_REFERENCE,
                         ids=lambda b: b.name.replace("/", ":"))
def test_backend_matches_reference(backend, case):
    """Matrix: every non-reference backend vs the xla reference on the
    same spec/inputs. Backends whose capabilities exclude a case must
    refuse it loudly instead of computing something else."""
    spec = _spec(backend.variant, gqa=(case == "gqa"))
    q, k, v, mu = _inputs(spec)
    kwargs = _case_kwargs(case)
    if case == "padded" and not backend.caps.supports_pad_mask:
        with pytest.raises(A.BackendResolutionError, match="pad_mask"):
            A.attend(spec, q, k, v, state=mu, impl=backend.impl, **kwargs)
        return
    ref = A.attend(spec, q, k, v, state=mu, update_state=False,
                   impl="xla", **kwargs)
    out = A.attend(spec, q, k, v, state=mu, update_state=False,
                   impl=backend.impl, **kwargs)
    assert out.out.shape == ref.out.shape
    assert _maxdiff(out.out, ref.out) < TOL


@pytest.mark.parametrize("backend", NON_REFERENCE,
                         ids=lambda b: b.name.replace("/", ":"))
def test_backend_grad_matches_reference(backend):
    """Grad leg of the matrix: every supports_grad backend's jax.grad
    must match the reference's — a kernel registered with a wrong (or
    missing) VJP cannot land. supports_grad defaults to False in the
    registry precisely so this leg is the only way to claim it."""
    if not backend.caps.supports_grad:
        pytest.skip(f"{backend.name} declares supports_grad=False")
    spec = _spec(backend.variant)
    q, k, v, mu = _inputs(spec)

    def loss(impl):
        def f(q, k, v):
            out = A.attend(spec, q, k, v, state=mu, update_state=False,
                           impl=impl, needs_grad=True).out
            return (out * out).sum() / 2
        return f

    g = jax.grad(loss(backend.impl), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss("xla"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        assert float(jnp.abs(a - b).max()) < 1e-4, backend.name


@pytest.mark.parametrize("variant", ["full", "local"])
def test_decode_matches_apply(variant):
    """Decode case of the matrix: for every registered decode-capable
    backend of exact-decode variants, sequential N=1 decode through the
    declared cache layout reproduces the teacher-forced apply rows."""
    spec = _spec(variant, gqa=True)
    q, k, v, _ = _inputs(spec, n=96)
    ref = A.attend(spec, q, k, v).out
    for b in A.backends_for(variant):
        if not b.caps.supports_decode:
            continue
        cache = A.init_decode_cache(spec, 2, 96, jnp.float32,
                                    impl=b.impl)
        for t in range(96):
            pos = jnp.full((2,), t, jnp.int32)
            out = A.attend(spec, q[:, :, t:t + 1], k[:, :, t:t + 1],
                           v[:, :, t:t + 1], cache=cache, pos=pos,
                           impl=b.impl)
            cache = out.cache
            assert _maxdiff(out.out[:, :, 0], ref[:, :, t]) < 1e-4, \
                (b.name, t)


@pytest.mark.parametrize("variant", ["routing", "local+routing"])
def test_decode_cache_coherent(variant):
    """Decode case for routing variants (argmax-paged decode is the
    designed serving adaptation, not bit-equal to balanced top-k): for
    EVERY registered decode-capable backend — xla and pallas_paged ride
    the same deselect-free loop — every decoded token lands in exactly
    one page and outputs stay finite."""
    spec = _spec(variant)
    q, k, v, mu = _inputs(spec, n=32)
    ran = []
    for b in A.backends_for(variant):
        if not b.caps.supports_decode:
            continue
        ran.append(b.impl)
        assert b.layout.name in ("pages", "ring+pages")
        # deprecation shim: the old string field mirrors the typed layout
        assert b.caps.cache_layout == b.layout.name
        cache = A.init_decode_cache(spec, 2, 32, jnp.float32, impl=b.impl)
        for t in range(32):
            pos = jnp.full((2,), t, jnp.int32)
            out = A.attend(spec, q[:, :, t:t + 1], k[:, :, t:t + 1],
                           v[:, :, t:t + 1], cache=cache, pos=pos,
                           state=mu, impl=b.impl)
            cache = out.cache
            assert bool(jnp.isfinite(out.out).all()), b.name
        assert bool((cache["rlen"].sum(-1) == 32).all()), b.name
    assert "xla" in ran and "pallas_paged" in ran


# ---------------------------------------------------------------------------
# Capability enforcement
# ---------------------------------------------------------------------------
def test_forced_decode_on_apply_only_backend_raises():
    spec = _spec("full")
    q, k, v, _ = _inputs(spec)
    cache = A.init_decode_cache(spec, 2, N, jnp.float32)
    with pytest.raises(A.BackendResolutionError, match="decode"):
        A.attend(spec, q[:, :, :1], k[:, :, :1], v[:, :, :1], cache=cache,
                 pos=jnp.zeros((2,), jnp.int32), impl="pallas")


def test_explicit_positions_excluded_from_index_masking_kernels():
    """The flash kernel masks by row index; calls with caller-supplied
    positions must fall back to the positions-aware reference (auto) or
    refuse loudly (forced) — never silently mask the wrong boundary."""
    spec = _spec("full")
    q, k, v, _ = _inputs(spec)
    pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (2, N))
    assert A.resolve(spec, platform="tpu", positioned=True).impl == "xla"
    with pytest.raises(A.BackendResolutionError, match="positions"):
        A.attend(spec, q, k, v, positions=pos, impl="pallas")
    # positions-aware backends still take them (routing gathers pos_q/k)
    r = _spec("routing")
    q2, k2, v2, mu = _inputs(r)
    A.attend(r, q2, k2, v2, state=mu, positions=pos, impl="pallas")


def test_logit_scale_excluded_from_baked_scale_backends():
    spec = A.AttentionSpec(variant="full", num_heads=4, num_kv_heads=4,
                           head_dim=DH, logit_scale=0.5)
    q, k, v, _ = _inputs(spec)
    assert A.resolve(spec, platform="tpu").impl == "xla"
    with pytest.raises(A.BackendResolutionError, match="logit_scale"):
        A.attend(spec, q, k, v, impl="pallas")
    lspec = A.AttentionSpec(variant="local", num_heads=4, num_kv_heads=4,
                            head_dim=DH, window=64, logit_scale=0.5)
    with pytest.raises(A.BackendResolutionError, match="logit_scale"):
        A.attend(lspec, q, k, v)          # no reference honors it either


def test_decode_rejects_pad_mask():
    """Decode validity lives in the cache; a pad_mask on the decode path
    would be silently ignored, so attend refuses it."""
    spec = _spec("full")
    q, k, v, _ = _inputs(spec)
    cache = A.init_decode_cache(spec, 2, N, jnp.float32)
    with pytest.raises(ValueError, match="pad_mask"):
        A.attend(spec, q[:, :, :1], k[:, :, :1], v[:, :, :1], cache=cache,
                 pos=jnp.zeros((2,), jnp.int32),
                 pad_mask=jnp.ones((2, N), bool))


def test_spec_routing_heads_field_is_authoritative():
    """AttentionSpec.routing_heads must drive the head split even when it
    disagrees with the RoutingConfig's own routing_heads knob (the spec
    is the single source of truth once built)."""
    from repro.attn.spec import head_split
    spec = A.AttentionSpec(variant="local+routing", num_heads=8,
                           num_kv_heads=8, head_dim=16, window=32,
                           routing=RoutingConfig(routing_heads=2),
                           routing_heads=6)
    assert head_split(spec) == (2, 6, 2, 6)


def test_unknown_impl_lists_registered():
    spec = _spec("full")
    q, k, v, _ = _inputs(spec)
    with pytest.raises(A.BackendResolutionError, match="pallas"):
        A.attend(spec, q, k, v, impl="cuda")


def test_unknown_variant_rejected_at_spec():
    with pytest.raises(ValueError, match="variant"):
        A.AttentionSpec(variant="strided", num_heads=4, num_kv_heads=4,
                        head_dim=32)


def test_max_seq_capability_enforced():
    A.registry.register(Backend(
        variant="full", impl="_test_short", apply=lambda *a, **kw: None,
        caps=Capabilities(max_seq=64)))
    try:
        spec = _spec("full")
        q, k, v, _ = _inputs(spec)          # N=256 > 64
        with pytest.raises(A.BackendResolutionError, match="max_seq"):
            A.attend(spec, q, k, v, impl="_test_short")
    finally:
        A.unregister("full", "_test_short")


def test_auto_resolution_prefers_pallas_on_tpu_only():
    spec = _spec("full")
    assert A.resolve(spec, platform="cpu").impl == "xla"
    assert A.resolve(spec, platform="tpu").impl == "pallas"
    # padded calls exclude the flash kernel even on TPU
    assert A.resolve(spec, platform="tpu", padded=True).impl == "xla"


def test_fused_routing_preferred_on_tpu():
    """Auto-resolution takes the gather-free fused kernel over the
    gathered pallas path on TPU (priority 20 vs 10), including under
    needs_grad (it has a VJP). Decode resolves to the paged-decode
    kernel (routing/pallas_paged) on TPU — the fused backend still
    declares no decode path; pallas_paged registers after it at the same
    priority, so the tie breaks toward fused for apply and toward the
    paged kernel for decode (parity: tests/test_routing_decode.py)."""
    for variant in ("routing", "local+routing"):
        spec = _spec(variant)
        assert A.resolve(spec, platform="tpu").impl == "pallas_fused"
        assert A.resolve(spec, platform="tpu",
                         needs_grad=True).impl == "pallas_fused"
        assert A.resolve(spec, platform="cpu").impl == "xla"
        assert A.decode_backend(spec, platform="tpu").impl == "pallas_paged"
        assert A.decode_backend(spec, platform="cpu").impl == "xla"
        # no VMEM-residency cliff anymore: the fused kernel auto-switches
        # to the double-buffered paged memory plan past the residency
        # budget, so auto-selection stays fused at every sequence length
        assert A.resolve(spec, platform="tpu",
                         seq_len=16384).impl == "pallas_fused"
        assert A.resolve(spec, platform="tpu",
                         seq_len=65536).impl == "pallas_fused"
    wide = A.AttentionSpec(variant="routing", num_heads=4, num_kv_heads=4,
                           head_dim=256, routing=ROUTING)
    assert A.resolve(wide, platform="tpu",
                     seq_len=8192).impl == "pallas_fused"
    # ... but the *forced* unpaged plan keeps the cap, and refusing it
    # names the auto-selected escape hatch
    spec = _spec("routing")
    with pytest.raises(A.BackendResolutionError,
                       match=r"max_seq_elems.*\n.*pallas_fused"):
        A.resolve(spec, platform="tpu", seq_len=65536,
                  impl="pallas_fused_unpaged")


def test_paged_fused_impls_in_parity_matrix():
    """Both forced memory plans of the fused kernel are registered for
    both routing variants — and therefore auto-picked-up by the
    NON_REFERENCE parity matrix above (forward, padded, and grad legs
    run against each without hand-listing them here)."""
    names = {b.name for b in NON_REFERENCE}
    for variant in ("routing", "local+routing"):
        assert f"{variant}/pallas_fused_paged" in names
        assert f"{variant}/pallas_fused_unpaged" in names
        # forced-plan backends are escape hatches, not contenders:
        # auto-selection must keep landing on the auto-switching impl
        assert A.resolve(_spec(variant), platform="tpu").impl == \
            "pallas_fused"


def test_capacity_fallback_counts_and_warns_once():
    """Auto-selection that skips a higher-priority backend purely on
    sequence capacity (max_seq/max_seq_elems) increments the obs
    'attn/fallback' counter every time and warns once per (excluded,
    chosen) pair — the N=8k-silently-lands-on-a-slower-path failure
    mode has a signal."""
    import warnings
    from repro.obs import default_registry
    spec = _spec("full")
    registry.register(Backend(
        variant="full", impl="_test_capped",
        apply=lambda *a, **k: None, priority=99,
        caps=Capabilities(max_seq_elems=1024, supports_grad=True)))
    try:
        registry._FALLBACK_WARNED.clear()
        ctr = default_registry().counter("attn/fallback")
        before = ctr.value
        # under the cap the capped backend wins outright: no fallback
        assert A.resolve(spec, seq_len=16).impl == "_test_capped"
        assert ctr.value == before
        # past the cap: fall back to the best eligible backend, warn
        with pytest.warns(RuntimeWarning,
                          match=r"fell back from full/_test_capped"):
            assert A.resolve(spec, seq_len=256).impl == "xla"
        assert ctr.value == before + 1
        # second occurrence: counted again, but not re-warned
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            A.resolve(spec, seq_len=256)
        assert ctr.value == before + 2
    finally:
        A.unregister("full", "_test_capped")
        registry._FALLBACK_WARNED.clear()


def test_mixed_local_half_uses_window_kernel(monkeypatch):
    """local+routing Pallas-family backends run the local half on the
    Pallas window kernel (which carries its own VJP — the composite
    gradient is kernel-backed end to end, covered by the matrix grad
    leg) when the case is expressible, and fall back to the XLA local
    reference when it is not (pad_mask)."""
    import repro.kernels.ops as kops
    calls = []
    orig = kops.local_attention

    def spy(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(kops, "local_attention", spy)
    spec = _spec("local+routing")
    q, k, v, mu = _inputs(spec)
    A.attend(spec, q, k, v, state=mu, update_state=False,
             impl="pallas_fused")
    assert calls, "local half did not reach the Pallas window kernel"
    calls.clear()
    A.attend(spec, q, k, v, state=mu, update_state=False,
             impl="pallas_fused", **_case_kwargs("padded"))
    assert not calls, "pad_mask case must use the XLA local reference"


def test_supports_grad_capability_enforced():
    """A forced non-differentiable backend refuses needs_grad calls at
    resolution, and jax.grad through its output raises the registry
    error instead of an opaque tracing failure (the guard)."""
    spec = _spec("full")
    q, k, v, _ = _inputs(spec)
    A.registry.register(Backend(
        variant="full", impl="_test_nograd",
        apply=lambda spec, q, k, v, **kw: (q, None),
        caps=Capabilities(supports_grad=False)))
    try:
        with pytest.raises(A.BackendResolutionError, match="supports_grad"):
            A.attend(spec, q, k, v, impl="_test_nograd", needs_grad=True)
        # un-announced grad: the guard fires during backward tracing
        def loss(q):
            return A.attend(spec, q, k, v, impl="_test_nograd").out.sum()
        with pytest.raises(A.BackendResolutionError, match="supports_grad"):
            jax.grad(loss)(q)
    finally:
        A.unregister("full", "_test_nograd")


def test_builtin_pallas_backends_are_differentiable():
    """Every built-in Pallas backend carries a custom VJP now — the train
    path never silently needs the XLA reference again."""
    for b in A.registered():
        assert b.caps.supports_grad, b.name


def test_every_backend_declares_consistent_hints():
    hints = A.cache_head_axes()
    for b in A.registered():
        if b.caps.supports_decode:
            cache = b.layout.init(_spec(b.variant), 1, 32, jnp.float32)
            for leaf, arr in cache.items():
                ax = hints.get(leaf)
                assert ax is None or arr.ndim >= ax, (b.name, leaf)


def test_cache_layout_protocol():
    """The typed CacheLayout answers every layout question in one object:
    init/fill callables, reset values, head axes, pageable structure, and
    allocation-free lane-byte accounting."""
    for b in A.registered():
        if not b.caps.supports_decode:
            continue
        lo = b.layout
        spec = _spec(b.variant)
        cache = lo.init(spec, 1, 32, jnp.float32)
        nbytes = lo.lane_bytes(spec, 32, jnp.float32)
        assert nbytes == sum(np.prod(a.shape) * a.dtype.itemsize
                             for a in cache.values()), b.name
        for leaf in lo.pageable_leaves:         # pages are (…, kc, cap, dh)
            assert cache[leaf].ndim >= 4, (b.name, leaf)
            assert lo.page_len_leaf in cache, b.name
        for leaf, val in lo.reset_values.items():
            assert bool((cache[leaf] == val).all()), (b.name, leaf)
        # deprecated Backend accessors still delegate to the layout
        assert b.init_cache is lo.init and b.prefill_fill is lo.fill
        assert b.cache_head_axes == lo.head_axes


def test_register_rejects_contradictory_layout_string():
    """A backend whose deprecated caps.cache_layout string disagrees with
    its typed layout is a registration error, not a silent shadowing."""
    lo = A.CacheLayout(name="append", init=lambda *a: {}, fill=lambda *a: {})
    with pytest.raises(ValueError, match="contradicts"):
        registry.register(Backend(
            variant="full", impl="_test_badlayout",
            apply=lambda *a, **k: None, layout=lo,
            caps=Capabilities(cache_layout="ring")))
    A.unregister("full", "_test_badlayout")


# ---------------------------------------------------------------------------
# Spec resolution (the attn_chunk satellite + degenerate splits)
# ---------------------------------------------------------------------------
def test_chunk_resolution_explicit_zero_wins_for_long_seq():
    base = dict(num_heads=4, num_kv_heads=4, head_dim=32)
    auto = A.AttentionSpec(variant="full", chunk=None, **base)
    one_shot = A.AttentionSpec(variant="full", chunk=0, **base)
    forced = A.AttentionSpec(variant="full", chunk=256, **base)
    assert A.resolve_chunk(auto, 8192) == 1024      # auto kicks in
    assert A.resolve_chunk(auto, 512) == 0
    assert A.resolve_chunk(one_shot, 8192) == 0     # 0 is now settable
    assert A.resolve_chunk(forced, 512) == 256


def test_config_chunk_flows_into_spec():
    cfg = ModelConfig(attention="full", attn_chunk=0)
    assert A.spec_for_layer(cfg, "full").chunk == 0
    cfg2 = ModelConfig(attention="full")
    assert A.spec_for_layer(cfg2, "full").chunk is None


def test_degenerate_local_routing_collapses():
    cfg = ModelConfig(num_heads=2, num_kv_heads=1,
                      attention="local+routing",
                      routing=RoutingConfig(routing_heads=2))
    assert A.spec_for_layer(cfg, "local+routing").variant == "routing"
    cfg2 = ModelConfig(num_heads=1, num_kv_heads=1,
                       attention="local+routing",
                       routing=RoutingConfig())   # H//2 == 0 -> no routing
    s2 = A.spec_for_layer(cfg2, "local+routing")
    assert s2.variant == "local"
    assert s2.window == cfg2.routing.local_window


# ---------------------------------------------------------------------------
# Mesh case of the matrix (multi-device CI lane; subprocess keeps the
# main pytest process single-device, see conftest)
# ---------------------------------------------------------------------------
def test_registry_matrix_on_mesh():
    run_forced_devices(f"""
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro import attn as A
from repro.configs.base import RoutingConfig
from repro.core.kmeans import init_kmeans

D = {FORCED_DEVICES}
mesh = Mesh(jax.devices(), ("data",))
rc = RoutingConfig(num_clusters=2)
for variant in ("full", "local", "routing", "local+routing"):
    kw = dict(num_heads=4, num_kv_heads=2, head_dim=32)
    spec = dict(
        full=A.AttentionSpec(variant="full", **kw),
        local=A.AttentionSpec(variant="local", window=64, **kw),
        routing=A.AttentionSpec(variant="routing", routing=rc, **kw),
    ).get(variant) or A.AttentionSpec(variant="local+routing", routing=rc,
                                      window=64, routing_heads=2, **kw)
    # a mesh call must resolve to a mesh-capable backend
    assert A.resolve(spec, mesh=mesh, platform="tpu").caps.supports_mesh
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (D, 4, 128, 32))
    k = jax.random.normal(ks[1], (D, 2, 128, 32))
    v = jax.random.normal(ks[2], (D, 2, 128, 32))
    mu = init_kmeans(ks[3], spec.routing_heads or 4, 2, 32).mu
    ref = A.attend(spec, q, k, v, state=mu, update_state=False).out

    sh = NamedSharding(mesh, P("data"))
    qs, ks_, vs = (jax.device_put(x, sh) for x in (q, k, v))
    fn = jax.jit(lambda q, k, v, mu: A.attend(
        spec, q, k, v, state=mu, update_state=False, mesh=mesh).out)
    out = fn(qs, ks_, vs, mu)
    err = float(jnp.abs(out - ref).max())
    assert err < 2e-5, (variant, err)
print("MESH-MATRIX-OK")
""")
