"""Continuous-batching engine: end-to-end scheduling correctness, slot
parity for routing heads, pool hygiene, admission policy, and sampling.

The load-bearing guarantees:
  * every request's output is exactly its solo-decode output, no matter
    which slot it lands in, who its co-tenants are, or when it arrives;
  * freed lanes are reused by later requests without reallocation;
  * the engine finishes the same workload in fewer decode steps than
    lock-step batching (the seed's fixed-batch loop).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.engine import (PRIORITY_BATCH, PRIORITY_INTERACTIVE,
                                FCFSScheduler, InferenceEngine, Request,
                                SamplingParams, init_pool, read_slot,
                                request_key, reset_slot, sample_tokens,
                                write_slot)
from repro.serve.serving import init_cache, make_serve_step, prefill

CFG = ModelConfig(name="eng", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  attention="local+routing",
                  routing=RoutingConfig(num_clusters=4, local_window=8),
                  dtype="float32")
MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    return init_model(CFG, jax.random.PRNGKey(0))


def _mk_requests(n=12, prompt_lens=(5, 9, 14, 20), gen_lens=(3, 5, 7, 9, 4),
                 arrival_every_other=True, seed=3):
    rng = np.random.RandomState(seed)
    reqs = []
    for uid in range(n):
        p = prompt_lens[uid % len(prompt_lens)]
        g = gen_lens[(2 * uid + 1) % len(gen_lens)]
        reqs.append(Request(
            uid=uid, prompt=rng.randint(0, CFG.vocab_size, size=p).tolist(),
            max_new_tokens=g,
            arrival_step=(uid // 2 if arrival_every_other else 0)))
    return reqs


def _solo_reference(params, kstate, req, n_tokens=None):
    """Greedy decode through the seed's single-batch make_serve_step path."""
    n_tokens = n_tokens or req.max_new_tokens
    cache = init_cache(CFG, 1, MAX_LEN)
    lg, cache = prefill(params, kstate, cache,
                        {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]},
                        CFG)
    step = jax.jit(make_serve_step(CFG))
    toks = [int(jnp.argmax(lg[0, -1]))]
    pos = req.prompt_len
    while len(toks) < n_tokens:
        lg1, cache = step(params, kstate, cache,
                          jnp.asarray([toks[-1]], jnp.int32),
                          jnp.asarray([pos], jnp.int32))
        toks.append(int(jnp.argmax(lg1[0])))
        pos += 1
    return toks


# ---------------------------------------------------------------------------
# End-to-end continuous batching (the acceptance test)
# ---------------------------------------------------------------------------
def test_continuous_batching_matches_solo(model):
    """12 staggered requests over 4 slots: every output exactly equals its
    solo decode; freed slots are reused; the pool fully drains."""
    params, kstate = model
    reqs = _mk_requests(n=12)
    eng = InferenceEngine(CFG, params, kstate, max_slots=4, max_len=MAX_LEN)
    out = eng.run(reqs)
    for r in reqs:
        assert out[r.uid] == _solo_reference(params, kstate, r), r.uid
        assert r.state == "FINISHED"
    # slot reuse: 12 requests over 4 slots forces lanes to be recycled
    slot_of = {r.uid: eng.metrics.requests[r.uid].slot for r in reqs}
    per_slot = {s: sum(1 for v in slot_of.values() if v == s)
                for s in set(slot_of.values())}
    assert max(per_slot.values()) >= 2, per_slot
    assert all(s is None for s in eng.slots)          # pool drained
    # continuous batching packs the pool: more useful tokens per step than
    # one request at a time, and bounded by the slot count
    assert 1.0 < eng.metrics.tokens_per_step <= 4.0


def test_engine_beats_lockstep_tokens_per_step(model):
    """Same workload, same kernels: the engine needs fewer decode steps
    (and so fewer jitted-step wall-seconds) than lock-step batching."""
    from benchmarks.serve_engine import (clone_requests, run_continuous,
                                         run_lockstep, workload_max_len)
    params, kstate = model
    reqs = _mk_requests(n=12)
    max_len = workload_max_len(reqs)
    out_ls, ls = run_lockstep(CFG, params, kstate, clone_requests(reqs),
                              4, max_len)
    out_cb, cb = run_continuous(CFG, params, kstate, clone_requests(reqs),
                                4, max_len)
    assert out_cb == out_ls                       # identical generations
    assert cb["decode_steps"] < ls["decode_steps"]
    assert cb["tokens_per_step"] > ls["tokens_per_step"]


@pytest.mark.slow
def test_benchmark_reports_higher_decode_throughput():
    """Wall-clock acceptance: benchmarks/serve_engine.py's workload gives
    the engine higher aggregate decode tokens/sec than lock-step."""
    from benchmarks.serve_engine import (build_model, clone_requests,
                                         make_workload, run_continuous,
                                         run_lockstep, workload_max_len)
    cfg, params, kstate = build_model()
    reqs = make_workload(cfg, n_requests=12)
    max_len = workload_max_len(reqs)
    # best-of-2 per scheduler: wall timings on shared CI machines are noisy
    ls = max((run_lockstep(cfg, params, kstate, clone_requests(reqs), 4,
                           max_len)[1] for _ in range(2)),
             key=lambda s: s["decode_tokens_per_s"])
    cb = max((run_continuous(cfg, params, kstate, clone_requests(reqs), 4,
                             max_len)[1] for _ in range(2)),
             key=lambda s: s["decode_tokens_per_s"])
    assert cb["tokens_per_step"] > ls["tokens_per_step"]
    assert cb["decode_tokens_per_s"] > ls["decode_tokens_per_s"], (cb, ls)


# ---------------------------------------------------------------------------
# Slot parity of routing heads (satellite)
# ---------------------------------------------------------------------------
def test_routing_slot_parity_bitwise(model):
    """A request decoded in slot 3 of a busy pool produces bit-identical
    logits to the same request decoded alone in slot 0, and matches the
    seed's single-batch make_serve_step path."""
    params, kstate = model
    rng = np.random.RandomState(11)
    target = lambda: Request(uid=99, prompt=rng_prompt, max_new_tokens=7)
    rng_prompt = rng.randint(0, CFG.vocab_size, size=13).tolist()
    tenants = [Request(uid=i, prompt=rng.randint(
        0, CFG.vocab_size, size=6 + i).tolist(), max_new_tokens=9)
        for i in range(3)]

    # run A: three co-tenants admitted first -> target lands in slot 3
    eng_a = InferenceEngine(CFG, params, kstate, max_slots=4,
                            max_len=MAX_LEN, record_logits=True)
    out_a = eng_a.run(tenants + [target()])
    assert eng_a.metrics.requests[99].slot == 3

    # run B: target alone in the same-size pool -> slot 0
    eng_b = InferenceEngine(CFG, params, kstate, max_slots=4,
                            max_len=MAX_LEN, record_logits=True)
    out_b = eng_b.run([target()])
    assert eng_b.metrics.requests[99].slot == 0

    assert out_a[99] == out_b[99]
    la, lb = eng_a.logits_trace[99], eng_b.logits_trace[99]
    assert len(la) == len(lb) == 7
    for step_a, step_b in zip(la, lb):
        assert np.array_equal(step_a, step_b)     # BIT-identical

    # seed path: same tokens, logits equal to numerical tolerance
    solo = _solo_reference(params, kstate, target())
    assert out_a[99] == solo


def test_sampled_outputs_independent_of_co_tenants(model):
    """Counter-based PRNG streams: a stochastic request's tokens do not
    change when its pool neighbours change."""
    params, kstate = model
    rng = np.random.RandomState(4)
    sp = SamplingParams(temperature=0.9, top_k=20, top_p=0.9, seed=5)
    mk = lambda: Request(uid=50, prompt=rng_prompt, max_new_tokens=6,
                         sampling=sp)
    rng_prompt = rng.randint(0, CFG.vocab_size, size=8).tolist()
    outs = []
    for tenant_seed in (1, 2):
        tenants = [Request(uid=i, prompt=np.random.RandomState(
            tenant_seed + i).randint(0, CFG.vocab_size, size=5 + i).tolist(),
            max_new_tokens=8, sampling=SamplingParams(temperature=1.1,
                                                      seed=tenant_seed))
            for i in range(2)]
        eng = InferenceEngine(CFG, params, kstate, max_slots=3,
                              max_len=MAX_LEN)
        outs.append(eng.run(tenants + [mk()])[50])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Chunked prefill: depth stages interleaved with decode (docs/serving.md)
# ---------------------------------------------------------------------------
def _clone(reqs):
    return [dataclasses.replace(r, output=[]) for r in reqs]


def test_chunked_prefill_matches_unchunked(model):
    """Depth-chunked prefill produces the same token streams as monolithic
    prefill for any stage budget, and two chunked engines with different
    budgets are bit-identical per decode step (same staged jits, only the
    scheduling differs)."""
    params, kstate = model
    base = _mk_requests(n=8)
    ref = InferenceEngine(CFG, params, kstate, max_slots=3, max_len=MAX_LEN)
    out_ref = ref.run(_clone(base))
    traces = {}
    for budget in (1, 3):
        eng = InferenceEngine(CFG, params, kstate, max_slots=3,
                              max_len=MAX_LEN, chunked_prefill=budget,
                              record_logits=True)
        assert out_ref == eng.run(_clone(base)), budget
        assert all(s is None for s in eng.slots)        # pool drained
        assert not eng._prefill_jobs                    # no orphan jobs
        traces[budget] = eng.logits_trace
    for uid in traces[1]:
        for a, b in zip(traces[1][uid], traces[3][uid]):
            assert np.array_equal(a, b)                 # BIT-identical


def test_chunked_prefill_interleaves_decode(model):
    """A long prompt admitted mid-flight no longer head-of-line-blocks:
    the already-decoding session gains a token on every step while the
    newcomer's prefill advances one depth stage at a time."""
    params, kstate = model
    rng = np.random.RandomState(13)
    eng = InferenceEngine(CFG, params, kstate, max_slots=2, max_len=MAX_LEN,
                          chunked_prefill=1)
    a = eng.submit(Request(uid=0, prompt=rng.randint(
        0, CFG.vocab_size, size=6).tolist(), max_new_tokens=12))
    while not a.output:                 # a's own staged prefill drains
        eng.step()
    b = eng.submit(Request(uid=1, prompt=rng.randint(
        0, CFG.vocab_size, size=20).tolist(), max_new_tokens=3))
    interleaved = 0
    while b.state in ("queued", "active") and not b.output:
        n = len(a.output)
        eng.step()
        if eng._prefill_jobs:           # b mid-prefill after this step
            interleaved += 1
            assert len(a.output) == n + 1   # a decoded through it
    assert interleaved >= 1             # prefill genuinely spanned steps
    while eng.has_work():
        eng.step()
    assert a.output == _solo_reference(params, kstate, a._request)
    assert b.output == _solo_reference(params, kstate, b._request)


def test_priority_preempts_mid_prefill_job(model):
    """max_slots=1, chunked_prefill=1: an interactive-class arrival
    preempts a batch-class request still in its prefill stages; the
    victim's partial work is dropped, it requeues, re-prefills, and both
    finish with solo-exact outputs."""
    params, kstate = model
    rng = np.random.RandomState(17)
    low = Request(uid=0, prompt=rng.randint(
        0, CFG.vocab_size, size=14).tolist(), max_new_tokens=5,
        priority=PRIORITY_BATCH)
    high = Request(uid=1, prompt=rng.randint(
        0, CFG.vocab_size, size=6).tolist(), max_new_tokens=4,
        priority=PRIORITY_INTERACTIVE)
    eng = InferenceEngine(CFG, params, kstate, max_slots=1, max_len=MAX_LEN,
                          chunked_prefill=1)
    eng.submit(low)
    eng.step()
    assert [j.request.uid for j in eng._prefill_jobs.values()] == [0]
    eng.submit(high)
    eng.step()                          # high evicts the mid-prefill job
    assert low.state in ("PARKED", "PREFILL", "WAITING")
    assert ([j.request.uid for j in eng._prefill_jobs.values()] == [1]
            or high.state == "DECODE")
    assert low.output == []             # partial prefill left no tokens
    while eng.has_work():
        eng.step()
    assert low.state == high.state == "FINISHED"
    assert list(low.output) == _solo_reference(params, kstate, low)
    assert list(high.output) == _solo_reference(params, kstate, high)
    assert eng.metrics.summary()["parks"] >= 1


def test_park_mid_prefill_requeues(model):
    """handle.park() on a session still in its prefill stages holds it
    with no lane in the KV store; resume() re-prefills from scratch and
    the output is unaffected."""
    params, kstate = model
    rng = np.random.RandomState(19)
    eng = InferenceEngine(CFG, params, kstate, max_slots=1, max_len=MAX_LEN,
                          chunked_prefill=1)
    h = eng.submit(Request(uid=5, prompt=rng.randint(
        0, CFG.vocab_size, size=10).tolist(), max_new_tokens=4))
    eng.step()
    assert eng._prefill_jobs and not h.output
    h.park()
    assert h.state == "parked"
    assert not eng._prefill_jobs and 5 not in eng.kvstore
    eng.step()                          # parked+held: nothing to run
    assert not h.output
    h.resume()
    while eng.has_work():
        eng.step()
    assert h.state == "finished"
    assert h.output == _solo_reference(params, kstate, h._request)


# ---------------------------------------------------------------------------
# Park / resume via the tiered KV store (DESIGN.md §11)
# ---------------------------------------------------------------------------
def test_park_resume_bit_parity_different_slot(model):
    """A routing-head session parked mid-decode and resumed into a
    *different* slot produces the identical token stream — and
    bit-identical per-step logits — as an uninterrupted run."""
    params, kstate = model
    rng = np.random.RandomState(11)
    prompt = rng.randint(0, CFG.vocab_size, size=13).tolist()
    mk = lambda: Request(uid=99, prompt=list(prompt), max_new_tokens=7)

    eng_ref = InferenceEngine(CFG, params, kstate, max_slots=2,
                              max_len=MAX_LEN, record_logits=True)
    out_ref = eng_ref.run([mk()])

    eng = InferenceEngine(CFG, params, kstate, max_slots=2, max_len=MAX_LEN,
                          record_logits=True)
    h = eng.submit(mk())
    eng.step()
    eng.step()
    assert h.state == "active" and eng.metrics.requests[99].slot == 0
    assert 0 < len(h.output) < 7                    # genuinely mid-decode
    h.park()
    assert h.state == "parked" and 99 in eng.kvstore
    # a tenant takes over slot 0 while 99 is parked
    eng.submit(Request(uid=1, prompt=rng.randint(
        0, CFG.vocab_size, size=6).tolist(), max_new_tokens=9))
    eng.step()
    assert eng.slots[0] is not None and eng.slots[0].request.uid == 1
    h.resume()
    while eng.has_work():
        eng.step()
    assert h.state == "finished"
    assert eng.metrics.requests[99].slot == 1       # resumed elsewhere
    assert 99 not in eng.kvstore                    # lane reclaimed
    assert h.output == out_ref[99] == _solo_reference(params, kstate, mk())
    la, lb = eng.logits_trace[99], eng_ref.logits_trace[99]
    assert len(la) == len(lb) == 7
    for a, b in zip(la, lb):
        assert np.array_equal(a, b)                 # BIT-identical
    summ = eng.metrics.summary()
    assert summ["parks"] == 1 and summ["resumes"] == 1


def test_sixteen_sessions_over_four_slots_bit_exact(model):
    """Acceptance: 16 concurrent sessions complete through a 4-slot pool
    via time-slice park/resume, every token stream identical to a
    16-slot run that never evicts."""
    params, kstate = model
    big = InferenceEngine(CFG, params, kstate, max_slots=16, max_len=MAX_LEN)
    out_big = big.run(_mk_requests(n=16, arrival_every_other=False))
    assert big.metrics.summary()["parks"] == 0      # never evicts

    small = InferenceEngine(CFG, params, kstate, max_slots=4,
                            max_len=MAX_LEN, time_slice=2)
    out_small = small.run(_mk_requests(n=16, arrival_every_other=False))
    assert out_small == out_big
    summ = small.metrics.summary()
    assert summ["parks"] > 0 and summ["resumes"] > 0
    assert all(s is None for s in small.slots)      # pool drained
    assert len(small.kvstore) == 0                  # store drained


def test_priority_preemption_parks_lowest(model):
    """max_slots=1: a priority-5 arrival preempts the running priority-0
    session, which parks, later resumes, and still finishes bit-exact."""
    params, kstate = model
    rng = np.random.RandomState(7)
    low = Request(uid=0, prompt=rng.randint(
        0, CFG.vocab_size, size=8).tolist(), max_new_tokens=12)
    high = Request(uid=1, prompt=rng.randint(
        0, CFG.vocab_size, size=6).tolist(), max_new_tokens=4, priority=5)
    eng = InferenceEngine(CFG, params, kstate, max_slots=1, max_len=MAX_LEN)
    eng.submit(low)
    eng.step()
    eng.step()
    assert low.state == "DECODE"
    eng.submit(high)
    eng.step()
    assert low.state == "PARKED" and high.state == "DECODE"
    while eng.has_work():
        eng.step()
    assert low.state == high.state == "FINISHED"
    assert list(low.output) == _solo_reference(params, kstate, low)
    assert list(high.output) == _solo_reference(params, kstate, high)
    assert eng.metrics.summary()["parks"] >= 1


def test_prefix_cache_hit_matches_miss(model):
    """Two sessions sharing one prompt: the second prefill is a cache hit
    (lane written from the store, no model call) yet yields the identical
    token stream and bit-identical logits."""
    from repro.serve.kvstore import PrefixCache
    params, kstate = model
    rng = np.random.RandomState(21)
    prompt = rng.randint(0, CFG.vocab_size, size=14).tolist()
    pc = PrefixCache()
    eng = InferenceEngine(CFG, params, kstate, max_slots=2, max_len=MAX_LEN,
                          prefix_cache=pc, record_logits=True)
    r_miss = Request(uid=0, prompt=list(prompt), max_new_tokens=6)
    r_hit = Request(uid=1, prompt=list(prompt), max_new_tokens=6,
                    arrival_step=5)     # arrives after the miss prefilled
    out = eng.run([r_miss, r_hit])
    assert pc.stats()["kvstore/prefix_hits"] == 1.0
    assert pc.stats()["kvstore/prefix_misses"] == 1.0
    assert out[0] == out[1] == _solo_reference(params, kstate, r_miss)
    for a, b in zip(eng.logits_trace[0], eng.logits_trace[1]):
        assert np.array_equal(a, b)


def test_session_handle_lifecycle_and_interop(model):
    """submit() returns a SessionHandle: queued→active→finished states,
    int(handle) interop with uid-keyed maps, cancel of a queued session."""
    params, kstate = model
    eng = InferenceEngine(CFG, params, kstate, max_slots=1, max_len=MAX_LEN)
    h1 = eng.submit(Request(uid=7, prompt=[3, 4, 5], max_new_tokens=3))
    h2 = eng.submit(Request(uid=8, prompt=[5, 6, 7], max_new_tokens=3))
    assert int(h1) == 7 and h1.uid == 7
    assert h1.state == h2.state == "queued"
    eng.step()
    assert h1.state == "active" and h2.state == "queued"
    h2.cancel()
    assert h2.state == "cancelled"
    while eng.has_work():
        eng.step()
    assert h1.state == "finished" and len(h1.output) == 3
    assert h2.output == []
    assert eng.metrics.requests[int(h1)].uid == 7   # __index__ interop


@pytest.mark.slow
def test_engine_on_mesh_matches_single_device():
    """Same request stream, 1-device placement vs a 4x2 ("data","model")
    host mesh with the production sharding rules on the slot pool:
    identical token streams. Spawned as a subprocess so the main pytest
    process keeps its single-device view (same pattern as test_dist.py)."""
    from conftest import run_forced_devices
    code = """
import jax, numpy as np
from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.engine import InferenceEngine, Request
from repro.launch.mesh import make_host_mesh

CFG = ModelConfig(name="eng", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  attention="local+routing",
                  routing=RoutingConfig(num_clusters=4, local_window=8),
                  dtype="float32")
params, kstate = init_model(CFG, jax.random.PRNGKey(0))

def workload():
    rng = np.random.RandomState(3)
    return [Request(uid=i,
                    prompt=rng.randint(0, CFG.vocab_size,
                                       size=5 + 3 * i).tolist(),
                    max_new_tokens=4 + (i % 5), arrival_step=i // 2)
            for i in range(8)]

eng1 = InferenceEngine(CFG, params, kstate, max_slots=4, max_len=48)
out1 = eng1.run(workload())

mesh = make_host_mesh(4, 2)      # clamps to the forced device count
assert mesh.shape["data"] * mesh.shape["model"] == len(jax.devices())
assert mesh.shape["model"] > 1, mesh.shape
eng8 = InferenceEngine(CFG, params, kstate, max_slots=4, max_len=48,
                       mesh=mesh)
out8 = eng8.run(workload())
assert out1 == out8, (out1, out8)
assert all(s is None for s in eng8.slots)
print("engine mesh parity OK")
"""
    run_forced_devices(code)


# ---------------------------------------------------------------------------
# Pool hygiene
# ---------------------------------------------------------------------------
def test_reset_slot_restores_init_state(model):
    """A freed lane equals a freshly allocated lane, leaf for leaf —
    routing cluster pages emptied, local ring positions back to -1."""
    params, kstate = model
    fresh = init_pool(CFG, 3, MAX_LEN)
    pool = fresh
    lane = init_cache(CFG, 1, MAX_LEN)
    toks = jnp.arange(12, dtype=jnp.int32)[None] % CFG.vocab_size
    _, lane = prefill(params, kstate, lane, {"tokens": toks}, CFG)
    pool = write_slot(pool, 1, lane)
    dirty = sum(int((a != b).sum()) for a, b in
                zip(jax.tree.leaves(pool), jax.tree.leaves(fresh)))
    assert dirty > 0                                # prefill really landed
    pool = reset_slot(pool, 1)
    for a, b in zip(jax.tree.leaves(pool), jax.tree.leaves(fresh)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_read_slot_roundtrip(model):
    params, kstate = model
    pool = init_pool(CFG, 2, MAX_LEN)
    lane = init_cache(CFG, 1, MAX_LEN)
    toks = jnp.arange(9, dtype=jnp.int32)[None] % CFG.vocab_size
    _, lane = prefill(params, kstate, lane, {"tokens": toks}, CFG)
    pool = write_slot(pool, 1, lane)
    back = read_slot(pool, 1)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(lane)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Scheduling / admission
# ---------------------------------------------------------------------------
def test_fcfs_scheduler_slot_and_budget_gating():
    sched = FCFSScheduler(token_budget=25)
    reqs = [Request(uid=i, prompt=[1] * 6, max_new_tokens=4)
            for i in range(4)]                      # 10 reserved tokens each
    for r in reqs:
        sched.submit(r)
    assert sched.next_admittable(0, 0) is None      # no free slot
    a = sched.next_admittable(4, 0)
    b = sched.next_admittable(3, 10)
    assert (a.uid, b.uid) == (0, 1)                 # FCFS order
    assert sched.next_admittable(2, 20) is None     # 20 + 10 > budget 25
    c = sched.next_admittable(2, 10)                # backpressure released
    assert c.uid == 2 and len(sched) == 1


def test_engine_token_budget_backpressure(model):
    """Budget that fits one request at a time: occupancy never exceeds 1
    even with free slots, and everything still finishes correctly."""
    params, kstate = model
    reqs = _mk_requests(n=3, arrival_every_other=False)
    budget = max(FCFSScheduler.reserved_tokens(r) for r in reqs)
    eng = InferenceEngine(CFG, params, kstate, max_slots=2, max_len=MAX_LEN,
                          token_budget=budget)
    out = eng.run(reqs)
    assert eng.metrics.mean_occupancy <= 1.0
    for r in reqs:
        assert out[r.uid] == _solo_reference(params, kstate, r)


def test_eos_termination(model):
    params, kstate = model
    req = _mk_requests(n=1, prompt_lens=(10,), gen_lens=(9,),
                       arrival_every_other=False)[0]
    solo = _solo_reference(params, kstate, req)
    eos = solo[2]
    stop_at = solo.index(eos) + 1
    eng = InferenceEngine(CFG, params, kstate, max_slots=2, max_len=MAX_LEN)
    out = eng.run([dataclasses.replace(req, eos_id=eos, output=[])])
    assert out[req.uid] == solo[:stop_at]


def test_submit_validation(model):
    params, kstate = model
    eng = InferenceEngine(CFG, params, kstate, max_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=[1] * 12, max_new_tokens=8))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=1, prompt=[], max_new_tokens=4))


# ---------------------------------------------------------------------------
# Sampling unit tests
# ---------------------------------------------------------------------------
def _sample(logits, sp: SamplingParams, uid=0, idx=0):
    return int(sample_tokens(
        request_key(sp, uid, idx)[None], jnp.asarray(logits)[None],
        jnp.asarray([sp.temperature], jnp.float32),
        jnp.asarray([sp.top_k], jnp.int32),
        jnp.asarray([sp.top_p], jnp.float32))[0])


def test_sampling_greedy_and_degenerate_filters():
    rng = np.random.RandomState(0)
    logits = rng.randn(64).astype(np.float32)
    best = int(np.argmax(logits))
    assert _sample(logits, SamplingParams()) == best
    assert _sample(logits, SamplingParams(temperature=1.3, top_k=1)) == best
    assert _sample(logits, SamplingParams(temperature=1.3,
                                          top_p=1e-6)) == best


def test_sampling_topk_support_and_determinism():
    rng = np.random.RandomState(1)
    logits = rng.randn(64).astype(np.float32)
    top3 = set(np.argsort(-logits)[:3].tolist())
    sp = SamplingParams(temperature=1.0, top_k=3, seed=7)
    draws = {_sample(logits, sp, idx=i) for i in range(40)}
    assert draws <= top3 and len(draws) > 1
    assert _sample(logits, sp, idx=5) == _sample(logits, sp, idx=5)


def test_sampling_heterogeneous_rows_vectorized():
    """One call, per-row settings: greedy row + filtered stochastic row."""
    rng = np.random.RandomState(2)
    logits = rng.randn(2, 32).astype(np.float32)
    keys = jnp.stack([request_key(SamplingParams(seed=0), 0, 0),
                      request_key(SamplingParams(seed=1), 1, 0)])
    toks = sample_tokens(keys, jnp.asarray(logits),
                         jnp.asarray([0.0, 1.0], jnp.float32),
                         jnp.asarray([0, 4], jnp.int32),
                         jnp.asarray([1.0, 0.95], jnp.float32))
    assert int(toks[0]) == int(np.argmax(logits[0]))
    assert int(toks[1]) in set(np.argsort(-logits[1])[:4].tolist())


# ---------------------------------------------------------------------------
# Family coverage: the engine reuses every family's cache unchanged
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_hybrid_family(model):
    cfg = ModelConfig(name="eng-h", family="hybrid", num_layers=3,
                      d_model=64, num_heads=4, num_kv_heads=1, d_ff=128,
                      vocab_size=64, attention="local", attn_window=8,
                      hybrid_pattern=("rglru", "rglru", "attn"),
                      dtype="float32")
    params, kstate = init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(5)
    reqs = [Request(uid=i, prompt=rng.randint(0, 64, size=6 + 2 * i).tolist(),
                    max_new_tokens=4 + i) for i in range(3)]
    eng = InferenceEngine(cfg, params, kstate, max_slots=2, max_len=32)
    out = eng.run(reqs)

    step = jax.jit(make_serve_step(cfg))
    for r in reqs:
        cache = init_cache(cfg, 1, 32)
        lg, cache = prefill(
            params, kstate, cache,
            {"tokens": jnp.asarray(r.prompt, jnp.int32)[None]}, cfg)
        toks = [int(jnp.argmax(lg[0, -1]))]
        pos = r.prompt_len
        while len(toks) < r.max_new_tokens:
            lg1, cache = step(params, kstate, cache,
                              jnp.asarray([toks[-1]], jnp.int32),
                              jnp.asarray([pos], jnp.int32))
            toks.append(int(jnp.argmax(lg1[0])))
            pos += 1
        assert out[r.uid] == toks, r.uid
