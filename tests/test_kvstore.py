"""Tiered KV store: bit-exact park/resume round trips (host + disk,
compacted cluster pages), prefix-cache behavior, and pool-write
validation (the read/write_slot satellite).

The bit-exactness contract is the load-bearing one: a resumed lane must
be byte-identical to the parked lane, leaf for leaf, or the engine's
park/resume decode parity (tests/test_engine.py) silently degrades into
a numerics lottery.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.engine import init_pool, read_slot, write_slot
from repro.serve.kvstore import KVStore, PrefixCache, StoreConfig
from repro.serve.serving import init_cache, prefill

CFG = ModelConfig(name="kvs", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  attention="local+routing",
                  routing=RoutingConfig(num_clusters=4, local_window=8),
                  dtype="float32")
MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    return init_model(CFG, jax.random.PRNGKey(0))


def _prefilled_lane(model, n=11, max_len=MAX_LEN, cfg=CFG):
    params, kstate = model
    lane = init_cache(cfg, 1, max_len)
    toks = jnp.arange(n, dtype=jnp.int32)[None] % cfg.vocab_size
    _, lane = prefill(params, kstate, lane, {"tokens": toks}, cfg)
    return lane


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, la), (_, lb) in zip(fa, fb):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype, pa
        assert np.array_equal(la, lb), jax.tree_util.keystr(pa)


# ---------------------------------------------------------------------------
# Host-tier round trips
# ---------------------------------------------------------------------------
def test_park_resume_roundtrip_bitexact(model):
    """Park -> resume reproduces every leaf byte-identically, including
    compacted cluster pages re-expanded against their rlen tables."""
    lane = _prefilled_lane(model)
    store = KVStore()
    store.park(7, lane)
    assert 7 in store and len(store) == 1
    back = store.resume(7)
    _assert_tree_equal(lane, back)
    assert 7 not in store and len(store) == 0


def test_page_compaction_shrinks_short_sessions(model):
    """A short prompt occupies a fraction of the cluster-page capacity;
    the parked footprint must reflect that, and disabling compaction must
    store the full lane."""
    lane = _prefilled_lane(model, n=6)
    full_bytes = sum(np.asarray(x).nbytes
                     for x in jax.tree_util.tree_leaves(lane))
    compact = KVStore().park(1, lane)
    assert compact.nbytes < full_bytes
    raw = KVStore(StoreConfig(compact_pages=False)).park(1, lane)
    assert raw.nbytes == full_bytes
    # and the uncompacted round trip is bit-exact too
    store = KVStore(StoreConfig(compact_pages=False))
    store.park(2, lane)
    _assert_tree_equal(lane, store.resume(2))


def test_park_duplicate_and_resume_missing_raise(model):
    lane = _prefilled_lane(model)
    store = KVStore()
    store.park(1, lane)
    with pytest.raises(ValueError, match="already parked"):
        store.park(1, lane)
    with pytest.raises(KeyError):
        store.resume(99)
    store.drop(1)
    assert 1 not in store


# ---------------------------------------------------------------------------
# Disk tier
# ---------------------------------------------------------------------------
def test_disk_spill_roundtrip_bitexact(model, tmp_path):
    """host_bytes_limit=1 forces every park straight to disk; the resumed
    lane is still byte-identical (uint8-view storage is dtype-proof) and
    the spill file is reclaimed."""
    lane = _prefilled_lane(model)
    store = KVStore(StoreConfig(spill_dir=str(tmp_path), host_bytes_limit=1))
    store.park(3, lane)
    spilled = list(tmp_path.glob("kv_session_*.blob"))
    assert len(spilled) == 1
    assert store.stats()["kvstore/spills"] == 1.0
    _assert_tree_equal(lane, store.resume(3))
    assert list(tmp_path.glob("kv_session_*.blob")) == []


def test_spill_is_lru_and_respects_limit(model, tmp_path):
    """Oldest parked session spills first once the host tier overflows."""
    lane = _prefilled_lane(model)
    nbytes = KVStore().park(0, lane).nbytes
    store = KVStore(StoreConfig(spill_dir=str(tmp_path),
                                host_bytes_limit=2 * nbytes))
    for uid in (1, 2):
        store.park(uid, lane)
    assert store.stats()["kvstore/spills"] == 0.0
    store.park(3, lane)                     # overflows: uid 1 spills
    assert store._sessions[1].spill_path is not None
    assert store._sessions[2].spill_path is None
    assert store.host_bytes <= 2 * nbytes
    for uid in (1, 2, 3):
        _assert_tree_equal(lane, store.resume(uid))


def test_over_limit_without_spill_dir_raises(model):
    lane = _prefilled_lane(model)
    store = KVStore(StoreConfig(host_bytes_limit=1))
    with pytest.raises(RuntimeError, match="spill_dir"):
        store.park(1, lane)


# ---------------------------------------------------------------------------
# Prefix cache
# ---------------------------------------------------------------------------
def test_prefix_cache_exact_hit_and_lru(model):
    lane = _prefilled_lane(model)
    row = np.zeros((1, CFG.vocab_size), np.float32)
    pc = PrefixCache(capacity=2)
    assert pc.get([1, 2, 3]) is None                # miss counted
    pc.put([1, 2, 3], lane, row)
    hit = pc.get([1, 2, 3])
    assert hit is not None
    _assert_tree_equal(lane, hit[0])
    assert pc.get([1, 2]) is None                   # prefix != exact key
    pc.put([4], lane, row)
    pc.get([1, 2, 3])                               # refresh LRU order
    pc.put([5], lane, row)                          # evicts [4]
    assert pc.get([4]) is None and pc.get([5]) is not None
    assert 0.0 < pc.hit_rate < 1.0
    # entries are read-only: a consumer cannot corrupt the shared pages
    leaf = jax.tree_util.tree_leaves(hit[0])[0]
    with pytest.raises(ValueError):
        leaf[...] = 0


# ---------------------------------------------------------------------------
# write_slot / read_slot validation (satellite)
# ---------------------------------------------------------------------------
def test_write_slot_rejects_wrong_max_len(model):
    pool = init_pool(CFG, 2, MAX_LEN)
    short = _prefilled_lane(model, n=5, max_len=MAX_LEN // 2)
    with pytest.raises(ValueError, match="max_len|trailing"):
        write_slot(pool, 0, short)


def test_write_slot_rejects_dtype_mismatch(model):
    """A bf16 lane into an fp32 pool used to be silently .astype-cast;
    it must now raise before the jitted update."""
    pool = init_pool(CFG, 2, MAX_LEN)
    lane = _prefilled_lane(model)
    wrong = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if x.dtype == jnp.float32 else x, lane)
    with pytest.raises(ValueError, match="dtype"):
        write_slot(pool, 0, wrong)


def test_write_slot_rejects_non_single_lane_and_structure(model):
    pool = init_pool(CFG, 2, MAX_LEN)
    lane = _prefilled_lane(model)
    wide = jax.tree.map(lambda x: np.concatenate([np.asarray(x)] * 2, 1),
                        lane)
    with pytest.raises(ValueError, match="B=1"):
        write_slot(pool, 0, wide)
    broken = [{g: {k: v for k, v in leaves.items() if k != "rlen"}
               for g, leaves in seg.items()} for seg in lane]
    with pytest.raises(ValueError, match="structure"):
        write_slot(pool, 0, broken)


def test_slot_index_bounds_checked(model):
    pool = init_pool(CFG, 2, MAX_LEN)
    lane = _prefilled_lane(model)
    with pytest.raises(ValueError, match="out of range"):
        write_slot(pool, 2, lane)
    with pytest.raises(ValueError, match="out of range"):
        read_slot(pool, -1)


def test_valid_write_still_works_and_roundtrips(model):
    pool = init_pool(CFG, 2, MAX_LEN)
    lane = _prefilled_lane(model)
    pool = write_slot(pool, 1, lane)
    _assert_tree_equal(lane, read_slot(pool, 1))
