"""Per-arch smoke tests: reduced same-family config, one forward + one
train step on CPU, asserting output shapes and no NaNs. The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config, with_routing
from repro.configs.base import RunConfig, TrainConfig, with_overrides
from repro.models.model import init_model, apply_model
from repro.train.train_step import init_train_state, make_train_step

pytestmark = pytest.mark.slow        # full-family train/forward integration

ASSIGNED = [a for a in ARCHS if not a.startswith("rt-")]
B, S = 2, 64


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {"tokens": jax.random.randint(ks[0], (B, S + 1), 0,
                                          cfg.vocab_size)}
    if cfg.family == "encoder":
        batch["features"] = jax.random.normal(ks[1], (B, S + 1, cfg.d_model),
                                              jnp.dtype(cfg.dtype))
        batch["mask_spans"] = jax.random.bernoulli(ks[2], 0.2, (B, S + 1))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[3], (B, cfg.num_image_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params, kstate = init_model(cfg, key)
    batch = _batch(cfg, key)
    fwd = {k: (v[:, :S] if v.ndim >= 2 and v.shape[1] == S + 1 else v)
           for k, v in batch.items()}
    logits, _, _ = apply_model(params, kstate, fwd, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    real = logits[..., :cfg.vocab_size]
    assert bool(jnp.isfinite(real).all()), f"{arch}: non-finite logits"
    if cfg.padded_vocab != cfg.vocab_size:      # pad rows masked out
        assert float(logits[..., cfg.vocab_size:].max()) <= -1e8


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=B, seq_len=S, lr=1e-3, schedule="const",
        warmup_steps=1, remat="full"))
    ts = init_train_state(run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(run))
    ts2, metrics = step(ts, _batch(cfg, jax.random.PRNGKey(1)))
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(ts2.step) == 1
    from conftest import tree_maxdiff
    assert tree_maxdiff(ts2.params, ts.params) > 0.0, \
        f"{arch}: params did not update"


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2-0.5b"])
def test_routing_enabled_variant(arch):
    """The paper's technique as a first-class switch on a dense arch."""
    cfg = with_routing(reduced_config(arch))
    params, kstate = init_model(cfg, key := jax.random.PRNGKey(0))
    batch = _batch(cfg, key)
    fwd = {k: (v[:, :S] if v.ndim >= 2 and v.shape[1] == S + 1 else v)
           for k, v in batch.items()}
    logits, nk, _ = apply_model(params, kstate, fwd, cfg)
    assert bool(jnp.isfinite(logits).all())
    from conftest import tree_maxdiff
    assert tree_maxdiff(nk, kstate) > 0.0, "centroids did not update"


def test_full_configs_instantiate_without_alloc():
    """Full configs build segment plans + param-count sanity (no arrays)."""
    expected = {"granite-8b": 8.0e9, "llama4-maverick-400b-a17b": 390e9,
                "mamba2-780m": 0.7e9, "hubert-xlarge": 0.9e9}
    from repro.models.transformer import build_segments
    for arch in ASSIGNED:
        cfg = get_config(arch)
        segs = build_segments(cfg)
        n_layers = sum(len(p) * g for p, g in segs)
        assert n_layers == cfg.num_layers, (arch, n_layers)
        if arch in expected:
            assert cfg.param_count() >= expected[arch]
