"""Hypothesis property tests on system invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install -e .[property]")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.kmeans import cluster_scores, init_kmeans, normalize_routing
from repro.core.routing import balanced_topk

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


@given(n=st.integers(8, 64), k=st.integers(1, 8), seed=st.integers(0, 99))
def test_balanced_topk_invariants(n, k, seed):
    """Indices sorted ascending, in range, exactly w per centroid, unique."""
    w = max(1, n // k)
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.randn(1, 1, n, k))
    idx = np.asarray(balanced_topk(scores, w))
    assert idx.shape == (1, 1, k, w)
    assert (idx >= 0).all() and (idx < n).all()
    assert (np.diff(idx, axis=-1) > 0).all()        # sorted & unique


@given(seed=st.integers(0, 99), d=st.sampled_from([8, 16, 32]))
def test_normalized_vectors_argmax_is_nearest(seed, d):
    """On the (scaled) unit ball, argmax dot == argmin euclidean distance
    (the MIPS <-> NNS equivalence, paper eq. 10-12)."""
    rng = np.random.RandomState(seed)
    r = normalize_routing(jnp.asarray(rng.randn(1, 1, 16, d)))
    mu = normalize_routing(jnp.asarray(rng.randn(1, 1, 4, d)))[0, 0]
    mu = mu[None]                                    # (1,4,d) same norm
    s = cluster_scores(r, mu)
    by_dot = np.asarray(jnp.argmax(s, -1))[0, 0]
    dists = np.linalg.norm(np.asarray(r)[0, 0][:, None]
                           - np.asarray(mu)[0][None], axis=-1)
    by_dist = dists.argmin(-1)
    assert (by_dot == by_dist).all()


@given(seed=st.integers(0, 99), n=st.integers(2, 6))
def test_online_softmax_merge_associative(seed, n):
    """Flash (m, l, acc) merge over arbitrary chunkings == full softmax."""
    rng = np.random.RandomState(seed)
    logits = rng.randn(n * 8).astype(np.float32) * 3
    vals = rng.randn(n * 8, 4).astype(np.float32)
    full = (np.exp(logits - logits.max())
            / np.exp(logits - logits.max()).sum()) @ vals

    m, l, acc = -np.inf, 0.0, np.zeros(4)
    for c in range(n):
        sl = slice(c * 8, (c + 1) * 8)
        mc = logits[sl].max()
        m_new = max(m, mc)
        p = np.exp(logits[sl] - m_new)
        corr = np.exp(m - m_new) if np.isfinite(m) else 0.0
        l = l * corr + p.sum()
        acc = acc * corr + p @ vals[sl]
        m = m_new
    np.testing.assert_allclose(acc / l, full, rtol=1e-5, atol=1e-6)


@given(seed=st.integers(0, 99), scale=st.floats(1e-3, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    """|x - dequant(quant(x))| <= max|x| / 254 elementwise."""
    _compression = pytest.importorskip(
        "repro.dist.compression", reason="repro.dist is not part of this build")
    _quant, _dequant = _compression._quant, _compression._dequant
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(64).astype(np.float32) * scale)
    q, s = _quant(x)
    err = jnp.abs(x - _dequant(q, s))
    bound = jnp.max(jnp.abs(x)) / 254.0 + 1e-6
    assert float(err.max()) <= float(bound) * 1.01


@given(seed=st.integers(0, 49))
def test_routing_output_permutation_equivariance(seed):
    """Permuting batch rows permutes outputs (no cross-example leakage)."""
    from repro.configs.base import RoutingConfig
    from repro.core.routing import routed_attention
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(3, 2, 32, 8).astype(np.float32))
    v = jnp.asarray(rng.randn(3, 2, 32, 8).astype(np.float32))
    stt = init_kmeans(jax.random.PRNGKey(seed), 2, 4, 8)
    cfg = RoutingConfig(num_clusters=4)
    out = routed_attention(q, None, v, stt, cfg).out
    perm = jnp.array([2, 0, 1])
    out_p = routed_attention(q[perm], None, v[perm], stt, cfg).out
    assert float(jnp.abs(out[perm] - out_p).max()) < 1e-5


@given(seed=st.integers(0, 49), w=st.sampled_from([8, 16]))
def test_local_attention_receptive_field(seed, w):
    """Output at position i depends only on inputs in blocks b-1, b."""
    from repro.core.local import local_attention
    rng = np.random.RandomState(seed)
    N = 64
    q = jnp.asarray(rng.randn(1, 1, N, 8).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 1, N, 8).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 1, N, 8).astype(np.float32))
    o1 = local_attention(q, k, v, window=w, causal=True)
    i = N - 1                                   # last token, block b
    lo = (i // w - 1) * w                       # start of block b-1
    # perturb everything strictly before lo: output at i must not change
    k2 = k.at[:, :, :lo].set(0.0)
    v2 = v.at[:, :, :lo].set(0.0)
    o2 = local_attention(q, k2, v2, window=w, causal=True)
    assert float(jnp.abs(o1[:, :, i] - o2[:, :, i]).max()) < 1e-5


@given(vocab=st.sampled_from([32, 64]), seed=st.integers(0, 20))
def test_lm_loss_uniform_logits(vocab, seed):
    """Uniform logits -> loss == log(vocab)."""
    from repro.models.model import lm_loss
    rng = np.random.RandomState(seed)
    logits = jnp.zeros((2, 8, vocab))
    targets = jnp.asarray(rng.randint(0, vocab, (2, 8)))
    loss, _ = lm_loss(logits, targets)
    assert abs(float(loss) - np.log(vocab)) < 1e-5
