"""Checkpointing & fault tolerance: atomic commit, bit-exact restart,
preemption, straggler accounting, torn-save recovery."""
import os
import shutil
import signal
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tree_maxdiff
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                TrainConfig)
from repro.data.synthetic import SyntheticLoader
from repro.train.trainer import Trainer

CFG = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                  d_ff=64, vocab_size=64, attention="local+routing",
                  routing=RoutingConfig(num_clusters=2, local_window=8),
                  dtype="float32")
RUN = RunConfig(model=CFG, train=TrainConfig(global_batch=4, seq_len=32,
                                             steps=9, lr=1e-3,
                                             schedule="const",
                                             warmup_steps=1))


def _loader():
    return SyntheticLoader("markov", 64, 4, 32)


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"a": jnp.arange(6.0).reshape(2, 3),
             "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr.save(3, state, extra={"loader": {"step": 7, "seed": 0}})
    restored, extra = mgr.restore(state)
    assert tree_maxdiff(state, restored) == 0.0
    assert extra["loader"]["step"] == 7


@pytest.mark.slow
def test_restart_bit_exact(tmp_path):
    t_full = Trainer(RUN, _loader(), ckpt_dir=str(tmp_path / "a"),
                     ckpt_every=3)
    t_full.fit(9)
    t_int = Trainer(RUN, _loader(), ckpt_dir=str(tmp_path / "b"),
                    ckpt_every=3)
    t_int.fit(5)
    t_res = Trainer(RUN, _loader(), ckpt_dir=str(tmp_path / "b"),
                    ckpt_every=3)
    t_res.fit(9)
    assert tree_maxdiff(t_full.state.params, t_res.state.params) == 0.0
    assert tree_maxdiff(t_full.state.kstate, t_res.state.kstate) == 0.0
    assert tree_maxdiff(t_full.state.opt_state["m"],
                        t_res.state.opt_state["m"]) == 0.0


def test_torn_save_ignored(tmp_path):
    """A .tmp directory (simulated crash mid-save) is invisible & cleaned."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((2,))}
    mgr.save(1, state)
    torn = tmp_path / "step_00000002.tmp"
    os.makedirs(torn)
    with open(torn / "arrays.npz", "w") as f:
        f.write("garbage")
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(state)
    assert tree_maxdiff(state, restored) == 0.0
    mgr.save(3, state)      # triggers gc of .tmp
    assert not os.path.exists(torn)


def test_keep_limit(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((3,))})


@pytest.mark.slow
def test_preemption_checkpoints_and_stops(tmp_path):
    tr = Trainer(RUN, _loader(), ckpt_dir=str(tmp_path), ckpt_every=100)
    tr.init_or_restore()
    # simulate a preemption notice after the 2nd step via the handler path
    orig = tr.step_fn
    count = {"n": 0}

    def step_and_preempt(state, batch):
        count["n"] += 1
        if count["n"] == 2:
            tr._preempted = True
        return orig(state, batch)

    tr.step_fn = step_and_preempt
    out = tr.fit(9)
    assert out["preempted"] and out["steps"] == 2
    assert tr.mgr.latest_step() == 2        # work saved at preemption
    # resume completes the run
    tr2 = Trainer(RUN, _loader(), ckpt_dir=str(tmp_path), ckpt_every=100)
    out2 = tr2.fit(9)
    assert out2["steps"] == 9 and not out2["preempted"]


def test_straggler_detection():
    import time
    tr = Trainer(RUN, _loader(), ckpt_dir=None, straggler_factor=1.5)
    tr.init_or_restore()
    orig = tr.step_fn
    count = {"n": 0}
    flagged = []
    tr.on_straggler = lambda step, ratio: flagged.append((step, ratio))

    def slow_step(state, batch):
        count["n"] += 1
        out = orig(state, batch)
        jax.block_until_ready(out[0].params)
        if count["n"] == 8:
            time.sleep(1.0)         # inject a straggler
        return out

    tr.step_fn = slow_step
    tr.fit(9)
    assert tr.straggler_count >= 1 and flagged


def test_ef_state_checkpoint_roundtrip(tmp_path):
    """The error-feedback residual rides in TrainState and must survive
    save/restore bit-exactly (it is optimizer-adjacent state: dropping it
    re-introduces the compression bias it exists to cancel)."""
    from repro.train.train_step import TrainState, init_train_state
    run = RunConfig(model=CFG, train=TrainConfig(
        global_batch=4, seq_len=32, grad_compression="int8_ef"))
    ts = init_train_state(run, jax.random.PRNGKey(0))
    assert ts.ef_state is not None
    # recognizable nonzero residuals (a fresh init would also be zeros)
    ts = ts._replace(ef_state=jax.tree.map(
        lambda e: e + 0.25, ts.ef_state))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, ts._asdict())
    restored, _ = mgr.restore(ts._asdict())
    ts2 = TrainState(**restored)
    assert tree_maxdiff(ts.ef_state, ts2.ef_state) == 0.0
    assert tree_maxdiff(ts.params, ts2.params) == 0.0


def test_ef_state_warm_start_from_uncompressed_ckpt(tmp_path):
    """Turning compression ON mid-run: a checkpoint saved without
    ef_state restores into a compression-enabled state with zero
    residuals instead of failing (zero is always a valid EF restart)."""
    from repro.train.train_step import TrainState, init_train_state
    run_f = RunConfig(model=CFG, train=TrainConfig(global_batch=4,
                                                   seq_len=32))
    ts_f = init_train_state(run_f, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, ts_f._asdict())
    run_c = RunConfig(model=CFG, train=TrainConfig(
        global_batch=4, seq_len=32, grad_compression="int8_ef"))
    ts_c = init_train_state(run_c, jax.random.PRNGKey(0))
    restored, _ = mgr.restore(ts_c._asdict())
    ts2 = TrainState(**restored)
    assert tree_maxdiff(ts_f.params, ts2.params) == 0.0
    assert all(float(jnp.abs(e).max()) == 0.0
               for e in jax.tree.leaves(ts2.ef_state))


@pytest.mark.slow
def test_restart_bit_exact_compressed(tmp_path):
    """The fault-tolerance contract holds with int8_ef compression on:
    interrupted-and-resumed == uninterrupted, bit for bit, including the
    error-feedback residual threading through the checkpoint."""
    run = RunConfig(model=CFG, train=TrainConfig(
        global_batch=4, seq_len=32, steps=9, lr=1e-3, schedule="const",
        warmup_steps=1, grad_compression="int8_ef"))
    t_full = Trainer(run, _loader(), ckpt_dir=str(tmp_path / "a"),
                     ckpt_every=3)
    t_full.fit(9)
    t_int = Trainer(run, _loader(), ckpt_dir=str(tmp_path / "b"),
                    ckpt_every=3)
    t_int.fit(5)
    t_res = Trainer(run, _loader(), ckpt_dir=str(tmp_path / "b"),
                    ckpt_every=3)
    t_res.fit(9)
    assert tree_maxdiff(t_full.state.params, t_res.state.params) == 0.0
    assert tree_maxdiff(t_full.state.ef_state, t_res.state.ef_state) == 0.0


def test_legacy_tuple_checkpoint_restores(tmp_path):
    """Checkpoints written before the field-named format (bare TrainState
    tuple, index-keyed leaves) still resume via the Trainer fallback."""
    from repro.train.train_step import init_train_state
    ts = init_train_state(RUN, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, ts, extra={"loader": {"step": 4, "seed": 0}})  # bare tuple
    tr = Trainer(RUN, _loader(), ckpt_dir=str(tmp_path))
    restored = tr.init_or_restore()
    assert tree_maxdiff(ts.params, restored.params) == 0.0
    assert int(restored.step) == int(ts.step)
    # a legacy checkpoint can never hold an ef residual: enabling
    # compression on resume gets fresh zeros, not a crash
    run_c = RunConfig(model=CFG, train=TrainConfig(
        global_batch=4, seq_len=32, grad_compression="int8_ef"))
    tr_c = Trainer(run_c, _loader(), ckpt_dir=str(tmp_path))
    restored_c = tr_c.init_or_restore()
    assert tree_maxdiff(ts.params, restored_c.params) == 0.0
    assert all(float(jnp.abs(e).max()) == 0.0
               for e in jax.tree.leaves(restored_c.ef_state))


def test_elastic_restore_across_shardings(tmp_path):
    """Restore re-shards onto a different sharding (elastic mesh change).
    On 1 CPU device we exercise the device_put path with two distinct
    single-device shardings; the multi-device path is covered in
    test_dist.py via subprocess."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh1 = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state)
    sh = {"w": NamedSharding(mesh1, P("data", None))}
    restored, _ = mgr.restore(state, shardings=sh)
    assert tree_maxdiff(state, restored) == 0.0
    assert restored["w"].sharding == sh["w"]
