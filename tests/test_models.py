"""Model components: SSD vs naive recurrence, RG-LRU scan vs naive, MoE
dispatch equivalence + capacity semantics, segment construction."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, RoutingConfig, with_overrides
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.transformer import build_segments, head_split, LayerSpec

KEY = jax.random.PRNGKey(11)


class TestSSD:
    @pytest.mark.parametrize("S,chunk", [(64, 16), (100, 32), (32, 32),
                                         (48, 64)])
    def test_chunked_equals_naive(self, S, chunk):
        B, H, P, N = 2, 3, 8, 16
        ks = jax.random.split(KEY, 4)
        xh = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[0], (B, S, N)) * 0.5
        y1, s1 = ssm_mod.ssd_chunked(xh, dt, A, Bm, Cm, chunk)
        y2, s2 = ssm_mod.ssd_naive(xh, dt, A, Bm, Cm)
        assert float(jnp.abs(y1 - y2).max()) < 1e-3
        assert float(jnp.abs(s1 - s2).max()) < 1e-3

    def test_state_carries_across_calls(self):
        """chunked(x[0:S]) == chunked(x[:S/2]) then chunked(x[S/2:], state)."""
        B, S, H, P, N = 1, 64, 2, 8, 16
        ks = jax.random.split(KEY, 4)
        xh = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[0], (B, S, N)) * 0.5
        y_all, _ = ssm_mod.ssd_chunked(xh, dt, A, Bm, Cm, 16)
        y1, st = ssm_mod.ssd_chunked(xh[:, :32], dt[:, :32], A, Bm[:, :32],
                                     Cm[:, :32], 16)
        y2, _ = ssm_mod.ssd_chunked(xh[:, 32:], dt[:, 32:], A, Bm[:, 32:],
                                    Cm[:, 32:], 16, init_state=st)
        err = float(jnp.abs(jnp.concatenate([y1, y2], 1) - y_all).max())
        assert err < 1e-3

    def test_gradients_finite(self):
        cfg = ModelConfig(family="ssm", d_model=32, ssm_state=8,
                          ssm_chunk=16, dtype="float32")
        p = ssm_mod.init_ssd(KEY, cfg)
        x = jax.random.normal(KEY, (2, 48, 32))

        def f(p):
            y, _ = ssm_mod.apply_ssd(p, x, cfg)
            return jnp.sum(y ** 2)

        g = jax.grad(f)(p)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.isfinite(leaf).all())


class TestRGLRU:
    @pytest.mark.parametrize("S", [16, 64, 100])
    def test_scan_equals_naive(self, S):
        B, w = 2, 8
        ks = jax.random.split(KEY, 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, w)))
        b = jax.random.normal(ks[1], (B, S, w))
        h1 = rglru_mod.rglru_scan(a, b)
        h2 = rglru_mod.rglru_naive(a, b)
        assert float(jnp.abs(h1 - h2).max()) < 1e-4

    def test_initial_state(self):
        B, S, w = 1, 32, 4
        ks = jax.random.split(KEY, 3)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, w)))
        b = jax.random.normal(ks[1], (B, S, w))
        h0 = jax.random.normal(ks[2], (B, w))
        h1 = rglru_mod.rglru_scan(a, b, h0)
        h2 = rglru_mod.rglru_naive(a, b, h0)
        assert float(jnp.abs(h1 - h2).max()) < 1e-4

    def test_decay_bounded(self):
        cfg = ModelConfig(d_model=16, lru_width=16, dtype="float32")
        p = rglru_mod.init_rglru(KEY, cfg)
        u = jax.random.normal(KEY, (2, 8, 16))
        a, _ = rglru_mod._gates(p, u)
        assert float(a.min()) > 0.0 and float(a.max()) < 1.0


class TestMoE:
    def _cfg(self, cf=8.0):
        return ModelConfig(family="moe", d_model=32, d_ff=64, moe_experts=4,
                           moe_capacity_factor=cf, dtype="float32")

    def test_einsum_equals_scatter(self):
        cfg = self._cfg()
        p = moe_mod.init_moe(KEY, cfg)
        x = jax.random.normal(KEY, (3, 16, 32))
        y1, a1 = moe_mod.apply_moe(p, x, cfg, impl="einsum")
        y2, a2 = moe_mod.apply_moe(p, x, cfg, impl="scatter")
        assert float(jnp.abs(y1 - y2).max()) < 1e-5
        assert abs(float(a1["moe_drop_frac"]) - float(a2["moe_drop_frac"])) \
            < 1e-6

    def test_capacity_drops_counted(self):
        cfg = self._cfg(cf=0.25)        # tiny capacity forces drops
        p = moe_mod.init_moe(KEY, cfg)
        x = jax.random.normal(KEY, (2, 32, 32))
        y, aux = moe_mod.apply_moe(p, x, cfg)
        assert float(aux["moe_drop_frac"]) > 0.0
        assert bool(jnp.isfinite(y).all())

    def test_identical_experts_match_dense(self):
        """If all experts share weights + no drops, MoE == dense MLP*gate+shared."""
        from repro.models.layers import apply_mlp
        cfg = with_overrides(self._cfg(), moe_shared_expert=False)
        p = moe_mod.init_moe(KEY, cfg)
        # tie all experts to expert 0
        for k in ("w_up", "w_gate", "w_down"):
            p[k] = jnp.broadcast_to(p[k][0][None], p[k].shape)
        x = jax.random.normal(KEY, (2, 16, 32))
        y, aux = moe_mod.apply_moe(p, x, cfg)
        mlp = {"w_up": p["w_up"][0], "w_gate": p["w_gate"][0],
               "w_down": p["w_down"][0]}
        ref = apply_mlp(mlp, x, "swiglu")
        logits = x.astype(jnp.float32) @ p["router"]
        gate = jax.nn.softmax(logits, -1).max(-1)
        assert float(jnp.abs(y - ref * gate[..., None]).max()) < 1e-4

    def test_load_balance_loss_uniform_is_one(self):
        """Perfectly uniform routing gives LB loss == 1 (Switch normalizer)."""
        cfg = self._cfg()
        p = moe_mod.init_moe(KEY, cfg)
        p["router"] = jnp.zeros_like(p["router"])   # uniform probs
        x = jax.random.normal(KEY, (2, 64, 32))
        _, aux = moe_mod.apply_moe(p, x, cfg)
        # f_e concentrates on argmax ties -> allow slack around 1
        assert 0.9 < float(aux["moe_lb_loss"]) < 1.6


class TestSegments:
    def test_dense(self):
        cfg = ModelConfig(family="dense", num_layers=8)
        segs = build_segments(cfg)
        assert len(segs) == 1 and segs[0][1] == 8

    def test_moe_interleave(self):
        cfg = ModelConfig(family="moe", num_layers=6, moe_experts=4,
                          moe_interleave=2)
        segs = build_segments(cfg)
        assert segs[0][0][0].kind == "moe" and segs[0][0][1].kind == "attn"
        assert segs[0][1] == 3

    def test_hybrid_tail(self):
        cfg = ModelConfig(family="hybrid", num_layers=38,
                          hybrid_pattern=("rglru", "rglru", "attn"))
        segs = build_segments(cfg)
        total = sum(len(p) * g for p, g in segs)
        assert total == 38
        assert segs[0][1] == 12 and len(segs[1][0]) == 2   # tail rglru x2

    def test_pg19_routing_suffix(self):
        cfg = ModelConfig(
            family="dense", num_layers=22, attention="local+routing",
            num_heads=8, num_kv_heads=8,
            routing=RoutingConfig(routing_heads=2, routing_layers=(20, 21)))
        segs = build_segments(cfg)
        assert sum(len(p) * g for p, g in segs) == 22
        assert segs[0][0][0].attn == "local" and segs[0][1] == 20
        assert segs[-1][0][0].attn == "local+routing" and segs[-1][1] == 2

    def test_vlm_cross_positions(self):
        cfg = ModelConfig(family="vlm", num_layers=40)
        segs = build_segments(cfg)
        pat = segs[0][0]
        assert [s.kind for s in pat] == ["attn"] * 4 + ["cross"]
        assert segs[0][1] == 8

    def test_head_split_alignment(self):
        cfg = ModelConfig(num_heads=32, num_kv_heads=8,
                          attention="local+routing")
        Hl, Hr, kvl, kvr = head_split(cfg)
        assert Hl + Hr == 32 and kvl + kvr == 8
        cfg1 = ModelConfig(num_heads=16, num_kv_heads=1,
                           attention="local+routing")
        Hl, Hr, kvl, kvr = head_split(cfg1)
        assert kvl == kvr == 1
