"""Paged-decode kernel (routing/pallas_paged) parity + resolution.

The contract under test (kernels/routing_decode.py):

* cache trajectories are BIT-identical to the xla cluster-page decode
  (the paged backend runs the reference's routing + cache-write code);
* greedy token streams are bit-identical over long multi-step decode
  (the only cross-step state is the cache and the argmax token);
* per-step attention outputs / model logits agree to float ulps (exact
  bitwise equality of f32 reductions across differently-compiled
  programs is compiler-dependent — see the kernel docstring);
* garbage in beyond-min(rlen,cap) page slots cannot leak;
* TPU auto-resolution (and the REPRO_ATTN_PLATFORM/REPRO_FORCE_INTERPRET
  forced-interpret path) picks pallas_paged for decode while apply stays
  on pallas_fused.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import attn
from repro.attn import registry
from repro.attn.spec import AttentionSpec
from repro.configs.base import ModelConfig, RoutingConfig as MRoutingConfig
from repro.core.routing import RoutingConfig
from repro.models.model import init_model
from repro.serve.serving import init_cache, make_serve_step, prefill

KEY = jax.random.PRNGKey(0)


def _spec(variant, H=4, dh=64, kc=8, cap=16, window=16):
    rc = RoutingConfig(num_clusters=kc, window=cap)
    if variant == "routing":
        return AttentionSpec(variant="routing", num_heads=H, num_kv_heads=H,
                             head_dim=dh, routing=rc)
    return AttentionSpec(variant="local+routing", num_heads=H,
                         num_kv_heads=H, head_dim=dh, window=window,
                         routing=rc, routing_heads=H // 2)


def _mu(spec, key):
    Hr = (attn.head_split(spec)[1] if spec.variant == "local+routing"
          else spec.num_heads)
    mu = jax.random.normal(key, (Hr, spec.routing.num_clusters,
                                 spec.head_dim), jnp.float32)
    return mu / jnp.linalg.norm(mu, axis=-1, keepdims=True)


def _tree_bitwise(a, b):
    return all(bool((x == y).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("variant", ["routing", "local+routing"])
def test_paged_decode_multi_step_parity(variant):
    """80 decode steps: caches bitwise-equal every step, outputs within
    float ulps, and a fixed linear readout's argmax 'tokens' identical."""
    spec = _spec(variant)
    B, H, dh = 2, spec.num_heads, spec.head_dim
    key = jax.random.PRNGKey(1)
    mu = _mu(spec, key)
    readout = jax.random.normal(jax.random.PRNGKey(2), (H * dh, 256),
                                jnp.float32)
    cache_x = attn.init_decode_cache(spec, B, 256, jnp.float32)
    cache_p = jax.tree.map(lambda x: x, cache_x)
    for t in range(80):
        k1, k2, key = jax.random.split(key, 3)
        q = jax.random.normal(k1, (B, H, 1, dh), jnp.float32)
        v = jax.random.normal(k2, (B, H, 1, dh), jnp.float32)
        pos = jnp.full((B,), t, jnp.int32)
        ox = attn.attend(spec, q, q, v, state=mu, cache=cache_x, pos=pos,
                         impl="xla")
        op = attn.attend(spec, q, q, v, state=mu, cache=cache_p, pos=pos,
                         impl="pallas_paged")
        cache_x, cache_p = ox.cache, op.cache
        assert _tree_bitwise(cache_x, cache_p), f"cache diverged at t={t}"
        d = float(jnp.abs(ox.out - op.out).max())
        assert d <= 1e-5, f"attention out drift {d} at t={t}"
        tok_x = jnp.argmax(ox.out.reshape(B, -1) @ readout, -1)
        tok_p = jnp.argmax(op.out.reshape(B, -1) @ readout, -1)
        assert bool((tok_x == tok_p).all()), f"token flip at t={t}"


@pytest.mark.parametrize("variant", ["routing", "local+routing"])
def test_paged_decode_poisoned_slots_no_leak(variant):
    """Beyond-min(rlen,cap) page slots hold garbage after ring wraps and
    compactions; neither decode path may let it reach the output. Poison
    them with 1e30 (finite, so a leak cannot hide behind NaN*0) and
    demand the poisoned run equals the clean run bit for bit."""
    spec = _spec(variant)
    B, H, dh = 2, spec.num_heads, spec.head_dim
    key = jax.random.PRNGKey(3)
    mu = _mu(spec, key)
    cache = attn.init_decode_cache(spec, B, 256, jnp.float32)
    for t in range(10):          # partially fill: many slots unoccupied
        k1, k2, key = jax.random.split(key, 3)
        q = jax.random.normal(k1, (B, H, 1, dh), jnp.float32)
        v = jax.random.normal(k2, (B, H, 1, dh), jnp.float32)
        cache = attn.attend(spec, q, q, v, state=mu, cache=cache,
                            pos=jnp.full((B,), t, jnp.int32),
                            impl="xla").cache
    cap = cache["rk"].shape[3]
    occ = jnp.minimum(cache["rlen"], cap)[..., None, None]     # (B,Hr,kc,1,1)
    dead = jnp.arange(cap)[None, None, None, :, None] >= occ
    poisoned = dict(cache)
    poisoned["rk"] = jnp.where(dead, 1e30, cache["rk"])
    poisoned["rv"] = jnp.where(dead, 1e30, cache["rv"])
    q = jax.random.normal(jax.random.PRNGKey(4), (B, H, 1, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(5), (B, H, 1, dh), jnp.float32)
    pos = jnp.full((B,), 10, jnp.int32)
    for impl in ("xla", "pallas_paged"):
        o_clean = attn.attend(spec, q, q, v, state=mu, cache=cache,
                              pos=pos, impl=impl)
        o_dirty = attn.attend(spec, q, q, v, state=mu, cache=poisoned,
                              pos=pos, impl=impl)
        assert bool(jnp.isfinite(o_dirty.out).all()), impl
        assert bool((o_clean.out == o_dirty.out).all()), \
            f"{impl}: poisoned slots leaked into the output"


@pytest.mark.parametrize("variant", ["routing", "local+routing"])
def test_decode_resolution_prefers_paged_on_tpu(variant):
    spec = _spec(variant)
    assert attn.decode_backend(spec, platform="tpu").impl == "pallas_paged"
    assert attn.decode_backend(spec, platform="cpu").impl == "xla"
    # the priority-20 tie with pallas_fused breaks toward fused for apply
    # (registration order); paged only owns decode
    assert registry.resolve(spec, seq_len=128, needs_grad=True,
                            platform="tpu").impl == "pallas_fused"
    # same cluster-page cache layout on both decode paths: engines can
    # prefill under one impl and decode under the other
    assert (attn.decode_backend(spec, platform="tpu").layout.name
            == attn.decode_backend(spec, platform="cpu").layout.name)


def test_decode_resolution_mesh_falls_back_to_xla():
    """Like every Pallas backend, pallas_paged declares supports_mesh=
    False: decode under a GSPMD mesh resolves to the reference."""
    class FakeMesh:            # resolve() only reads .size
        size = 2
    spec = _spec("routing")
    assert attn.decode_backend(spec, mesh=FakeMesh(),
                               platform="tpu").impl == "xla"


def test_forced_interpret_env_resolution(monkeypatch):
    """REPRO_ATTN_PLATFORM=tpu + REPRO_FORCE_INTERPRET=1 routes auto
    resolution to the TPU backends in interpret mode on a CPU host."""
    monkeypatch.setenv("REPRO_ATTN_PLATFORM", "tpu")
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    for variant in ("routing", "local+routing"):
        assert attn.decode_backend(_spec(variant)).impl == "pallas_paged"
    monkeypatch.delenv("REPRO_ATTN_PLATFORM")
    assert attn.decode_backend(_spec("routing")).impl == "xla"


def test_model_decode_token_and_logit_parity(monkeypatch):
    """The acceptance gate: a real model decodes greedily for 24 steps
    under forced-interpret TPU resolution (pallas_paged decode) and
    under the default CPU resolution (xla decode) from the same prefill;
    token streams must match exactly, per-step vocab logits to ulps,
    and the cluster-page cache trajectories bit for bit."""
    cfg = ModelConfig(name="pd", family="dense", attention="local+routing",
                      routing=MRoutingConfig(num_clusters=4, local_window=16),
                      num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=64, dtype="float32")
    params, kstate = init_model(cfg, KEY)
    B, TP, steps = 2, 32, 24
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, TP), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, B, max_len=TP + steps + 1)
    lg, cache = prefill(params, kstate, cache, {"tokens": toks}, cfg)
    cache_x = cache
    cache_p = jax.tree.map(lambda x: x, cache)

    step_xla = jax.jit(make_serve_step(cfg))
    monkeypatch.setenv("REPRO_ATTN_PLATFORM", "tpu")
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert attn.decode_backend(
        attn.spec_for_layer(cfg, cfg.attention)).impl == "pallas_paged"
    step_paged = jax.jit(make_serve_step(cfg))

    tok_x = tok_p = lg[:, -1].argmax(-1).astype(jnp.int32)
    for t in range(TP, TP + steps):
        pos = jnp.full((B,), t, jnp.int32)
        lg_x, cache_x = step_xla(params, kstate, cache_x, tok_x, pos)
        lg_p, cache_p = step_paged(params, kstate, cache_p, tok_p, pos)
        d = float(jnp.abs(lg_x - lg_p).max())
        assert d <= 5e-4, f"vocab logit drift {d} at t={t}"
        tok_x = lg_x.argmax(-1).astype(jnp.int32)
        tok_p = lg_p.argmax(-1).astype(jnp.int32)
        assert bool((tok_x == tok_p).all()), f"greedy token flip at t={t}"
        for name in ("rk", "rv", "rlen"):
            a = [l[name] for l in jax.tree.leaves(
                cache_x, is_leaf=lambda x: isinstance(x, dict))
                if isinstance(l, dict) and name in l]
            b = [l[name] for l in jax.tree.leaves(
                cache_p, is_leaf=lambda x: isinstance(x, dict))
                if isinstance(l, dict) and name in l]
            assert all(bool((x == y).all()) for x, y in zip(a, b)), \
                f"page cache {name} diverged at t={t}"
