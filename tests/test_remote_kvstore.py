"""Distributed KV plane: blob codec integrity, transport semantics,
TCP peer round trips, fault injection, remote-tier bit-exactness, async
transfers, and the export/import rail.

The two load-bearing contracts:
  * a remote round trip is bit-exact to the logit — same bytes, same
    dtypes, compacted pages re-expanded identically to a host resume;
  * no fault (transient error, dropped/truncated/corrupted blob,
    unreachable peer) ever loses a parked session: the store degrades
    to the nearer tier, records the degradation, and corruption is
    *detected* (BlobChecksumError) rather than resumed as garbage.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.kvstore import InflightPark, KVStore, StoreConfig
from repro.serve.kvstore.remote import (BlobChecksumError, BlobError,
                                        BlobNotFound,
                                        FaultInjectionTransport,
                                        FileTransport, LoopbackTransport,
                                        RetryPolicy, TCPStoreServer,
                                        TCPTransport, TransportError,
                                        decode_session, encode_session,
                                        with_retries)
from repro.serve.serving import init_cache, prefill

CFG = ModelConfig(name="rkv", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  attention="local+routing",
                  routing=RoutingConfig(num_clusters=4, local_window=8),
                  dtype="float32")
MAX_LEN = 48
FAST = RetryPolicy(attempts=3, base_delay_s=0.001, max_delay_s=0.002)


@pytest.fixture(scope="module")
def model():
    return init_model(CFG, jax.random.PRNGKey(0))


def _prefilled_lane(model, n=11):
    params, kstate = model
    lane = init_cache(CFG, 1, MAX_LEN)
    toks = jnp.arange(n, dtype=jnp.int32)[None] % CFG.vocab_size
    _, lane = prefill(params, kstate, lane, {"tokens": toks}, CFG)
    return lane


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, la), (_, lb) in zip(fa, fb):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype, pa
        assert np.array_equal(la, lb), jax.tree_util.keystr(pa)


# ---------------------------------------------------------------------------
# Blob codec
# ---------------------------------------------------------------------------
def test_blob_roundtrip_bitexact(model):
    lane = _prefilled_lane(model)
    store = KVStore()
    sess = store.park(5, lane)
    blob = encode_session(sess, meta={"pos": 11, "note": "x"})
    back, meta = decode_session(blob)
    assert meta == {"pos": 11, "note": "x"}
    assert back.uid == 5 and back.order == sess.order
    assert back.nbytes == sess.nbytes
    for k in sess.order:
        a, b = sess.leaves[k], back.leaves[k]
        assert a.shape == b.shape and a.page_len_key == b.page_len_key
        assert a.data.dtype == b.data.dtype
        assert np.array_equal(a.data, b.data), k


def test_blob_detects_corruption_and_truncation(model):
    sess = KVStore().park(1, _prefilled_lane(model))
    blob = encode_session(sess)
    for i in (10, len(blob) // 2, len(blob) - 1):
        bad = blob[:i] + bytes([blob[i] ^ 0xFF]) + blob[i + 1:]
        with pytest.raises(BlobChecksumError):
            decode_session(bad)
    with pytest.raises(BlobError):
        decode_session(blob[:len(blob) // 2])
    with pytest.raises(BlobError):
        decode_session(b"")
    with pytest.raises(BlobError):
        # valid CRC over a wrong magic still fails loudly
        import struct
        import zlib
        body = b"XXXX" + blob[4:-4]
        decode_session(body + struct.pack(">I", zlib.crc32(body)))


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------
@pytest.fixture(params=["loopback", "file", "tcp"])
def transport(request, tmp_path):
    if request.param == "loopback":
        yield LoopbackTransport()
    elif request.param == "file":
        yield FileTransport(str(tmp_path / "blobs"))
    else:
        with TCPStoreServer() as server:
            yield TCPTransport(server.host, server.port, retry=FAST)


def test_transport_semantics(transport):
    """put/get/delete/exists/list behave identically on every transport
    (the KV store's remote tier is transport-agnostic by this contract)."""
    assert not transport.exists("a")
    with pytest.raises(BlobNotFound):
        transport.get("a")
    transport.put("a", b"one")
    transport.put("b/1", b"two")
    transport.put("b/2", b"three" * 1000)
    assert transport.exists("a") and transport.get("a") == b"one"
    transport.put("a", b"overwritten")
    assert transport.get("a") == b"overwritten"
    assert transport.list_blobs() == ["a", "b/1", "b/2"]
    assert transport.list_blobs("b/") == ["b/1", "b/2"]
    transport.delete("a")
    assert not transport.exists("a")
    with pytest.raises(BlobNotFound):
        transport.delete("a")
    stats = transport.stats()
    assert stats["transport/puts"] == 4.0
    assert stats["transport/bytes_in"] > 0


def test_tcp_large_blob_roundtrip():
    """Framing holds across many recv() chunks (an 8 MiB blob does not
    fit one socket buffer)."""
    big = np.random.RandomState(0).bytes(8 << 20)
    with TCPStoreServer() as server:
        t = TCPTransport(server.host, server.port, retry=FAST)
        t.put("big", big)
        assert t.get("big") == big


def test_tcp_retry_then_connect():
    """wait_until_ready + retried ops survive a peer that comes up late."""
    srv_box = {}

    def boot():
        srv_box["s"] = TCPStoreServer(port=0)

    with TCPStoreServer() as probe:
        port = probe.port            # a port that is free right after
    timer = threading.Timer(0.2, boot)
    t = TCPTransport("127.0.0.1", port, retry=FAST)
    with pytest.raises(TransportError):
        t.put("x", b"1")             # nobody listening: retries then fails
    assert t.stats()["transport/retries"] >= 2.0
    timer.cancel()


def test_with_retries_policy():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransportError("transient")
        return "ok"

    assert with_retries(flaky, FAST) == "ok"
    assert len(calls) == 3
    with pytest.raises(BlobNotFound):
        # deterministic answers are never retried
        with_retries(lambda: (_ for _ in ()).throw(BlobNotFound("gone")),
                     FAST)


# ---------------------------------------------------------------------------
# Remote tier
# ---------------------------------------------------------------------------
def test_remote_tier_roundtrip_bitexact(model):
    """host_bytes_limit=1 pushes every park through the transport; the
    resumed lane is byte-identical and the remote blob is reclaimed."""
    lane = _prefilled_lane(model)
    t = LoopbackTransport()
    store = KVStore(StoreConfig(host_bytes_limit=1, remote=t))
    store.park(3, lane)
    assert t.list_blobs() == ["spill/3"]
    assert store.stats()["kvstore/remote_parks"] == 1.0
    _assert_tree_equal(lane, store.resume(3))
    assert t.list_blobs() == []
    assert store.stats()["kvstore/remote_resumes"] == 1.0


def test_remote_tier_over_tcp_bitexact(model):
    lane = _prefilled_lane(model)
    with TCPStoreServer() as server:
        t = TCPTransport(server.host, server.port, retry=FAST)
        store = KVStore(StoreConfig(host_bytes_limit=1, remote=t))
        store.park(9, lane)
        assert len(server) == 1
        _assert_tree_equal(lane, store.resume(9))


def test_disk_then_remote_tier_chain(model, tmp_path):
    """disk_bytes_limit pushes the oldest spilled sessions onward to the
    remote tier; every tier still resumes bit-exact."""
    lane = _prefilled_lane(model)
    nbytes = KVStore().park(0, lane).nbytes
    t = LoopbackTransport()
    store = KVStore(StoreConfig(spill_dir=str(tmp_path),
                                host_bytes_limit=2 * nbytes,
                                disk_bytes_limit=nbytes, remote=t))
    for uid in (1, 2, 3, 4):
        store.park(uid, lane)
    # 2 resident, 1 on disk, 1 pushed remote
    tiers = {uid: ("remote" if s.remote_name else
                   "disk" if s.spill_path else "host")
             for uid, s in store._sessions.items()}
    assert sorted(tiers.values()) == ["disk", "host", "host", "remote"]
    assert tiers[1] == "remote"         # oldest went furthest
    for uid in (1, 2, 3, 4):
        _assert_tree_equal(lane, store.resume(uid))


def test_disk_limit_without_remote_rejected():
    with pytest.raises(ValueError, match="remote"):
        KVStore(StoreConfig(spill_dir="/tmp/x", disk_bytes_limit=1))


# ---------------------------------------------------------------------------
# Fault injection: no parked session is ever lost
# ---------------------------------------------------------------------------
def test_remote_put_failure_degrades_to_host(model):
    """A dead transport (fails after retries) keeps the session resident,
    counts + records the degradation, and the resume is bit-exact."""
    lane = _prefilled_lane(model)
    ft = FaultInjectionTransport(LoopbackTransport(), fail_puts=99)
    store = KVStore(StoreConfig(host_bytes_limit=1, remote=ft))
    store.park(4, lane)
    assert 4 in store
    events = store.drain_events()
    assert [e["kind"] for e in events] == ["kvstore_remote_degraded"]
    assert events[0]["uid"] == 4 and events[0]["kept_tier"] == "host"
    assert store.stats()["kvstore/remote_degraded"] == 1.0
    _assert_tree_equal(lane, store.resume(4))


def test_remote_put_failure_degrades_to_disk(model, tmp_path):
    """Disk->remote overflow push fails: the session stays on disk (the
    spill is re-written) and resumes bit-exact."""
    lane = _prefilled_lane(model)
    ft = FaultInjectionTransport(LoopbackTransport(), fail_puts=99)
    store = KVStore(StoreConfig(spill_dir=str(tmp_path), host_bytes_limit=1,
                                disk_bytes_limit=1, remote=ft))
    store.park(5, lane)
    sess = store._sessions[5]
    assert sess.spill_path is not None and sess.remote_name is None
    assert store.drain_events()[0]["kept_tier"] == "disk"
    _assert_tree_equal(lane, store.resume(5))


def test_transient_put_failure_retries_through(model):
    """One transient fault inside the transport's retry budget: the park
    lands remotely with no degradation."""
    lane = _prefilled_lane(model)
    with TCPStoreServer() as server:
        inner = TCPTransport(server.host, server.port, retry=FAST)
        ft = FaultInjectionTransport(inner, fail_puts=1)
        # the store's put goes through ft once; ft fails it, the store
        # degrades. Wrap the fault one level down instead: retry happens
        # above the fault, inside with_retries at the store's disposal.
        store = KVStore(StoreConfig(
            host_bytes_limit=1,
            remote=_RetryingTransport(ft, FAST)))
        store.park(6, lane)
        assert store.stats()["kvstore/remote_degraded"] == 0.0
        assert len(server) == 1
        _assert_tree_equal(lane, store.resume(6))


class _RetryingTransport:
    """Test shim: retries around an inner transport's whole ops (the way
    TCPTransport retries internally around each socket RPC)."""

    def __init__(self, inner, policy):
        self.inner, self.policy = inner, policy

    def put(self, name, data):
        with_retries(lambda: self.inner.put(name, data), self.policy)

    def get(self, name):
        return with_retries(lambda: self.inner.get(name), self.policy)

    def delete(self, name):
        self.inner.delete(name)

    def exists(self, name):
        return self.inner.exists(name)

    def list_blobs(self, prefix=""):
        return self.inner.list_blobs(prefix)


def test_corrupted_remote_blob_detected_never_garbage(model):
    """A corrupted (or truncated) fetched blob raises BlobChecksumError —
    and the session record survives, so a healed transport resumes it."""
    lane = _prefilled_lane(model)
    for fault in ({"corrupt_gets": 1}, {"truncate_gets": 1}):
        ft = FaultInjectionTransport(LoopbackTransport(), **fault)
        store = KVStore(StoreConfig(host_bytes_limit=1, remote=ft))
        store.park(7, lane)
        with pytest.raises((BlobChecksumError, BlobError)):
            store.resume(7)
        assert 7 in store               # not lost
        _assert_tree_equal(lane, store.resume(7))   # fault used up: heals


def test_dropped_put_is_a_loud_miss(model):
    """A transport that acks a put without storing (lost blob): resume
    fails loudly with BlobNotFound, and the session record survives."""
    lane = _prefilled_lane(model)
    ft = FaultInjectionTransport(LoopbackTransport(), drop_puts=1)
    store = KVStore(StoreConfig(host_bytes_limit=1, remote=ft))
    store.park(8, lane)
    with pytest.raises(BlobNotFound):
        store.resume(8)
    assert 8 in store


def test_duplicated_put_is_idempotent(model):
    lane = _prefilled_lane(model)
    ft = FaultInjectionTransport(LoopbackTransport(), duplicate_puts=True)
    store = KVStore(StoreConfig(host_bytes_limit=1, remote=ft))
    store.park(9, lane)
    _assert_tree_equal(lane, store.resume(9))


# ---------------------------------------------------------------------------
# Async transfers
# ---------------------------------------------------------------------------
def test_async_park_returns_inflight_handle(model):
    lane = _prefilled_lane(model)
    store = KVStore(StoreConfig(async_transfers=True))
    h = store.park(1, lane)
    assert isinstance(h, InflightPark) and h.uid == 1
    assert 1 in store
    sess = h.wait(10)
    assert sess.nbytes > 0 and h.nbytes == sess.nbytes
    _assert_tree_equal(lane, store.resume(1))
    store.close()


def test_async_park_resume_immediately_is_safe(model):
    """resume() right after an async park waits for the in-flight
    transfer — no torn lane, bit-exact result."""
    lane = _prefilled_lane(model)
    store = KVStore(StoreConfig(async_transfers=True))
    for uid in range(6):
        store.park(uid, lane)
        _assert_tree_equal(lane, store.resume(uid))
    store.close()


def test_async_with_remote_tier_and_flush(model):
    lane = _prefilled_lane(model)
    t = LoopbackTransport()
    store = KVStore(StoreConfig(host_bytes_limit=1, remote=t,
                                async_transfers=True))
    for uid in range(4):
        store.park(uid, lane)
    store.flush(30)
    assert len(t.list_blobs()) == 4
    for uid in range(4):
        _assert_tree_equal(lane, store.resume(uid))
    store.close()


def test_async_duplicate_park_rejected(model):
    lane = _prefilled_lane(model)
    store = KVStore(StoreConfig(async_transfers=True))
    store.park(1, lane)
    with pytest.raises(ValueError, match="already parked"):
        store.park(1, lane)
    store.close()


def test_prefetch_warms_spilled_session(model, tmp_path):
    lane = _prefilled_lane(model)
    store = KVStore(StoreConfig(spill_dir=str(tmp_path), host_bytes_limit=1))
    store.park(1, lane)
    assert store._sessions[1].spill_path is not None
    h = store.prefetch(1)
    h.wait(10)
    assert store._sessions[1].resident
    assert store.prefetch(1) is None    # already resident: no-op
    _assert_tree_equal(lane, store.resume(1))
    store.close()


# ---------------------------------------------------------------------------
# Export / import (the disaggregation rail)
# ---------------------------------------------------------------------------
def test_export_import_moves_ownership(model):
    lane = _prefilled_lane(model)
    t = LoopbackTransport()
    a = KVStore(StoreConfig(remote=t))
    b = KVStore(StoreConfig(remote=t))
    a.park(11, lane)
    name = a.export(11, meta={"pos": 11, "last_token": 3})
    assert 11 not in a
    uid, meta = b.import_remote(name)
    assert (uid, meta) == (11, {"pos": 11, "last_token": 3})
    assert not t.exists(name)           # consumed
    _assert_tree_equal(lane, b.resume(11))


def test_export_import_over_tcp(model):
    lane = _prefilled_lane(model)
    with TCPStoreServer() as server:
        t = TCPTransport(server.host, server.port, retry=FAST)
        a = KVStore(StoreConfig(remote=t))
        a.park(12, lane)
        name = a.export(12, meta={"k": 1})
        b = KVStore(StoreConfig(
            remote=TCPTransport(server.host, server.port, retry=FAST)))
        uid, meta = b.import_remote(name)
        assert uid == 12 and meta == {"k": 1}
        _assert_tree_equal(lane, b.resume(12))


def test_export_failure_keeps_session(model):
    lane = _prefilled_lane(model)
    ft = FaultInjectionTransport(LoopbackTransport(), fail_puts=99)
    store = KVStore(StoreConfig(remote=ft))
    store.park(13, lane)
    with pytest.raises(TransportError):
        store.export(13)
    assert 13 in store
    _assert_tree_equal(lane, store.resume(13))
