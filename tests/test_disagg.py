"""Disaggregated prefill/decode pools + partial-prefix reuse.

The acceptance contract: splitting a workload across a prefill-pool
engine and a decode-pool engine — sessions shipped between them as
transport blobs — produces token streams *bit-identical* to one
monolithic engine. And partial-prefix reuse (teacher-forced prompt
tails over a cached shorter prefix) is bit-identical to a full prefill
for the layouts it is enabled on, and disabled for cluster-page
layouts, where it would not be.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.engine import InferenceEngine, Request
from repro.serve.kvstore import KVStore, PrefixCache, StoreConfig
from repro.serve.kvstore.remote import (FileTransport, LoopbackTransport,
                                        TCPStoreServer, TCPTransport)
from repro.serve.serving import decode_cache_layouts

ROUTED = ModelConfig(name="dsg", family="dense", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                     attention="local+routing",
                     routing=RoutingConfig(num_clusters=4, local_window=8),
                     dtype="float32")
LOCAL = ModelConfig(name="dsl", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                    attention="local",
                    routing=RoutingConfig(local_window=8),
                    dtype="float32")
MAX_LEN = 48


@pytest.fixture(scope="module")
def routed_model():
    return init_model(ROUTED, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def local_model():
    return init_model(LOCAL, jax.random.PRNGKey(0))


def _mk_requests(n=6, seed=3, vocab=128):
    rng = np.random.RandomState(seed)
    return [Request(uid=u, prompt=rng.randint(0, vocab, size=5 + 2 * u)
                    .tolist(), max_new_tokens=4 + (u % 3))
            for u in range(n)]


def _monolithic(cfg, model, reqs):
    params, kstate = model
    eng = InferenceEngine(cfg, params, kstate, max_slots=2, max_len=MAX_LEN)
    out = eng.run(reqs)
    eng.close()
    return out


def _disaggregate(cfg, model, reqs, make_transport):
    """Prefill pool -> transport blobs -> decode pool."""
    params, kstate = model
    pre = InferenceEngine(cfg, params, kstate, max_slots=2, max_len=MAX_LEN,
                          kvstore=KVStore(StoreConfig(
                              remote=make_transport())),
                          prefill_only=True)
    for r in reqs:
        pre.submit(r)
    while pre.has_work():
        pre.step()
    names = [pre.export_session(r.uid) for r in reqs]
    assert all(r.state == "EXPORTED" for r in reqs)
    pre.close()
    dec = InferenceEngine(cfg, params, kstate, max_slots=2, max_len=MAX_LEN,
                          kvstore=KVStore(StoreConfig(
                              remote=make_transport(),
                              async_transfers=True)))
    handles = [dec.import_session(n) for n in names]
    while dec.has_work():
        dec.step()
    dec.close()
    return {h.uid: h.output for h in handles}


# ---------------------------------------------------------------------------
# Disaggregation parity (the tentpole's acceptance test)
# ---------------------------------------------------------------------------
def test_disagg_parity_loopback_routed(routed_model):
    """Routing model through a shared loopback transport: every token
    stream bit-identical to the monolithic engine."""
    ref = _monolithic(ROUTED, routed_model, _mk_requests())
    t = LoopbackTransport()
    out = _disaggregate(ROUTED, routed_model, _mk_requests(), lambda: t)
    assert out == ref


def test_disagg_parity_file_transport(local_model, tmp_path):
    """Two pools meeting in a shared directory (object-store semantics)."""
    ref = _monolithic(LOCAL, local_model, _mk_requests(n=4))
    out = _disaggregate(LOCAL, local_model, _mk_requests(n=4),
                        lambda: FileTransport(str(tmp_path / "blobs")))
    assert out == ref


def test_disagg_parity_tcp(routed_model):
    """Both pools talk to one TCP blob peer — the same rails the
    two-process harness (examples/disaggregate.py) runs on."""
    ref = _monolithic(ROUTED, routed_model, _mk_requests(n=4))
    with TCPStoreServer() as server:
        out = _disaggregate(
            ROUTED, routed_model, _mk_requests(n=4),
            lambda: TCPTransport(server.host, server.port))
    assert out == ref


def test_prefill_only_engine_parks_not_decodes(routed_model):
    params, kstate = routed_model
    eng = InferenceEngine(ROUTED, params, kstate, max_slots=2,
                          max_len=MAX_LEN,
                          kvstore=KVStore(StoreConfig(
                              remote=LoopbackTransport())),
                          prefill_only=True)
    h = eng.submit(Request(uid=1, prompt=[3, 1, 4, 1, 5],
                           max_new_tokens=8))
    while eng.has_work():
        eng.step()
    # exactly the first (prefill-sampled) token, then parked held
    assert h.state == "parked" and len(h.output) == 1
    assert eng.metrics.decode_steps == 0
    eng.close()


def test_export_requires_prefilled_parked_session(routed_model):
    params, kstate = routed_model
    eng = InferenceEngine(ROUTED, params, kstate, max_slots=2,
                          max_len=MAX_LEN,
                          kvstore=KVStore(StoreConfig(
                              remote=LoopbackTransport())))
    h = eng.submit(Request(uid=1, prompt=[1, 2, 3], max_new_tokens=4))
    with pytest.raises(ValueError, match="not parked"):
        eng.export_session(1)
    eng.step()
    h.park()
    name = eng.export_session(1)
    assert h.state == "exported"
    with pytest.raises(ValueError, match="not parked"):
        eng.export_session(1)           # already gone
    eng.close()
    assert name


def test_import_collision_rejected(routed_model):
    params, kstate = routed_model
    t = LoopbackTransport()
    eng = InferenceEngine(ROUTED, params, kstate, max_slots=2,
                          max_len=MAX_LEN,
                          kvstore=KVStore(StoreConfig(remote=t)))
    h = eng.submit(Request(uid=1, prompt=[1, 2, 3], max_new_tokens=4))
    eng.step()
    h.park()
    name = eng.export_session(1)
    eng.submit(Request(uid=1, prompt=[9, 9], max_new_tokens=2))
    with pytest.raises(ValueError, match="collides"):
        eng.import_session(name)
    eng.close()


# ---------------------------------------------------------------------------
# Partial-prefix reuse (satellite)
# ---------------------------------------------------------------------------
def test_partial_prefix_gate_by_layout(routed_model, local_model):
    """Enabled iff every decode cache layout teacher-forces bit-exact:
    ring/append yes, cluster pages no."""
    assert decode_cache_layouts(LOCAL) == {"ring"}
    assert decode_cache_layouts(ROUTED) == {"ring+pages"}
    p, k = local_model
    on = InferenceEngine(LOCAL, p, k, max_slots=2, max_len=MAX_LEN,
                         prefix_cache=PrefixCache())
    assert on._partial_prefix
    on.close()
    p, k = routed_model
    off = InferenceEngine(ROUTED, p, k, max_slots=2, max_len=MAX_LEN,
                          prefix_cache=PrefixCache())
    assert not off._partial_prefix
    off.close()


def test_partial_prefix_hit_matches_full_prefill(local_model):
    """A prompt extending a cached shorter prefix decodes bit-identically
    to an engine that prefilled it from scratch."""
    params, kstate = local_model
    rng = np.random.RandomState(7)
    base = rng.randint(0, 128, size=13).tolist()
    tails = ([17], [41, 2], [3, 99, 64])

    ref = _monolithic(
        LOCAL, local_model,
        [Request(uid=i, prompt=base + t, max_new_tokens=5)
         for i, t in enumerate(tails)])

    pc = PrefixCache()
    eng = InferenceEngine(LOCAL, params, kstate, max_slots=2,
                          max_len=MAX_LEN, prefix_cache=pc)
    eng.run([Request(uid=100, prompt=base, max_new_tokens=1)])  # seed
    out = eng.run([Request(uid=i, prompt=base + t, max_new_tokens=5)
                   for i, t in enumerate(tails)])
    eng.close()
    assert {i: out[i] for i in range(len(tails))} == ref
    assert pc.stats()["kvstore/prefix_partial_hits"] >= 1.0


def test_partial_prefix_extends_cache_for_exact_hits(local_model):
    """After a partial hit, the extended full prompt is cached: the same
    prompt next time is an exact hit (no teacher-forcing)."""
    params, kstate = local_model
    pc = PrefixCache()
    eng = InferenceEngine(LOCAL, params, kstate, max_slots=2,
                          max_len=MAX_LEN, prefix_cache=pc)
    base = [5, 6, 7, 8, 9]
    eng.run([Request(uid=1, prompt=base, max_new_tokens=1)])
    eng.run([Request(uid=2, prompt=base + [1, 2], max_new_tokens=2)])
    partial_before = pc.stats()["kvstore/prefix_partial_hits"]
    out3 = eng.run([Request(uid=3, prompt=base + [1, 2], max_new_tokens=2)])
    out4 = eng.run([Request(uid=4, prompt=base + [1, 2], max_new_tokens=2)])
    eng.close()
    assert pc.stats()["kvstore/prefix_partial_hits"] == partial_before
    assert pc.stats()["kvstore/prefix_hits"] >= 2.0
    assert out3[3] == out4[4]


def test_routed_model_exact_hits_still_work(routed_model):
    """With the partial gate off, exact full-prompt hits keep the PR 7
    behavior: hit output == miss output."""
    params, kstate = routed_model
    pc = PrefixCache()
    eng = InferenceEngine(ROUTED, params, kstate, max_slots=2,
                          max_len=MAX_LEN, prefix_cache=pc)
    prompt = [11, 22, 33, 44, 55, 66]
    a = eng.run([Request(uid=1, prompt=prompt, max_new_tokens=6)])
    b = eng.run([Request(uid=2, prompt=prompt, max_new_tokens=6)])
    eng.close()
    assert a[1] == b[2]
    assert pc.stats()["kvstore/prefix_hits"] == 1.0
    assert pc.stats()["kvstore/prefix_partial_hits"] == 0.0
