"""Mesh-shape factorization: oversubscribed (data, model) requests scale
down to the largest feasible grid instead of discarding the model axis.
Pure logic — no devices needed (make_host_mesh itself is exercised on an
8-device platform by the engine-mesh parity test)."""
from repro.launch.mesh import feasible_mesh_shape


def test_request_that_fits_is_unchanged():
    assert feasible_mesh_shape(8, 2, 4) == (2, 4)
    assert feasible_mesh_shape(8, 1, 1) == (1, 1)
    assert feasible_mesh_shape(16, 16, 1) == (16, 1)


def test_oversubscribed_preserves_model_axis():
    # the seed fell back to (n, 1) here, silently dropping TP entirely
    assert feasible_mesh_shape(8, 4, 4) == (2, 4)
    assert feasible_mesh_shape(8, 2, 16) == (1, 8)
    assert feasible_mesh_shape(8, 16, 2) == (4, 2)


def test_oversubscribed_non_divisor_request():
    # model clamps to the largest divisor of n below the request
    assert feasible_mesh_shape(8, 3, 5) == (2, 4)
    assert feasible_mesh_shape(6, 4, 3) == (2, 3)
    assert feasible_mesh_shape(6, 4, 4) == (2, 3)


def test_single_device_degenerates_cleanly():
    assert feasible_mesh_shape(1, 2, 4) == (1, 1)
    assert feasible_mesh_shape(1, 1, 1) == (1, 1)
