"""Routing-stats overhead gate: stats-on train step vs stats-off.

Runs the SAME tiny local+routing model through jitted train steps twice —
``RoutingConfig.stats`` False then True — and compares median step
wall-time over ``--iters`` measured steps (after ``--warmup`` compile +
cache-warm steps). The telemetry is designed to be cheap (one (P, N)
probe softmax + reductions over intermediates the layer already has), so
CI gates the relative overhead:

    PYTHONPATH=src python benchmarks/obs_overhead.py \
        --json obs_overhead.json --max-overhead 0.05

The gate passes when median_on - median_off <= max(rel * median_off,
floor): tiny CPU steps are timing-noisy, so an absolute floor (default
2 ms) keeps the relative gate meaningful. The run also sanity-checks the
stats themselves: entropy within [0, log k], recall/mismatch in [0, 1].
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                TrainConfig, with_overrides)
from repro.train.train_step import init_train_state, make_train_step


def build_run(stats: bool) -> RunConfig:
    cfg = ModelConfig(
        name="obs-overhead", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        attention="local+routing",
        routing=RoutingConfig(num_clusters=4, local_window=32, stats=stats),
        dtype="float32")
    return RunConfig(model=cfg, train=TrainConfig(
        global_batch=2, seq_len=128, steps=100, lr=1e-3))


def median_step_time(run: RunConfig, warmup: int, iters: int,
                     seed: int = 0):
    step = jax.jit(make_train_step(run), donate_argnums=(0,))
    state = init_train_state(run, jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    batch = {"tokens": rng.randint(
        0, run.model.vocab_size,
        size=(run.train.global_batch, run.train.seq_len)).astype(np.int32)}
    metrics = {}
    for _ in range(warmup):
        state, metrics = step(state, batch)
    jax.block_until_ready(state.params)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state, metrics = step(state, batch)
        jax.block_until_ready(state.params)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), jax.device_get(metrics)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--warmup", type=int, default=3)
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--max-overhead", type=float, default=None,
                    help="exit nonzero when the stats-on median exceeds "
                         "stats-off by more than this fraction (subject to "
                         "--floor-ms)")
    ap.add_argument("--floor-ms", type=float, default=2.0,
                    help="absolute slack floor for the gate (timing noise "
                         "on sub-ms CPU steps)")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    run_off = build_run(stats=False)
    run_on = with_overrides(
        run_off,
        model=with_overrides(
            run_off.model,
            routing=with_overrides(run_off.model.routing, stats=True)))

    med_off, m_off = median_step_time(run_off, args.warmup, args.iters)
    med_on, m_on = median_step_time(run_on, args.warmup, args.iters)
    overhead = (med_on - med_off) / med_off if med_off else float("nan")

    assert "routing/entropy" not in m_off, "stats leaked into stats-off run"
    ent = float(m_on["routing/entropy"])
    logk = float(np.log(run_on.model.routing.num_clusters))
    assert -1e-5 <= ent <= logk + 1e-5, f"entropy {ent} outside [0, log k]"
    for key in ("routing/recall", "routing/mismatch"):
        v = float(m_on[key])
        assert -1e-5 <= v <= 1 + 1e-5, f"{key}={v} outside [0, 1]"

    print("name,us_per_call,derived")
    print(f"obs_overhead/stats_off,{med_off*1e6:.1f},baseline")
    print(f"obs_overhead/stats_on,{med_on*1e6:.1f},"
          f"overhead={overhead*100:.1f}%;entropy={ent:.3f};"
          f"dead={float(m_on['routing/dead']):.2f};"
          f"recall={float(m_on['routing/recall']):.3f}")

    record = {"median_off_s": med_off, "median_on_s": med_on,
              "overhead_frac": overhead, "warmup": args.warmup,
              "iters": args.iters,
              "routing": {k.split("/", 1)[1]: float(m_on[k]) for k in m_on
                          if k.startswith("routing/")}}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")

    if args.max_overhead is not None:
        slack = max(args.max_overhead * med_off, args.floor_ms / 1e3)
        if med_on - med_off > slack:
            print(f"FAIL: stats-on median {med_on*1e3:.2f} ms exceeds "
                  f"stats-off {med_off*1e3:.2f} ms by more than "
                  f"{slack*1e3:.2f} ms", file=sys.stderr)
            sys.exit(1)
        print(f"overhead gate passed: +{(med_on-med_off)*1e3:.2f} ms "
              f"(slack {slack*1e3:.2f} ms)")


if __name__ == "__main__":
    main()
