"""Continuous batching vs lock-step batching on one staggered workload.

Runs the SAME requests (mixed prompt lengths, mixed generation lengths,
staggered arrivals) through (a) the continuous-batching engine and (b) the
seed's lock-step loop — groups of ``max_slots`` requests that prefill
together and decode until the LONGEST generation in the group finishes,
with finished lanes stepping idly. Reports aggregate decode throughput
(useful tokens / decode wall-time) and its hardware-independent proxy
tokens-per-step; continuous batching wins because retired lanes are
refilled mid-flight instead of idling until the group drains.

Arrival staggering is ignored for the lock-step baseline (generous to it).

Run:  PYTHONPATH=src python benchmarks/serve_engine.py
CI:   PYTHONPATH=src python benchmarks/serve_engine.py --smoke \
          --json benchmarks/serve_engine_smoke.json --min-speedup 1.2
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RoutingConfig
from repro.models.model import init_model
from repro.serve.engine import InferenceEngine, Request
from repro.serve.engine.pool import init_pool, write_slot
from repro.serve.serving import init_cache, make_serve_step, prefill


def build_model(seed: int = 0, **overrides):
    kw = dict(name="rt-engine-bench", family="dense", num_layers=4,
              d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
              vocab_size=1024, attention="local+routing",
              routing=RoutingConfig(num_clusters=8, local_window=32),
              dtype="float32")
    kw.update(overrides)
    cfg = ModelConfig(**kw)
    params, kstate = init_model(cfg, jax.random.PRNGKey(seed))
    return cfg, params, kstate


def make_workload(cfg: ModelConfig, n_requests: int = 12, seed: int = 1,
                  prompt_lens=(16, 24, 48, 64), gen_lens=(8, 16, 24, 40, 48),
                  arrival_every: int = 1) -> List[Request]:
    """Mixed prompt/generation lengths, one arrival per ``arrival_every``
    engine steps — real-traffic shape, greedy sampling (deterministic)."""
    rng = np.random.RandomState(seed)
    reqs = []
    for uid in range(n_requests):
        p = int(prompt_lens[uid % len(prompt_lens)])
        g = int(gen_lens[(3 * uid + 1) % len(gen_lens)])
        prompt = rng.randint(0, cfg.vocab_size, size=p).tolist()
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=g,
                            arrival_step=uid * arrival_every))
    return reqs


def clone_requests(requests: List[Request]) -> List[Request]:
    """Fresh copies (Request.output is mutated by the runners)."""
    return [dataclasses.replace(r, output=[]) for r in requests]


def workload_max_len(requests: List[Request]) -> int:
    # lock-step lanes keep stepping until the group's longest generation
    # finishes, so a lane can reach max(prompt) + max(gen) positions
    return (max(r.prompt_len for r in requests)
            + max(r.max_new_tokens for r in requests))


def run_continuous(cfg, params, kstate, requests, max_slots: int,
                   max_len: int, warmup: bool = True,
                   obs_jsonl: str = None, chunked_prefill: int = None
                   ) -> Tuple[Dict[int, List[int]], dict]:
    from repro.serve.engine.metrics import EngineMetrics
    eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len, obs_jsonl=obs_jsonl,
                          routing_stats=bool(obs_jsonl),
                          chunked_prefill=chunked_prefill)
    if warmup:
        # compile the fused decode step outside the measured run (jit
        # caches are per-engine; a cold first step would dominate timing)
        eng.run([dataclasses.replace(requests[0], uid=2**31 - 1, output=[],
                                     max_new_tokens=2, arrival_step=0)])
        eng.metrics = EngineMetrics()
        eng.step_count = 0
    outputs = eng.run(requests)
    summary = eng.metrics.summary()
    # observability riders: which backend each attention variant's decode
    # resolved to (registry-dependent: pallas_paged on TPU, xla elsewhere)
    # and whether prefill ran depth-chunked
    summary["decode_backends"] = dict(eng.attn_backends)
    summary["chunked_prefill"] = chunked_prefill
    eng.close()
    return outputs, summary


def run_lockstep(cfg, params, kstate, requests, max_slots: int,
                 max_len: int) -> Tuple[Dict[int, List[int]], dict]:
    """Seed-style fixed-batch decoding (the `make_serve_step` loop)."""
    step = jax.jit(make_serve_step(cfg))
    jit_prefill = jax.jit(functools.partial(prefill, cfg=cfg))
    # compile the decode step outside the measured loop (same treatment as
    # the continuous runner's warmup)
    wp = init_pool(cfg, max_slots, max_len)
    _ = step(params, kstate, wp, np.zeros((max_slots,), np.int32),
             np.zeros((max_slots,), np.int32))
    outputs: Dict[int, List[int]] = {}
    decode_steps, useful, decode_time = 0, 0, 0.0
    for start in range(0, len(requests), max_slots):
        group = requests[start:start + max_slots]
        pool = init_pool(cfg, max_slots, max_len)
        toks = np.zeros((max_slots,), np.int32)
        pos = np.zeros((max_slots,), np.int32)
        for lane, r in enumerate(group):
            lane_cache = init_cache(cfg, 1, max_len)
            lg, lane_cache = jit_prefill(
                params, kstate, lane_cache,
                {"tokens": jnp.asarray(r.prompt, jnp.int32)[None]})
            pool = write_slot(pool, lane, lane_cache)
            toks[lane] = int(jnp.argmax(lg[0, -1]))
            pos[lane] = r.prompt_len
            outputs[r.uid] = [int(toks[lane])]
        t0 = time.perf_counter()
        for _ in range(max(r.max_new_tokens for r in group) - 1):
            lg, pool = step(params, kstate, pool, jnp.asarray(toks),
                            jnp.asarray(pos))
            nxt = np.asarray(jnp.argmax(lg, -1))
            for lane, r in enumerate(group):
                if len(outputs[r.uid]) < r.max_new_tokens:
                    outputs[r.uid].append(int(nxt[lane]))
                    useful += 1
                toks[lane] = int(nxt[lane])
                pos[lane] += 1
            decode_steps += 1
        jax.block_until_ready(lg)
        decode_time += time.perf_counter() - t0
    return outputs, {
        "decode_steps": decode_steps,
        "decode_tokens": useful,
        "decode_tokens_per_s": useful / decode_time if decode_time else 0.0,
        "tokens_per_step": useful / decode_steps if decode_steps else 0.0,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller model + workload (CI regression gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the summary record as JSON")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit nonzero if continuous-batching decode tok/s "
                         "< this multiple of lock-step (or outputs differ)")
    ap.add_argument("--obs-jsonl", default=None, metavar="PATH",
                    help="stream engine telemetry (engine_prefill routing "
                         "health, per-tick pages health, final summary) as "
                         "schema v1 JSONL; also enables routing stats in "
                         "the engine's prefill")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax profiler trace of the continuous "
                         "run into this directory")
    ap.add_argument("--chunked-prefill", type=int, default=2, metavar="N",
                    help="depth stages advanced per engine step (prefill "
                         "interleaves with decode); 0 = monolithic prefill "
                         "at admission. The default of 2 covers the smoke "
                         "model's full depth per step, so occupancy matches "
                         "monolithic prefill while the chunked path is "
                         "exercised end-to-end")
    args = ap.parse_args(argv)
    chunked = args.chunked_prefill if args.chunked_prefill > 0 else None

    if args.smoke:
        cfg, params, kstate = build_model(num_layers=2, d_model=128,
                                          num_heads=4, num_kv_heads=2,
                                          d_ff=256)
        requests = make_workload(cfg, n_requests=8)
    else:
        cfg, params, kstate = build_model()
        requests = make_workload(cfg, n_requests=12)
    max_slots = 4
    max_len = workload_max_len(requests)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{len(requests)} requests, {max_slots} slots, max_len={max_len}")

    out_ls, ls = run_lockstep(cfg, params, kstate, clone_requests(requests),
                              max_slots, max_len)
    from repro.obs.trace import profile as obs_profile
    with obs_profile(args.profile_dir):
        out_cb, cb = run_continuous(cfg, params, kstate,
                                    clone_requests(requests), max_slots,
                                    max_len, obs_jsonl=args.obs_jsonl,
                                    chunked_prefill=chunked)
    match = all(out_cb[u] == out_ls[u] for u in out_cb)
    print(f"outputs identical across schedulers: {match}")
    print(f"decode backends: {cb['decode_backends']}; "
          f"chunked_prefill={cb['chunked_prefill']}")

    print("name,us_per_call,derived")
    for name, stats in (("lockstep", ls), ("continuous", cb)):
        us = (1e6 / stats["decode_tokens_per_s"]
              if stats["decode_tokens_per_s"] else 0.0)
        print(f"serve_{name}_decode,{us:.1f},"
              f"tok/s={stats['decode_tokens_per_s']:.1f} "
              f"tok/step={stats['tokens_per_step']:.2f} "
              f"steps={stats['decode_steps']}")
    speedup = (cb["decode_tokens_per_s"] / ls["decode_tokens_per_s"]
               if ls["decode_tokens_per_s"] else float("nan"))
    print(f"continuous-vs-lockstep decode throughput: {speedup:.2f}x "
          f"(tokens/step {cb['tokens_per_step']:.2f} vs "
          f"{ls['tokens_per_step']:.2f}); "
          f"mean occupancy {cb['mean_occupancy']:.2f}/{max_slots}, "
          f"mean TTFT {cb['mean_ttft_s']*1e3:.0f} ms")

    if args.json:
        record = {"smoke": args.smoke, "model": cfg.name,
                  "params_m": cfg.param_count() / 1e6,
                  "n_requests": len(requests), "max_slots": max_slots,
                  "max_len": max_len, "outputs_identical": match,
                  "decode_backends": cb["decode_backends"],
                  "chunked_prefill": cb["chunked_prefill"],
                  # None, not NaN: strict JSON parsers reject bare NaN
                  "speedup_tokens_per_s": (speedup if speedup == speedup
                                           else None),
                  "lockstep": ls, "continuous": cb}
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")

    if args.min_speedup is not None:
        if not match:
            print("FAIL: scheduler outputs diverged", file=sys.stderr)
            sys.exit(1)
        if not speedup >= args.min_speedup:    # NaN fails the gate too
            print(f"FAIL: continuous batching {speedup:.2f}x < required "
                  f"{args.min_speedup:.2f}x lock-step", file=sys.stderr)
            sys.exit(1)
        print(f"speedup gate passed: {speedup:.2f}x >= "
              f"{args.min_speedup:.2f}x")


if __name__ == "__main__":
    main()
