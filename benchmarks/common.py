"""Shared benchmark machinery.

Every table harness returns rows `(name, us_per_call, derived)` where
`us_per_call` is a measured CPU wall time of the reduced config's jitted
step and `derived` carries the quantity the paper's table reports
(bits/dim, perplexity target, steps/sec estimate, JSD, ...). CPU wall
times are NOT TPU projections — TPU numbers come from the roofline model
(benchmarks/roofline.py); both are printed so the derivation is visible.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                TrainConfig, with_overrides)
from repro.data.synthetic import SyntheticLoader
from repro.train.train_step import init_train_state, make_train_step


def time_step(fn: Callable, args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall microseconds per call of a jitted step."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(jax.tree.leaves(out)[0])
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def shrink(cfg: ModelConfig, layers=2, d=64, heads=4, seq=256,
           vocab=None) -> ModelConfig:
    """Reduce a paper config to CPU scale, preserving the head/layer
    structure knobs that matter for the ablation being measured."""
    rl = cfg.routing.routing_layers
    if rl:
        # keep the suffix structure proportionally
        n_routing = max(1, int(len(rl) * layers / cfg.num_layers))
        rl = tuple(range(layers - n_routing, layers))
    routing = with_overrides(
        cfg.routing, num_clusters=min(cfg.routing.num_clusters, 8),
        window=0, local_window=min(cfg.routing.local_window, seq // 4),
        routing_layers=rl)
    return with_overrides(
        cfg, num_layers=layers, d_model=d, num_heads=heads,
        num_kv_heads=heads, head_dim=0, d_ff=4 * d,
        vocab_size=vocab or min(cfg.vocab_size, 256),
        attn_window=min(cfg.attn_window, seq // 4),
        routing=routing, dropout=0.0, dtype="float32", max_seq_len=seq)


def train_step_time(cfg: ModelConfig, batch_size=2, seq=256,
                    steps_measure=3) -> Tuple[float, float]:
    """(us_per_step, loss_after) for a reduced config."""
    run = RunConfig(model=cfg, train=TrainConfig(
        global_batch=batch_size, seq_len=seq, lr=1e-3, schedule="const",
        warmup_steps=2))
    ts = init_train_state(run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(run))
    loader = SyntheticLoader("markov", cfg.vocab_size, batch_size, seq)
    b = {k: jnp.asarray(v) for k, v in next(iter(loader)).items()}
    us = time_step(step, (ts, b))
    ts2, m = step(ts, b)
    return us, float(m["loss"])


def nats_to_bits_per_dim(nll_nats: float) -> float:
    return nll_nats / np.log(2.0)


def nats_to_ppl(nll_nats: float) -> float:
    return float(np.exp(nll_nats))
