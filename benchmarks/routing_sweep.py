"""--routing-sweep: gathered vs gather-free fused routing kernel.

One row per (sequence length, impl) through the full ``routed_attention``
module (shared-QK causal, k = sqrt-ish clusters of window 256), measuring
tok/s of the jitted call and peak memory (XLA ``memory_analysis`` temp +
output bytes). The same record is written to ``BENCH_routing.json`` at the
repo root — the perf-trajectory baseline for the routing hot-spot.

Interpret-mode caveat (CPU CI, this container): the Pallas rows execute
the kernel bodies via the interpreter, where the fused kernel's in-VMEM
row pulls cost more wall-clock than XLA's vectorized HBM gather — tok/s
*inverts* relative to hardware. The HBM story is in ``peak_mb``: the
fused rows drop the gathered (B,H,k,w,dh) q/k/v copies from the compiled
buffer plan at every N. On TPU (interpret off) the same drop is the
bandwidth win; record hardware numbers by re-running this sweep there.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import List, Tuple

import jax
import numpy as np

from repro.configs.base import RoutingConfig
from repro.core.kmeans import init_kmeans
from repro.core.routing import routed_attention

Row = Tuple[str, float, str]

B, H, DH = 1, 2, 64
WINDOW = 256
SEQ_LENS = (1024, 4096, 8192)
IMPLS = ("xla", "pallas", "pallas_fused")
JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_routing.json"


def _peak_bytes(compiled) -> int:
    try:
        m = compiled.memory_analysis()
        return int(m.temp_size_in_bytes + m.output_size_in_bytes)
    except Exception:                      # backend without the analysis
        return 0


def routing_sweep_rows(iters: int = 3,
                       seq_lens=SEQ_LENS) -> Tuple[List[Row], dict]:
    rows: List[Row] = []
    record = {
        "shape": {"B": B, "H": H, "dh": DH, "window": WINDOW},
        "platform": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "note": ("interpret-mode wall-clock (CPU): fused in-kernel row "
                 "pulls are interpreter-slow, so tok/s inverts vs "
                 "hardware; the fused win is the gathered-copy drop in "
                 "peak_mb (and HBM bandwidth on TPU)"),
        "points": [],
    }
    for N in seq_lens:
        kc = max(2, N // WINDOW)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, N, DH))
        v = jax.random.normal(ks[1], (B, H, N, DH))
        st = init_kmeans(ks[2], H, kc, DH)
        cfg = RoutingConfig(num_clusters=kc)
        point = {"N": N, "clusters": kc, "impls": {}}
        for impl in IMPLS:
            fn = jax.jit(lambda q, v, impl=impl: routed_attention(
                q, None, v, st, cfg, update_state=False, impl=impl).out)
            # one AOT compile serves both memory_analysis and timing
            compiled = fn.lower(q, v).compile()
            peak = _peak_bytes(compiled)
            jax.block_until_ready(compiled(q, v))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(compiled(q, v))
                ts.append(time.perf_counter() - t0)
            us = float(np.median(ts) * 1e6)
            tok_s = B * N / (us / 1e6)
            rows.append((f"routing_sweep/N{N}:{impl}", us,
                         f"tok_s={tok_s:.0f};peak_mb={peak / 2**20:.1f}"))
            point["impls"][impl] = {"us_per_call": round(us, 1),
                                    "tok_s": round(tok_s),
                                    "peak_bytes": peak}
        g, f = point["impls"]["pallas"], point["impls"]["pallas_fused"]
        point["fused_speedup_tok_s"] = round(f["tok_s"] / g["tok_s"], 3)
        point["fused_peak_ratio"] = (
            round(f["peak_bytes"] / g["peak_bytes"], 3)
            if g["peak_bytes"] else None)
        record["points"].append(point)
    return rows, record


def write_json(record: dict, path: Path = JSON_PATH) -> None:
    path.write_text(json.dumps(record, indent=2) + "\n")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    all_rows, record = routing_sweep_rows()
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    write_json(record)
