"""--routing-sweep: gathered vs gather-free fused routing kernel.

One row per (sequence length, impl) through the full ``routed_attention``
module (shared-QK causal, k = sqrt-ish clusters of window 256), measuring
tok/s of the jitted call and peak memory (XLA ``memory_analysis`` temp +
output bytes). Impls cover both memory plans of the fused kernel —
``pallas_fused_paged`` (double-buffered per-row DMA, no VMEM residency
cliff) and ``pallas_fused_unpaged`` (whole-plane resident) — next to the
auto-switching ``pallas_fused``, the gathered ``pallas`` kernel and the
``xla`` reference. Every row carries the device kind and whether the
kernel ran in interpret mode, so hardware and CI numbers are never
conflated in the trend line.

The same record is written to ``BENCH_routing.json`` at the repo root —
the perf-trajectory baseline for the routing hot-spot — together with
the analytic routing-vs-flash roofline (benchmarks/roofline.py
``attention_roofline``), whose predicted O(n^1.5)-vs-O(n^2) crossover
carries the at-scale speed story that CPU wall-clock cannot.

``check=True`` gates the sweep: every impl's output must match the xla
reference (always), and on real TPU hardware the paged fused rows must
not be slower than the gathered kernel (tok/s ordering is only asserted
when the platform is ``tpu``; see the interpret-mode caveat below).

Interpret-mode caveat (CPU CI, this container): the Pallas rows execute
the kernel bodies via the interpreter, where the fused kernel's in-VMEM
row pulls cost more wall-clock than XLA's vectorized HBM gather — tok/s
*inverts* relative to hardware. The HBM story is in ``peak_mb``: the
fused rows drop the gathered (B,H,k,w,dh) q/k/v copies from the compiled
buffer plan at every N. On TPU (interpret off) the same drop is the
bandwidth win; record hardware numbers by re-running this sweep there.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import List, Tuple

import jax
import numpy as np

from repro.configs.base import RoutingConfig
from repro.core.kmeans import init_kmeans
from repro.core.routing import routed_attention

Row = Tuple[str, float, str]

B, H, DH = 1, 2, 64
WINDOW = 256
SEQ_LENS = (1024, 4096, 8192)
IMPLS = ("xla", "pallas", "pallas_fused", "pallas_fused_paged",
         "pallas_fused_unpaged")
CHECK_TOL = 2e-4
JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_routing.json"


def _peak_bytes(compiled) -> int:
    try:
        m = compiled.memory_analysis()
        return int(m.temp_size_in_bytes + m.output_size_in_bytes)
    except Exception:                      # backend without the analysis
        return 0


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


def routing_sweep_rows(iters: int = 3, seq_lens=SEQ_LENS,
                       check: bool = False) -> Tuple[List[Row], dict]:
    from benchmarks.roofline import attention_roofline
    platform = jax.default_backend()
    interpret = platform != "tpu"
    device = _device_kind()
    rows: List[Row] = []
    record = {
        "shape": {"B": B, "H": H, "dh": DH, "window": WINDOW},
        "platform": platform,
        "device_kind": device,
        "interpret": interpret,
        "note": ("interpret-mode wall-clock (CPU): fused in-kernel row "
                 "pulls are interpreter-slow, so tok/s inverts vs "
                 "hardware; the fused win is the gathered-copy drop in "
                 "peak_mb (and HBM bandwidth on TPU) — the at-scale "
                 "speed story is the analytic crossover under "
                 "'roofline'"),
        "checked": bool(check),
        "points": [],
        # analytic routing-vs-flash model + predicted O(n^1.5) crossover
        "roofline": attention_roofline(),
    }
    for N in seq_lens:
        kc = max(2, N // WINDOW)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, N, DH))
        v = jax.random.normal(ks[1], (B, H, N, DH))
        st = init_kmeans(ks[2], H, kc, DH)
        cfg = RoutingConfig(num_clusters=kc)
        point = {"N": N, "clusters": kc, "device_kind": device,
                 "interpret": interpret, "impls": {}}
        ref_out = None
        for impl in IMPLS:
            fn = jax.jit(lambda q, v, impl=impl: routed_attention(
                q, None, v, st, cfg, update_state=False, impl=impl).out)
            # one AOT compile serves both memory_analysis and timing
            compiled = fn.lower(q, v).compile()
            peak = _peak_bytes(compiled)
            out = compiled(q, v)
            jax.block_until_ready(out)
            if impl == "xla":
                ref_out = out
            maxdiff = float(jax.numpy.abs(out - ref_out).max())
            if check and maxdiff >= CHECK_TOL:
                raise SystemExit(
                    f"routing sweep parity check failed: N={N} impl="
                    f"{impl} maxdiff {maxdiff:.2e} >= {CHECK_TOL:.0e}")
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(compiled(q, v))
                ts.append(time.perf_counter() - t0)
            us = float(np.median(ts) * 1e6)
            tok_s = B * N / (us / 1e6)
            rows.append((f"routing_sweep/N{N}:{impl}", us,
                         f"tok_s={tok_s:.0f};peak_mb={peak / 2**20:.1f};"
                         f"device={device};interpret={interpret}"))
            point["impls"][impl] = {"us_per_call": round(us, 1),
                                    "tok_s": round(tok_s),
                                    "peak_bytes": peak,
                                    "maxdiff_vs_xla": maxdiff}
        g = point["impls"]["pallas"]
        f = point["impls"]["pallas_fused"]
        p = point["impls"]["pallas_fused_paged"]
        point["fused_speedup_tok_s"] = round(f["tok_s"] / g["tok_s"], 3)
        point["paged_speedup_tok_s"] = round(p["tok_s"] / g["tok_s"], 3)
        point["fused_peak_ratio"] = (
            round(f["peak_bytes"] / g["peak_bytes"], 3)
            if g["peak_bytes"] else None)
        if check and platform == "tpu" and p["tok_s"] < g["tok_s"]:
            raise SystemExit(
                f"routing sweep perf check failed on tpu: N={N} paged "
                f"fused {p['tok_s']} tok/s < gathered {g['tok_s']} tok/s")
        record["points"].append(point)
    return rows, record


def write_json(record: dict, path: Path = JSON_PATH) -> None:
    path.write_text(json.dumps(record, indent=2) + "\n")


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    all_rows, record = routing_sweep_rows(check="--check" in sys.argv[1:])
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    write_json(record)
