"""Compressed (int8_ef) vs fp32 data-parallel training smoke.

Trains the SAME synthetic-LM data stream twice — grad_compression="none"
vs "int8_ef" — on a forced multi-device host platform and reports per-step
wall time plus the relative final-loss gap (mean over the last 10 steps).
The gap is the number that matters: error feedback is supposed to make
int8 gradient exchange converge like fp32, and the CI `train-bench` job
fails the push when the gap exceeds the documented threshold
(--max-loss-gap, default 0.02 — the same 2% bar as the multi-device
lane's test_int8_ef_train_parity_and_wire, which also asserts the s8 wire
format; this job seeds the step-time trend line next to it).

Run:  PYTHONPATH=src python benchmarks/train_compression.py
CI:   PYTHONPATH=src python benchmarks/train_compression.py --smoke \
          --json benchmarks/train_compression_smoke.json --max-loss-gap 0.02

The device count is forced via XLA_FLAGS BEFORE jax is imported (all
repro imports are deferred into main), so the script runs identically on
single-CPU laptops and CI runners.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

DEFAULT_GAP = 0.02      # documented threshold: 2% relative final loss


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller model + fewer steps (CI regression gate)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host-platform device count (0: leave "
                         "XLA_FLAGS alone)")
    ap.add_argument("--steps", type=int, default=0,
                    help="train steps per variant (default 200, smoke 200)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the summary record as JSON")
    ap.add_argument("--max-loss-gap", type=float, default=None,
                    help="exit nonzero if |int8_ef - fp32| / fp32 final "
                         f"loss exceeds this (documented: {DEFAULT_GAP})")
    args = ap.parse_args(argv)

    flags = os.environ.get("XLA_FLAGS", "")
    if args.devices and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count"
            f"={args.devices}").strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import (ModelConfig, RoutingConfig, RunConfig,
                                    TrainConfig)
    from repro.data.synthetic import SyntheticLoader
    from repro.train.train_step import init_train_state, make_train_step

    steps = args.steps or 200
    if args.smoke:
        mc = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=64)
    else:
        mc = dict(num_layers=4, d_model=128, num_heads=8, num_kv_heads=4,
                  d_ff=256, vocab_size=256)
    cfg = ModelConfig(name="rt-train-bench", attention="local+routing",
                      routing=RoutingConfig(num_clusters=4,
                                            local_window=16),
                      dtype="float32", **mc)

    n_dev = len(jax.devices())
    batch, seq = 8, 64

    def run_cfg(comp):
        return RunConfig(model=cfg, train=TrainConfig(
            global_batch=batch, seq_len=seq, steps=steps, lr=3e-3,
            schedule="const", warmup_steps=5, grad_compression=comp))

    mesh = jax.make_mesh((n_dev,), ("data",))
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.2f}M params, "
          f"{n_dev} devices, {steps} steps x 2 variants")

    def fit(comp):
        run = run_cfg(comp)
        ts = init_train_state(run, jax.random.PRNGKey(0),
                              mesh=mesh if comp != "none" else None)
        step = jax.jit(make_train_step(
            run, mesh=mesh if comp != "none" else None),
            donate_argnums=(0,))
        loader = SyntheticLoader("markov", cfg.vocab_size, batch, seq)
        losses, t_run = [], 0.0
        for i, b in zip(range(steps), loader):
            b = {k: jnp.asarray(v) for k, v in b.items()}
            t0 = time.perf_counter()
            ts, m = step(ts, b)
            loss = float(m["loss"])        # blocks on the step
            if i > 0:                      # exclude compile
                t_run += time.perf_counter() - t0
            losses.append(loss)
        return {"final_loss": float(np.mean(losses[-10:])),
                "first_loss": losses[0],
                "step_time_ms": 1e3 * t_run / max(steps - 1, 1)}

    fp32 = fit("none")
    comp = fit("int8_ef")
    gap = abs(comp["final_loss"] - fp32["final_loss"]) / fp32["final_loss"]

    print("name,us_per_call,derived")
    for name, r in (("fp32", fp32), ("int8_ef", comp)):
        print(f"train_{name}_step,{1e3 * r['step_time_ms']:.0f},"
              f"loss={r['first_loss']:.3f}->{r['final_loss']:.4f}")
    print(f"compressed-vs-fp32 final-loss gap: {gap:.4%} "
          f"(fp32 {fp32['final_loss']:.4f}, int8_ef "
          f"{comp['final_loss']:.4f})")

    if args.json:
        record = {"smoke": args.smoke, "model": cfg.name,
                  "params_m": cfg.param_count() / 1e6, "devices": n_dev,
                  "steps": steps, "global_batch": batch, "seq_len": seq,
                  "loss_gap_rel": gap, "fp32": fp32, "int8_ef": comp}
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")

    if args.max_loss_gap is not None:
        if not gap <= args.max_loss_gap:   # NaN fails the gate too
            print(f"FAIL: loss gap {gap:.4%} > allowed "
                  f"{args.max_loss_gap:.4%}", file=sys.stderr)
            sys.exit(1)
        print(f"loss-gap gate passed: {gap:.4%} <= {args.max_loss_gap:.4%}")


if __name__ == "__main__":
    main()
