"""--backend-sweep: one row per registered attention backend.

Emits rows in the report-table CSV schema (``name,us_per_call,derived``)
where ``derived`` carries ``tok_s`` (tokens/s of the jitted attend call
at the sweep shape) and ``peak_mb`` (XLA ``memory_analysis`` temp+output
bytes of the compiled call), so a backend regression shows up in the
perf trajectory next to the paper tables. Pallas backends run in
interpret mode on CPU — their wall-clock is NOT a kernel projection
(the roofline table owns TPU numbers); the row exists so the kernel
path's memory shape and correctness-under-jit are tracked per push.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import attn as A
from repro.configs.base import RoutingConfig
from repro.core.kmeans import init_kmeans

Row = Tuple[str, float, str]

# Sweep shape: satisfies every kernel block constraint (N % 128 == 0,
# cluster window N/kc = 128) while staying CPU-interpretable.
B, H, HKV, N, DH = 2, 4, 2, 512, 64
ROUTING = RoutingConfig(num_clusters=4)


def _spec(variant: str) -> A.AttentionSpec:
    kw = dict(num_heads=H, num_kv_heads=HKV, head_dim=DH,
              rope_theta=10000.0)
    if variant == "full":
        return A.AttentionSpec(variant="full", **kw)
    if variant == "local":
        return A.AttentionSpec(variant="local", window=128, **kw)
    if variant == "routing":
        return A.AttentionSpec(variant="routing", routing=ROUTING, **kw)
    return A.AttentionSpec(variant="local+routing", routing=ROUTING,
                           window=128, routing_heads=2, **kw)


def _peak_bytes(compiled) -> int:
    try:
        m = compiled.memory_analysis()
        return int(m.temp_size_in_bytes + m.output_size_in_bytes)
    except Exception:                      # backend without the analysis
        return 0


def backend_sweep_rows(iters: int = 3) -> List[Row]:
    rows: List[Row] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, N, DH))
    k = jax.random.normal(ks[1], (B, HKV, N, DH))
    v = jax.random.normal(ks[2], (B, HKV, N, DH))
    for backend in sorted(A.registered(), key=lambda b: b.name):
        spec = _spec(backend.variant)
        Hr = spec.routing_heads or H
        mu = (init_kmeans(ks[3], Hr, ROUTING.num_clusters, DH).mu
              if spec.routing is not None else jnp.zeros((0,)))

        def fn(q, k, v, mu, backend=backend, spec=spec):
            return A.attend(spec, q, k, v,
                            state=mu if spec.routing is not None else None,
                            update_state=False, impl=backend.impl).out

        jfn = jax.jit(fn)
        peak = _peak_bytes(jfn.lower(q, k, v, mu).compile())
        out = jfn(q, k, v, mu)
        jax.block_until_ready(out)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(q, k, v, mu))
            ts.append(time.perf_counter() - t0)
        us = float(np.median(ts) * 1e6)
        tok_s = B * N / (us / 1e6)
        caps = backend.caps
        flags = "+".join(
            f for f, on in [("decode", caps.supports_decode),
                            ("mesh", caps.supports_mesh),
                            ("pad", caps.supports_pad_mask),
                            ("grad", caps.supports_grad),
                            ("tpu", caps.needs_tpu)] if on)
        layout = backend.layout.name if backend.layout is not None else "-"
        rows.append((f"backends/{backend.variant}:{backend.impl}", us,
                     f"tok_s={tok_s:.0f};peak_mb={peak/2**20:.1f};"
                     f"cache={layout};caps={flags}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in backend_sweep_rows():
        print(f"{name},{us:.1f},{derived}")
