"""Disaggregated prefill/decode pools + async KV transfers (DESIGN.md §11.5).

Three measurements on one model:

  disagg parity     the same workload through (a) one monolithic engine
                    and (b) a prefill-pool engine that parks + exports
                    every freshly prefilled session through a transport
                    and a decode-pool engine that imports + decodes them.
                    Token streams must be bit-identical — the
                    ``--check`` gate fails the run otherwise. Measured
                    over the in-process loopback transport AND a real
                    localhost TCP blob peer (the same rails the
                    two-process harness examples/disaggregate.py uses).
  async park        the oversubscription workload (sessions >> slots,
                    time-slice rotation, dozens of parks) under
                    synchronous vs async transfers: the admission path's
                    park cost drops from the full host materialization
                    to an enqueue (the transfer overlaps subsequent
                    decode steps), outputs still bit-exact vs a
                    never-evicting pool.
  transport cost    bytes and p50 put/get latency through the TCP peer
                    for the exported session blobs.

Run:  PYTHONPATH=src python -m benchmarks.disagg
CI:   PYTHONPATH=src python -m benchmarks.disagg --smoke \
          --json benchmarks/disagg_smoke.json --check
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.serve_engine import build_model, make_workload
from repro.serve.engine import InferenceEngine
from repro.serve.kvstore import KVStore, StoreConfig
from repro.serve.kvstore.remote import (LoopbackTransport, TCPStoreServer,
                                        TCPTransport)


def _run_monolithic(cfg, params, kstate, reqs, max_slots, max_len):
    eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len)
    t0 = time.perf_counter()
    out = eng.run(reqs)
    wall = time.perf_counter() - t0
    eng.close()
    return out, wall


def _run_disaggregated(cfg, params, kstate, reqs, max_slots, max_len,
                       make_transport):
    """Prefill pool -> exported blobs -> decode pool; returns outputs,
    per-pool wall times, and the decode pool's transport stats."""
    pre = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len, prefill_only=True,
                          kvstore=KVStore(StoreConfig(
                              remote=make_transport())))
    t0 = time.perf_counter()
    for r in reqs:
        pre.submit(r)
    while pre.has_work():
        pre.step()
    names = [pre.export_session(r.uid) for r in reqs
             if r.state == "PARKED"]
    prefill_wall = time.perf_counter() - t0
    pre.close()

    dec_transport = make_transport()
    dec = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len,
                          kvstore=KVStore(StoreConfig(
                              remote=dec_transport,
                              async_transfers=True)))
    t0 = time.perf_counter()
    handles = [dec.import_session(n) for n in names]
    while dec.has_work():
        dec.step()
    decode_wall = time.perf_counter() - t0
    tstats = (dec_transport.stats()
              if hasattr(dec_transport, "stats") else {})
    dec.close()
    out = {h.uid: h.output for h in handles}
    for r in reqs:                      # sessions finished at prefill
        if r.uid not in out:
            out[r.uid] = list(r.output)
    return out, prefill_wall, decode_wall, tstats


def bench_disagg(cfg, params, kstate, n_requests, max_slots, max_len) -> dict:
    mk = lambda: make_workload(cfg, n_requests=n_requests, arrival_every=0)
    ref, mono_wall = _run_monolithic(cfg, params, kstate, mk(),
                                     max_slots, max_len)

    loop = LoopbackTransport()
    out_l, pre_l, dec_l, _ = _run_disaggregated(
        cfg, params, kstate, mk(), max_slots, max_len, lambda: loop)

    with TCPStoreServer() as server:
        out_t, pre_t, dec_t, tstats = _run_disaggregated(
            cfg, params, kstate, mk(), max_slots, max_len,
            lambda: TCPTransport(server.host, server.port))

    return {
        "n_requests": n_requests, "max_slots": max_slots,
        "monolithic_wall_s": mono_wall,
        "loopback": {
            "outputs_identical": out_l == ref,
            "prefill_wall_s": pre_l, "decode_wall_s": dec_l,
            "blob_bytes": loop.stats()["transport/bytes_out"],
        },
        "tcp": {
            "outputs_identical": out_t == ref,
            "prefill_wall_s": pre_t, "decode_wall_s": dec_t,
            "blob_bytes_in": tstats.get("transport/bytes_in", 0.0),
            "get_p50_ms": tstats.get("transport/get_p50_s", 0.0) * 1e3,
        },
    }


def bench_async_park(cfg, params, kstate, n_sessions, max_slots, max_len,
                     time_slice: int = 4) -> dict:
    """Sessions >> slots with rotation: sync vs async park latency on the
    admission path, outputs checked against a never-evicting pool."""
    mk = lambda: make_workload(cfg, n_requests=n_sessions, arrival_every=0)
    big = InferenceEngine(cfg, params, kstate, max_slots=n_sessions,
                          max_len=max_len)
    ref = big.run(mk())
    big.close()

    results = {}
    for mode, store_cfg in (("sync", StoreConfig()),
                            ("async", StoreConfig(async_transfers=True))):
        eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                              max_len=max_len, time_slice=time_slice,
                              kvstore=KVStore(store_cfg))
        t0 = time.perf_counter()
        out = eng.run(mk())
        wall = time.perf_counter() - t0
        stats = eng.kvstore.stats()
        results[mode] = {
            "wall_s": wall,
            "outputs_identical": out == ref,
            "parks": stats["kvstore/parks"],
            "park_p50_ms": stats.get("kvstore/park_p50_s", 0.0) * 1e3,
            "transfer_p50_ms":
                stats.get("kvstore/park_transfer_p50_s", 0.0) * 1e3,
        }
        eng.close()
    return {
        "n_sessions": n_sessions, "max_slots": max_slots,
        "time_slice": time_slice,
        "sync": results["sync"], "async": results["async"],
        # the headline: what the admission path pays per park
        "park_admission_p50_ms": {
            "sync": results["sync"]["park_p50_ms"],
            "async_enqueue": results["async"]["park_p50_ms"],
        },
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller model + workload (CI regression gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the summary record as JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless both disaggregated runs are "
                         "bit-identical to the monolithic engine and the "
                         "async-park run parked enough to be meaningful")
    args = ap.parse_args(argv)

    if args.smoke:
        cfg, params, kstate = build_model(num_layers=2, d_model=128,
                                          num_heads=4, num_kv_heads=2,
                                          d_ff=256)
        n_requests, n_sessions, max_slots = 8, 12, 4
    else:
        cfg, params, kstate = build_model()
        n_requests, n_sessions, max_slots = 12, 16, 4
    max_len = 128
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{n_requests} requests over {max_slots} slots per pool")

    dg = bench_disagg(cfg, params, kstate, n_requests, max_slots, max_len)
    print(f"disagg loopback: prefill {dg['loopback']['prefill_wall_s']:.2f}s"
          f" + decode {dg['loopback']['decode_wall_s']:.2f}s vs monolithic "
          f"{dg['monolithic_wall_s']:.2f}s, "
          f"{dg['loopback']['blob_bytes']/2**20:.1f} MiB shipped, "
          f"identical: {dg['loopback']['outputs_identical']}")
    print(f"disagg tcp: get p50 {dg['tcp']['get_p50_ms']:.2f} ms, "
          f"{dg['tcp']['blob_bytes_in']/2**20:.1f} MiB pulled, "
          f"identical: {dg['tcp']['outputs_identical']}")

    ap_ = bench_async_park(cfg, params, kstate, n_sessions, max_slots,
                           max_len)
    print(f"async park: {ap_['async']['parks']:.0f} parks; admission p50 "
          f"sync {ap_['sync']['park_p50_ms']:.3f} ms vs async enqueue "
          f"{ap_['async']['park_p50_ms']:.3f} ms (background transfer p50 "
          f"{ap_['async']['transfer_p50_ms']:.3f} ms), identical: "
          f"{ap_['async']['outputs_identical']}")

    if args.json:
        record = {"smoke": args.smoke, "model": cfg.name,
                  "params_m": cfg.param_count() / 1e6,
                  "disagg": dg, "async_park": ap_}
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")

    if args.check:
        ok = True
        for rail in ("loopback", "tcp"):
            if not dg[rail]["outputs_identical"]:
                print(f"FAIL: disaggregated ({rail}) token streams diverged "
                      f"from the monolithic engine", file=sys.stderr)
                ok = False
        for mode in ("sync", "async"):
            if not ap_[mode]["outputs_identical"]:
                print(f"FAIL: {mode}-park outputs diverged from the "
                      f"never-evicting pool", file=sys.stderr)
                ok = False
        if ap_["async"]["parks"] < 30:
            print(f"FAIL: only {ap_['async']['parks']:.0f} parks — the "
                  f"async path was not meaningfully exercised",
                  file=sys.stderr)
            ok = False
        if not ok:
            sys.exit(1)
        print("disagg gate passed: both pools bit-identical to monolithic, "
              "async park bit-exact under rotation")


if __name__ == "__main__":
    main()
