"""Roofline analysis (§Roofline): three terms per (arch x cell x mesh).

Terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute_s    = analytic_FLOPs / (chips x 197e12)
  memory_s     = analytic_HBM_bytes_per_chip / 819e9
  collective_s = loop-aware HLO collective bytes_per_chip (weighted) / 50e9

Why analytic FLOPs/bytes instead of cost_analysis(): XLA's cost analysis
does NOT multiply while-loop (lax.scan) bodies by trip count, so a
48-layer scanned stack reports ~1/48th of its FLOPs; the CPU backend also
upcasts bf16 dots to f32, inflating bytes. The collective term CAN be
recovered exactly from HLO because the while nesting structure is visible
in the text (see repro.launch.dryrun.collective_bytes). The HLO-reported
flops are kept in the record for reference.

Analytic model (per step, global):
  matmul FLOPs        fwd = 2 * N_matmul_active * tokens;  train x3 (bwd),
                      +1 fwd if remat=full (recompute)  -> 8NT counted in
                      `expected`, while MODEL_FLOPS (the "useful" number)
                      stays 6NT per the task spec.
  attention FLOPs     per attn layer fwd = 4*B*S*K_eff*H*dh
                      K_eff: full causal S/2; blocked-local ~1.5w;
                      routing k clusters x w^2/S ~= S/k_clusters (+ n*k
                      assignment matmul); decode: K_eff = cache length
                      (full) / 2w (local) / cap (routing pages).
  ssd FLOPs           fwd ~= 2*B*S*(3*d_in*N_state) + intra-chunk
                      2*B*S*Q*H*P  (mamba2 dual form).
  moe dispatch        einsum dispatch+combine: 2 * 2*B*S*E_local_capacity*d.
  HBM bytes/chip      params traffic (x3 train passes, x1 inference; FSDP
                      gathers still land+read in HBM so full-model bytes),
                      optimizer moment r/w, activation r/w with remat,
                      logits, decode KV-cache read (the decode bottleneck).

MFU-style score: est_step = max(terms); train/prefill report
mfu = (6NT ideal)/est_step; decode reports bandwidth fraction
memory_s/est_step (decode is bandwidth-bound by definition).
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results.json")
OUT = os.path.join(os.path.dirname(__file__), "roofline.json")
CHIPS = {"pod": 256, "multipod": 512}


def _cfg_cell(arch, cell_name, variant):
    from repro.configs import cell_by_name, get_config, routing_for_seq, \
        with_routing
    cfg = get_config(arch)
    cell = cell_by_name(cell_name)
    if variant == "routing":
        cfg = routing_for_seq(with_routing(cfg), cell.seq_len)
    return cfg, cell


def _attn_layers(cfg) -> int:
    from repro.models.transformer import per_layer_specs
    return sum(1 for s in per_layer_specs(cfg)
               if s.kind in ("attn", "moe", "cross"))


def _k_eff(cfg, cell, mode: str) -> float:
    S = cell.seq_len
    if cell.kind == "decode":
        if mode == "full":
            return S
        if mode == "local":
            return 2 * cfg.attn_window
        kc = cfg.routing.num_clusters
        return cfg.routing.window or max(1, S // kc)
    if mode == "full":
        return S / 2
    if mode == "local":
        w = cfg.attn_window if cfg.family == "hybrid" \
            else cfg.routing.local_window
        return 1.5 * w
    kc = cfg.routing.num_clusters
    w = cfg.routing.window or max(1, S // kc)
    return (kc * w * w) / S / 2          # balanced clusters, causal half


def analytic_flops(arch: str, cell_name: str, variant: str) -> Dict:
    cfg, cell = _cfg_cell(arch, cell_name, variant)
    B, S = cell.global_batch, cell.seq_len
    toks = B * (1 if cell.kind == "decode" else S)
    dh, H = cfg.head_dim_, cfg.num_heads
    n_attn = _attn_layers(cfg)
    N_act = cfg.active_param_count()
    # ---- matmul term (params touched per token)
    mat = 2.0 * N_act * toks
    # ---- attention term
    attn = 0.0
    if cfg.family != "ssm":
        if cfg.attention == "local+routing":
            from repro.models.transformer import head_split
            Hl, Hr, _, _ = head_split(cfg)
            attn = 4.0 * toks * dh * (
                Hl * _k_eff(cfg, cell, "local")
                + Hr * _k_eff(cfg, cell, "routing")) * n_attn
            # routing assignment: n x k matmul per routing layer
            attn += 2.0 * toks * dh * cfg.routing.num_clusters * Hr * n_attn
        else:
            mode = {"full": "full", "local": "local",
                    "routing": "routing"}.get(cfg.attention, "full")
            attn = 4.0 * toks * dh * H * _k_eff(cfg, cell, mode) * n_attn
    # ---- ssd term
    ssd = 0.0
    if cfg.family == "ssm":
        from repro.models.ssm import ssm_spec
        s = ssm_spec(cfg)
        q = 1 if cell.kind == "decode" else min(s.chunk, S)
        ssd = (2.0 * toks * 3 * s.d_inner * s.nstate
               + 2.0 * toks * q * s.nheads * s.headdim) * cfg.num_layers
    # ---- moe dispatch term (einsum dispatch/combine)
    moe = 0.0
    if cfg.family == "moe":
        E = cfg.moe_experts
        C = max(1, int(cfg.moe_capacity_factor
                       * (1 if cell.kind == "decode" else S) / E))
        n_moe = len([i for i in range(cfg.num_layers)
                     if i % cfg.moe_interleave == 0])
        # dispatch + combine einsums: (B,N,E,C) x (B,N,d) each
        moe = 2 * 2.0 * B * (1 if cell.kind == "decode" else S) \
            * E * C * cfg.d_model * n_moe
    fwd = mat + attn + ssd + moe
    mult = 3.0 if cell.kind == "train" else 1.0
    remat_extra = fwd if cell.kind == "train" else 0.0
    total = fwd * mult + remat_extra
    useful = (6.0 if cell.kind == "train" else 2.0) * N_act * toks
    return {"total": total, "useful": useful, "fwd": fwd}


def analytic_bytes_per_chip(arch: str, cell_name: str, variant: str,
                            chips: int) -> float:
    cfg, cell = _cfg_cell(arch, cell_name, variant)
    B, S = cell.global_batch, cell.seq_len
    N = cfg.param_count()
    pbytes = 2.0                     # bf16 params
    d = cfg.d_model
    L = cfg.num_layers
    if cell.kind == "train":
        toks_local = B * S / chips
        # params: fwd + bwd + remat reads + grad write (model is spread over
        # at most `chips`; FSDP gathers still land in HBM and get read)
        model_io = 4.0 * N * pbytes / min(chips, 256)
        opt_io = 2.0 * N * (4.0 if cfg.param_count() < 20e9 else 0.5) / chips
        act_io = toks_local * d * 2.0 * L * 4.0      # save+read, remat pass
        logits = toks_local * cfg.vocab_size * 4.0 / 16 * 2
        return model_io + opt_io + act_io + logits
    if cell.kind == "prefill":
        toks_local = B * S / chips
        return N * pbytes / min(chips, 16) + toks_local * d * 2.0 * L * 2.0
    # decode: read the whole local model shard + local cache once
    model_local = N * pbytes / 16                    # TP-sharded params
    if cfg.param_count() > 20e9:
        model_local = N * pbytes / chips             # FSDP-sharded
    cache_local = _cache_bytes(cfg, cell) / chips
    return model_local + cache_local


def _cache_bytes(cfg, cell) -> float:
    B, S = cell.global_batch, cell.seq_len
    dh = cfg.head_dim_
    if cfg.family == "ssm":
        from repro.models.ssm import ssm_spec
        s = ssm_spec(cfg)
        return B * s.nheads * s.nstate * s.headdim * 4.0 * cfg.num_layers
    n_attn = _attn_layers(cfg)
    if cfg.attention == "full":
        return 2.0 * B * cfg.num_kv_heads * S * dh * 2.0 * n_attn
    if cfg.attention == "local":
        return 2.0 * B * cfg.num_kv_heads * 2 * cfg.attn_window * dh * 2.0 \
            * n_attn
    # local+routing: ring + the one page each query reads
    from repro.models.transformer import head_split
    Hl, Hr, kvl, kvr = head_split(cfg)
    kc = cfg.routing.num_clusters
    cap = cfg.routing.window or max(1, S // kc)
    ring = 2.0 * B * kvl * 2 * cfg.routing.local_window * dh * 2.0
    page = 2.0 * B * Hr * cap * dh * 2.0
    return (ring + page) * n_attn


def roofline_row(key: str, rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    arch, cell, mesh, variant = key.split("|")
    chips = CHIPS[mesh]
    fl = analytic_flops(arch, cell, variant)
    t_c = fl["total"] / (chips * PEAK_FLOPS)
    t_m = analytic_bytes_per_chip(arch, cell, variant, chips) / HBM_BW
    w = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}
    coll = rec["collectives"]
    t_x = sum(coll[k]["bytes"] * w[k] for k in w) / ICI_BW
    est = max(t_c, t_m, t_x)
    dom = {t_c: "compute", t_m: "memory", t_x: "collective"}[est]
    ideal = fl["useful"] / (chips * PEAK_FLOPS)
    kind = "decode" if cell.startswith(("decode", "long")) else "train"
    score = (t_m / est) if kind == "decode" else (ideal / est)
    return {
        "arch": arch, "cell": cell, "mesh": mesh, "variant": variant,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "est_step_s": est, "dominant": dom,
        "model_flops": fl["useful"], "analytic_flops": fl["total"],
        "hlo_flops_per_dev": rec["flops_per_device"],
        "useful_ratio": fl["useful"] / fl["total"],
        "score": score, "score_kind": "bw_frac" if kind == "decode"
        else "mfu",
        "peak_gib": rec["peak_device_bytes"] / 2 ** 30,
        "fits_16g": rec["peak_device_bytes"] < 16 * 2 ** 30,
        "coll_raw_gib": coll.get("raw_total_bytes", 0) / 2 ** 30,
        "coll_gib": coll["total_bytes"] / 2 ** 30,
    }


def build(results_path: str = RESULTS) -> Dict[str, Dict]:
    with open(results_path) as f:
        res = json.load(f)
    rows = {}
    for key, rec in sorted(res.items()):
        row = roofline_row(key, rec)
        if row:
            rows[key] = row
    return rows


def markdown_table(rows: Dict[str, Dict], mesh: str = "pod") -> str:
    hdr = ("| arch | cell | var | compute s | memory s | coll s | dom | "
           "6ND/analytic | score | peak GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for key, r in rows.items():
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['variant'][:4]} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['dominant'][:4]} "
            f"| {r['useful_ratio']:.2f} "
            f"| {r['score']:.2f} ({r['score_kind']}) "
            f"| {r['peak_gib']:.1f} | {'y' if r['fits_16g'] else 'N'} |")
    return hdr + "\n".join(lines)


# ---------------------------------------------------------------------------
# Attention-op roofline: routing (paged fused) vs flash, the O(n^1.5)
# crossover (§Roofline, op level — feeds BENCH_routing.json)
# ---------------------------------------------------------------------------
ATTN_SEQ_LENS = (1024, 4096, 8192, 16384, 32768)


def _attn_terms(N: int, B: int, H: int, dh: int, impl: str,
                dtype_bytes: float = 2.0) -> Dict:
    """Analytic FLOPs + HBM bytes for one attention op at sequence N.

    ``flash``    full causal: every query scores N/2 keys -> O(n^2) FLOPs;
                 q/k/v/o planes streamed once -> 4*N*dh bytes/head.
    ``routing``  paged fused kernel, paper scaling kc = w = sqrt(N):
                 each query scores w/2 in-cluster keys (causal half) plus
                 the n x kc assignment matmul -> O(n^1.5) FLOPs. The pager
                 streams each sequence row into VMEM exactly once per
                 membership (per-row DMA), so bytes stay the same four
                 planes as flash + 4-byte membership indices — no
                 N-resident VMEM term and no gathered copies.
    ``gathered`` same FLOPs as routing, but the XLA gather materializes
                 (B,H,kc,w,dh) copies of q/k/v in HBM: one extra write +
                 one extra read of three planes (and the output scatter),
                 ~3x the plane traffic the fused kernel pays.
    """
    w = max(1.0, math.sqrt(N))
    kc = N / w
    plane = B * H * N * dh * dtype_bytes
    if impl == "flash":
        flops = 4.0 * B * H * N * (N / 2.0) * dh
        bytes_ = 4.0 * plane
    else:
        flops = (4.0 * B * H * N * (w / 2.0) * dh        # in-cluster scores
                 + 2.0 * B * H * N * kc * dh)            # assignment matmul
        bytes_ = 4.0 * plane + B * H * N * 4.0           # + int32 members
        if impl == "gathered":
            bytes_ += 2.0 * 3.0 * plane + plane          # copy w+r, scatter
    t_c, t_m = flops / PEAK_FLOPS, bytes_ / HBM_BW
    return {"flops": flops, "hbm_bytes": bytes_,
            "compute_s": t_c, "memory_s": t_m,
            "est_s": max(t_c, t_m),
            "bound": "compute" if t_c >= t_m else "memory"}


def attention_roofline(B: int = 1, H: int = 8, dh: int = 128,
                       seq_lens=ATTN_SEQ_LENS,
                       dtype_bytes: float = 2.0) -> Dict:
    """Routing-vs-flash roofline across N + the predicted crossover: the
    smallest N where the routing op's est time beats flash on a v5e.
    Below it both ops sit on the same memory roof (identical plane
    traffic) and flash's simpler schedule wins in practice; past it
    flash goes compute-bound on its O(n^2) term while routing stays on
    the O(n^1.5) curve — est_s ratios grow ~sqrt(N) from there."""
    points = []
    for N in seq_lens:
        row = {"N": N}
        for impl in ("flash", "routing", "gathered"):
            row[impl] = _attn_terms(N, B, H, dh, impl, dtype_bytes)
        row["routing_speedup_vs_flash"] = round(
            row["flash"]["est_s"] / row["routing"]["est_s"], 3)
        row["paged_vs_gathered_bytes"] = round(
            row["gathered"]["hbm_bytes"] / row["routing"]["hbm_bytes"], 3)
        points.append(row)
    crossover = None
    for N in range(256, max(seq_lens) + 1, 256):
        if (_attn_terms(N, B, H, dh, "routing", dtype_bytes)["est_s"]
                < _attn_terms(N, B, H, dh, "flash", dtype_bytes)["est_s"]):
            crossover = N
            break
    return {"arch": "tpu_v5e",
            "peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
            "shape": {"B": B, "H": H, "dh": dh,
                      "dtype_bytes": dtype_bytes,
                      "window": "sqrt(N)"},
            "predicted_crossover_n": crossover,
            "points": points}


def attention_markdown(rec: Dict) -> str:
    hdr = ("| N | flash est s | routing est s | flash bound | "
           "routing bound | routing speedup | gathered/paged bytes |\n"
           "|---|---|---|---|---|---|---|\n")
    lines = []
    for p in rec["points"]:
        lines.append(
            f"| {p['N']} | {p['flash']['est_s']:.2e} "
            f"| {p['routing']['est_s']:.2e} "
            f"| {p['flash']['bound'][:4]} | {p['routing']['bound'][:4]} "
            f"| {p['routing_speedup_vs_flash']:.2f}x "
            f"| {p['paged_vs_gathered_bytes']:.2f}x |")
    return hdr + "\n".join(lines)


def main():
    import sys
    if "--attention" in sys.argv[1:]:
        rec = attention_roofline()
        print(f"attention roofline (v5e, w = sqrt(N)); predicted "
              f"routing-beats-flash crossover at N = "
              f"{rec['predicted_crossover_n']}")
        print(attention_markdown(rec))
        return
    rows = build()
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1)
    for mesh in ("pod", "multipod"):
        print(f"\n===== mesh: {mesh} ({CHIPS[mesh]} chips) =====")
        print(markdown_table(rows, mesh))
    pod = [r for r in rows.values() if r["mesh"] == "pod"]
    print("\nworst scores (pod):")
    for r in sorted(pod, key=lambda r: r["score"])[:5]:
        print(f"  {r['arch']}|{r['cell']}|{r['variant']}: "
              f"{r['score']:.3f} ({r['score_kind']}) dom={r['dominant']}")
    print("most collective-bound (pod):")
    for r in sorted(pod, key=lambda r: -(r["collective_s"]
                                         / max(r["est_step_s"], 1e-12)))[:5]:
        print(f"  {r['arch']}|{r['cell']}|{r['variant']}: "
              f"coll={r['collective_s']:.2e}s of est {r['est_step_s']:.2e}s")


if __name__ == "__main__":
    main()
