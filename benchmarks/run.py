"""Benchmark entry point: one harness per paper table + roofline summary.

Prints ``name,us_per_call,derived`` CSV (one row per measured entity).
``us_per_call`` is the reduced-config CPU step wall-time; ``derived``
carries the table's quantity (paper reference value, measured ratio, JSD,
bits/dim, ...). TPU-projected numbers live in the roofline table
(EXPERIMENTS.md §Roofline), not here.
"""
import sys


def main() -> None:
    from benchmarks.tables import ALL_TABLES
    print("name,us_per_call,derived")
    for table in ALL_TABLES:
        for name, us, derived in table():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    main()
