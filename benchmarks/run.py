"""Benchmark entry point: one harness per paper table + roofline summary.

Prints ``name,us_per_call,derived`` CSV (one row per measured entity).
``us_per_call`` is the reduced-config CPU step wall-time; ``derived``
carries the table's quantity (paper reference value, measured ratio, JSD,
bits/dim, ...). TPU-projected numbers live in the roofline table
(EXPERIMENTS.md §Roofline), not here.

``--backend-sweep`` appends one row per registered attention backend
(repro.attn registry) with tok/s + peak-memory, so backend regressions
show up in the same report tables; ``--backend-sweep-only`` skips the
paper tables (fast per-push trend line).

``--routing-sweep`` appends the gathered-vs-fused routing kernel rows
across N in {1k, 4k, 8k} x {xla, pallas, pallas_fused, and both forced
fused memory plans} (tok/s + memory_analysis peak + device kind +
interpret flag) and rewrites ``BENCH_routing.json`` at the repo root —
the routing hot-spot's perf trajectory, including the analytic
routing-vs-flash roofline crossover; ``--routing-sweep-only`` runs just
that (the push-time CI bench job). ``--routing-check`` additionally
gates the sweep: output parity vs the xla reference always, and
paged-fused >= gathered tok/s when running on real TPU hardware.

``--obs-sweep`` appends routing-health telemetry rows (occupancy entropy
vs log k, dead clusters, balanced-vs-nearest mismatch, sampled attention
recall, stats-on tok/s) per sequence length; ``--obs-sweep-only`` runs
just those.
"""
import sys


FLAGS = ("--backend-sweep", "--backend-sweep-only",
         "--routing-sweep", "--routing-sweep-only", "--routing-check",
         "--obs-sweep", "--obs-sweep-only")


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    unknown = [a for a in argv if a not in FLAGS]
    if unknown:
        raise SystemExit(f"unknown arguments {unknown}; known: {FLAGS}")
    sweep = "--backend-sweep" in argv or "--backend-sweep-only" in argv
    routing_check = "--routing-check" in argv
    routing = ("--routing-sweep" in argv or "--routing-sweep-only" in argv
               or routing_check)
    obs = "--obs-sweep" in argv or "--obs-sweep-only" in argv
    # any -only flag skips the paper tables; the sweeps themselves compose
    tables = not any(a.endswith("-only") for a in argv)
    print("name,us_per_call,derived")
    if tables:
        from benchmarks.tables import ALL_TABLES
        for table in ALL_TABLES:
            for name, us, derived in table():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
    if sweep:
        from benchmarks.backend_sweep import backend_sweep_rows
        for name, us, derived in backend_sweep_rows():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
    if routing:
        from benchmarks.routing_sweep import routing_sweep_rows, write_json
        rows, record = routing_sweep_rows(check=routing_check)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        write_json(record)
    if obs:
        from benchmarks.obs_sweep import obs_sweep_rows
        for name, us, derived in obs_sweep_rows():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    main()
