"""Benchmark entry point: one harness per paper table + roofline summary.

Prints ``name,us_per_call,derived`` CSV (one row per measured entity).
``us_per_call`` is the reduced-config CPU step wall-time; ``derived``
carries the table's quantity (paper reference value, measured ratio, JSD,
bits/dim, ...). TPU-projected numbers live in the roofline table
(EXPERIMENTS.md §Roofline), not here.

``--backend-sweep`` appends one row per registered attention backend
(repro.attn registry) with tok/s + peak-memory, so backend regressions
show up in the same report tables; ``--backend-sweep-only`` skips the
paper tables (fast per-push trend line).
"""
import sys


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    sweep = "--backend-sweep" in argv or "--backend-sweep-only" in argv
    tables = "--backend-sweep-only" not in argv
    print("name,us_per_call,derived")
    if tables:
        from benchmarks.tables import ALL_TABLES
        for table in ALL_TABLES:
            for name, us, derived in table():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
    if sweep:
        from benchmarks.backend_sweep import backend_sweep_rows
        for name, us, derived in backend_sweep_rows():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    main()
