"""Tiered KV store: resume-vs-re-prefill speedup, oversubscription, and
prefix-cache hit rate (DESIGN.md §11).

Three measurements on one model:

  resume vs re-prefill   the latency of bringing a parked session back
                         (KVStore.resume + write_slot) against recomputing
                         its lane from the prompt (prefill + write_slot).
                         Resume is a host→device copy and skips the model
                         forward pass entirely, so it must win by a wide
                         margin — the ``--min-speedup`` gate (CI: 2x)
                         fails the run if it does not.
  oversubscription       sessions ≫ slots through the engine with
                         time-slice rotation: parks/resumes, bytes moved,
                         park/resume p50 latency, and a bit-exactness
                         check against a never-evicting pool of
                         ``n_sessions`` slots.
  prefix hit rate        many sessions sharing few distinct prompts with
                         a PrefixCache: measured hit rate must equal
                         1 - unique/total (exact full-prompt keying).

Run:  PYTHONPATH=src python -m benchmarks.kv_offload
CI:   PYTHONPATH=src python -m benchmarks.kv_offload --smoke \
          --json benchmarks/kv_offload_smoke.json --min-speedup 2.0
"""
from __future__ import annotations

import argparse
import functools
import json
import statistics
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.serve_engine import build_model, make_workload
from repro.serve.engine import InferenceEngine, init_pool, write_slot
from repro.serve.kvstore import KVStore, PrefixCache
from repro.serve.serving import init_cache, prefill


def _prefill_lane(cfg, params, kstate, prompt: List[int], max_len: int,
                  jit_prefill):
    lane = init_cache(cfg, 1, max_len)
    _, lane = jit_prefill(params, kstate, lane,
                          {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    return lane


def bench_resume_vs_prefill(cfg, params, kstate, prompt_len: int,
                            max_len: int, trials: int = 7) -> dict:
    """Median wall time of resume-into-slot vs re-prefill-into-slot."""
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, size=prompt_len).tolist()
    jit_prefill = jax.jit(functools.partial(prefill, cfg=cfg))
    pool = init_pool(cfg, 2, max_len)
    lane = _prefill_lane(cfg, params, kstate, prompt, max_len, jit_prefill)
    store = KVStore()
    # warm both paths (compile prefill/write_slot; touch the store once)
    store.park(0, lane)
    jax.block_until_ready(write_slot(pool, 0, store.resume(0)))
    jax.block_until_ready(write_slot(
        pool, 0, _prefill_lane(cfg, params, kstate, prompt, max_len,
                               jit_prefill)))

    t_resume, t_prefill = [], []
    for _ in range(trials):
        store.park(0, lane)             # park cost not charged to resume
        t0 = time.perf_counter()
        p = write_slot(pool, 0, store.resume(0))
        jax.block_until_ready(p)
        t_resume.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        fresh = _prefill_lane(cfg, params, kstate, prompt, max_len,
                              jit_prefill)
        p = write_slot(pool, 0, fresh)
        jax.block_until_ready(p)
        t_prefill.append(time.perf_counter() - t0)
    resume_s = statistics.median(t_resume)
    prefill_s = statistics.median(t_prefill)
    return {
        "prompt_len": prompt_len,
        "resume_ms": resume_s * 1e3,
        "reprefill_ms": prefill_s * 1e3,
        "speedup": prefill_s / resume_s if resume_s else float("nan"),
        "parked_bytes": store.stats()["kvstore/bytes_to_host"] / (trials + 1),
    }


def bench_oversubscription(cfg, params, kstate, n_sessions: int,
                           max_slots: int, max_len: int,
                           time_slice: int = 4) -> dict:
    """n_sessions through max_slots lanes; outputs must match a pool big
    enough to never evict."""
    mk = lambda: make_workload(cfg, n_requests=n_sessions, arrival_every=0)
    big = InferenceEngine(cfg, params, kstate, max_slots=n_sessions,
                          max_len=max_len)
    out_big = big.run(mk())

    eng = InferenceEngine(cfg, params, kstate, max_slots=max_slots,
                          max_len=max_len, time_slice=time_slice)
    t0 = time.perf_counter()
    out = eng.run(mk())
    wall_s = time.perf_counter() - t0
    stats = eng.kvstore.stats()
    summ = eng.metrics.summary()
    return {
        "n_sessions": n_sessions, "max_slots": max_slots,
        "time_slice": time_slice, "wall_s": wall_s,
        "outputs_identical": out == out_big,
        "parks": summ["parks"], "resumes": summ["resumes"],
        "bytes_to_host": stats["kvstore/bytes_to_host"],
        "bytes_to_device": stats["kvstore/bytes_to_device"],
        "park_p50_ms": stats.get("kvstore/park_p50_s", 0.0) * 1e3,
        "resume_p50_ms": stats.get("kvstore/resume_p50_s", 0.0) * 1e3,
        "tokens_per_step": summ["tokens_per_step"],
    }


def bench_prefix_hit_rate(cfg, params, kstate, n_sessions: int,
                          n_unique: int, max_len: int) -> dict:
    """n_sessions drawn round-robin from n_unique distinct prompts."""
    from repro.serve.engine import Request
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=24).tolist()
               for _ in range(n_unique)]
    reqs = [Request(uid=i, prompt=list(prompts[i % n_unique]),
                    max_new_tokens=8, arrival_step=i)
            for i in range(n_sessions)]
    pc = PrefixCache()
    eng = InferenceEngine(cfg, params, kstate, max_slots=2, max_len=max_len,
                          prefix_cache=pc)
    eng.run(reqs)
    return {
        "n_sessions": n_sessions, "n_unique_prompts": n_unique,
        "hit_rate": pc.hit_rate,
        "expected_hit_rate": 1.0 - n_unique / n_sessions,
        "hits": pc.stats()["kvstore/prefix_hits"],
        "misses": pc.stats()["kvstore/prefix_misses"],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller model + workload (CI regression gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the summary record as JSON")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit nonzero if resume is not at least this many "
                         "times faster than re-prefill (or outputs diverge, "
                         "or the prefix hit rate is off)")
    args = ap.parse_args(argv)

    if args.smoke:
        cfg, params, kstate = build_model(num_layers=2, d_model=128,
                                          num_heads=4, num_kv_heads=2,
                                          d_ff=256)
        prompt_len, n_sessions, max_slots = 48, 12, 4
    else:
        cfg, params, kstate = build_model()
        prompt_len, n_sessions, max_slots = 128, 16, 4
    max_len = prompt_len + 64
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{n_sessions} sessions over {max_slots} slots, "
          f"max_len={max_len}")

    rv = bench_resume_vs_prefill(cfg, params, kstate, prompt_len, max_len)
    print(f"resume {rv['resume_ms']:.2f} ms vs re-prefill "
          f"{rv['reprefill_ms']:.2f} ms (prompt {rv['prompt_len']} tok, "
          f"parked {rv['parked_bytes']/1024:.0f} KiB) -> "
          f"{rv['speedup']:.1f}x")

    ov = bench_oversubscription(cfg, params, kstate, n_sessions, max_slots,
                                max_len)
    print(f"oversubscription: {ov['parks']} parks / {ov['resumes']} resumes, "
          f"park p50 {ov['park_p50_ms']:.2f} ms, resume p50 "
          f"{ov['resume_p50_ms']:.2f} ms, "
          f"{ov['bytes_to_host']/2**20:.1f} MiB offloaded, "
          f"outputs identical: {ov['outputs_identical']}")

    pf = bench_prefix_hit_rate(cfg, params, kstate, n_sessions,
                               n_unique=max(2, n_sessions // 4),
                               max_len=max_len)
    print(f"prefix cache: {pf['hits']:.0f} hits / {pf['misses']:.0f} misses "
          f"-> hit rate {pf['hit_rate']:.2f} "
          f"(expected {pf['expected_hit_rate']:.2f})")

    if args.json:
        record = {"smoke": args.smoke, "model": cfg.name,
                  "params_m": cfg.param_count() / 1e6,
                  "resume_vs_prefill": rv, "oversubscription": ov,
                  "prefix_cache": pf}
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")

    if args.min_speedup is not None:
        ok = True
        if not ov["outputs_identical"]:
            print("FAIL: park/resume outputs diverged from the "
                  "never-evicting pool", file=sys.stderr)
            ok = False
        if not (ov["parks"] > 0 and ov["resumes"] > 0):
            print("FAIL: oversubscription exercised no park/resume",
                  file=sys.stderr)
            ok = False
        if not rv["speedup"] >= args.min_speedup:   # NaN fails too
            print(f"FAIL: resume {rv['speedup']:.2f}x < required "
                  f"{args.min_speedup:.2f}x re-prefill", file=sys.stderr)
            ok = False
        if abs(pf["hit_rate"] - pf["expected_hit_rate"]) > 1e-9:
            print(f"FAIL: prefix hit rate {pf['hit_rate']:.3f} != expected "
                  f"{pf['expected_hit_rate']:.3f}", file=sys.stderr)
            ok = False
        if not ok:
            sys.exit(1)
        print(f"kv-offload gate passed: resume {rv['speedup']:.2f}x >= "
              f"{args.min_speedup:.2f}x, bit-exact, prefix hit rate on "
              f"target")


if __name__ == "__main__":
    main()
