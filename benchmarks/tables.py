"""Benchmark harnesses, one per paper table (Roy et al. 2020).

Tables 1-5 and 7 report model quality/speed from multi-week TPUv3 runs;
on this CPU container each harness (a) builds the *exact* published
architecture, (b) measures the step mechanics on a structure-preserving
reduced config, and (c) reports the paper's published value as the
reference target next to the reduced-scale measurement. Table 6 (JSD
analysis) is reproduced *for real* at reduced scale — it is a property of
the mechanism, not of weeks of training.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (nats_to_bits_per_dim, shrink, time_step,
                               train_step_time)
from repro.configs import paper
from repro.configs.base import with_overrides

Row = Tuple[str, float, str]


# ---------------------------------------------------------------------------
# Table 1 — CIFAR-10 ablations (routing heads/layers x window, steps/sec)
# ---------------------------------------------------------------------------
def table1_cifar10() -> List[Row]:
    rows: List[Row] = []
    grid = [(0, 0, 512), (2, 2, 512), (4, 4, 512), (8, 12, 512),
            (4, 4, 1024)]
    paper_bpd = {(0, 0, 512): 3.009, (2, 2, 512): 3.005, (4, 4, 512): 2.975,
                 (8, 12, 512): 3.400, (4, 4, 1024): 2.950}
    base_us = None
    for rh, rl, w in grid:
        cfg = shrink(paper.cifar10(rh, rl, w), layers=4, seq=256)
        us, loss = train_step_time(cfg, seq=256)
        if rh == 0:
            base_us = us
        rows.append((f"table1/cifar10_r{rh}x{rl}_w{w}", us,
                     f"paper_bpd={paper_bpd[(rh, rl, w)]};"
                     f"rel_step_time={us / base_us:.2f};loss={loss:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# Tables 2/3/5 — LM perplexity configs (wikitext-103 / enwik8 / pg19)
# ---------------------------------------------------------------------------
def _lm_table(name: str, cfg_full, paper_value: str) -> List[Row]:
    cfg = shrink(cfg_full, layers=3, seq=512)
    us, loss = train_step_time(cfg, seq=512)
    full = cfg_full
    return [(f"{name}/{full.name}", us,
             f"{paper_value};params={full.param_count()/1e6:.0f}M;"
             f"reduced_loss={loss:.2f}")]


def table2_wikitext103() -> List[Row]:
    return _lm_table("table2", paper.wikitext103(),
                     "paper_test_ppl=15.8_vs_txl_18.3")


def table3_enwik8() -> List[Row]:
    return _lm_table("table3", paper.enwik8(),
                     "paper_bpb=0.99_vs_adaptive_0.98")


def table5_pg19() -> List[Row]:
    return _lm_table("table5", paper.pg19(),
                     "paper_test_ppl=33.2_SOTA_vs_compressive_33.6")


# ---------------------------------------------------------------------------
# Table 4 — ImageNet-64 bits/dim
# ---------------------------------------------------------------------------
def table4_imagenet64() -> List[Row]:
    cfg = shrink(paper.imagenet64(), layers=3, seq=512)
    us, loss = train_step_time(cfg, seq=512)
    bpd = nats_to_bits_per_dim(loss)
    return [("table4/rt-imagenet64", us,
             f"paper_bpd=3.43_vs_sparse_tx_3.44;reduced_bpd={bpd:.2f}")]


# ---------------------------------------------------------------------------
# Table 6 — Jensen-Shannon divergence between local and routing heads
# ---------------------------------------------------------------------------
def _jsd(p: np.ndarray, q: np.ndarray) -> float:
    m = 0.5 * (p + q)

    def kl(a, b):
        mask = a > 0
        return float(np.sum(a[mask] * np.log(a[mask] / np.maximum(
            b[mask], 1e-20))))

    return 0.5 * kl(p, m) + 0.5 * kl(q, m)


def table6_jsd() -> List[Row]:
    """Reproduces the paper's analysis: attention distributions of routing
    heads diverge strongly from local heads (JSD near the ln2 ~= 0.693
    bound), while local||local stays low. Computed from an actual reduced
    Routing Transformer forward pass (real mechanism, reduced scale)."""
    from repro.configs.base import ModelConfig, RoutingConfig
    from repro.core.kmeans import init_kmeans, normalize_routing
    from repro.core.routing import routed_attention
    from repro.core.local import local_attention
    from repro.models.model import init_model
    from repro.models import layers as L

    N, dh, H = 256, 16, 4
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=H, num_kv_heads=H,
                      d_ff=128, vocab_size=128, attention="local+routing",
                      routing=RoutingConfig(num_clusters=8, local_window=32),
                      dtype="float32")
    params, kstate = init_model(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.RandomState(0).randint(0, 128, (1, N)))
    x = L.embed(params["embed"], toks)
    p0 = params["stack"][0]
    attn_p = jax.tree.map(lambda a: a[0], p0)[0]["attn"]
    h = L.apply_norm(jax.tree.map(lambda a: a[0], p0)[0]["ln1"], x, cfg.norm)
    q, k, v = L.qkv_project(attn_p, h, cfg, rope=False)

    # local head attention distribution over the full sequence
    w = 32
    pos = np.arange(N)
    blk = pos // w
    keep = ((blk[:, None] - blk[None, :] >= 0)
            & (blk[:, None] - blk[None, :] <= 1)
            & (pos[:, None] >= pos[None, :]))
    s = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(q.shape[-1])
    s = jnp.where(jnp.asarray(keep)[None, None], s, -1e9)
    local_attn = np.asarray(jax.nn.softmax(s, -1))        # (1,H,N,N)

    # routing head attention scattered back to (N, N) — use the routing
    # half of the heads (the paper's split), whose centroids live in kstate
    from repro.core.kmeans import KMeansState
    from repro.models.transformer import head_split
    Hl, Hr, _, _ = head_split(cfg)
    mu = kstate[0]["0"][0]                                # (Hr, kc, dh)
    ro = routed_attention(q[:, Hl:], None, v[:, Hl:], KMeansState(mu=mu),
                          cfg.routing, return_attn=True)
    kc, wsz = ro.q_idx.shape[2], ro.q_idx.shape[3]
    routing_attn = np.zeros((1, Hr, N, N))
    qi = np.asarray(ro.q_idx)
    at = np.asarray(ro.attn)
    for hh in range(Hr):
        for c in range(kc):
            rows_ = qi[0, hh, c]
            routing_attn[0, hh, rows_[:, None], rows_[None, :]] += \
                at[0, hh, c]
    routing_attn /= np.maximum(routing_attn.sum(-1, keepdims=True), 1e-20)

    t = N - 1      # the paper computes over the sequence; use the last row
    out: List[Row] = []
    ll = _jsd(local_attn[0, 0, t], local_attn[0, 1, t])
    lr = _jsd(local_attn[0, 0, t], routing_attn[0, 0, t])
    rr = _jsd(routing_attn[0, 0, t], routing_attn[0, 1, t])
    out.append(("table6/jsd_local_local", 0.0,
                f"jsd={ll:.3f};paper_range=0.00-0.31"))
    out.append(("table6/jsd_local_routing", 0.0,
                f"jsd={lr:.3f};paper_range=0.47-0.67;bound=0.693"))
    out.append(("table6/jsd_routing_routing", 0.0,
                f"jsd={rr:.3f};paper_range=0.16-0.58"))
    assert lr > ll, "routing heads must diverge from local heads"
    return out


# ---------------------------------------------------------------------------
# Table 7 — step-time: Local vs Routing Transformer (PG-19)
# ---------------------------------------------------------------------------
def table7_steptime() -> List[Row]:
    base = paper.pg19()
    local_only = with_overrides(base, attention="local")
    cfg_r = shrink(base, layers=3, seq=512)
    cfg_l = shrink(local_only, layers=3, seq=512)
    us_r, _ = train_step_time(cfg_r, seq=512)
    us_l, _ = train_step_time(cfg_l, seq=512)
    ratio = us_r / us_l
    return [("table7/local_transformer", us_l, "paper_steps_per_s=1.231"),
            ("table7/routing_transformer", us_r,
             f"paper_steps_per_s=0.7236;paper_ratio=1.70;"
             f"measured_ratio={ratio:.2f}")]


ALL_TABLES = [table1_cifar10, table2_wikitext103, table3_enwik8,
              table4_imagenet64, table5_pg19, table6_jsd, table7_steptime]
