"""--obs-sweep: routing-health telemetry rows across sequence lengths.

One row per N through the full ``routed_attention`` module with
``RoutingConfig.stats`` on: occupancy entropy against its log(k) ceiling,
dead clusters, balanced-vs-nearest mismatch, sampled attention recall,
plus the tok/s of the stats-on call — the health numbers reviewers should
watch drifting when routing code changes, in the same CSV the other
sweeps print.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.configs.base import RoutingConfig
from repro.core.kmeans import init_kmeans
from repro.core.routing import routed_attention

Row = Tuple[str, float, str]

B, H, DH = 2, 2, 64
WINDOW = 64
SEQ_LENS = (256, 512)


def obs_sweep_rows(iters: int = 3, seq_lens=SEQ_LENS) -> List[Row]:
    rows: List[Row] = []
    for N in seq_lens:
        kc = max(2, N // WINDOW)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, N, DH))
        v = jax.random.normal(ks[1], (B, H, N, DH))
        st = init_kmeans(ks[2], H, kc, DH)
        cfg = RoutingConfig(num_clusters=kc, stats=True)
        fn = jax.jit(lambda q, v: routed_attention(
            q, None, v, st, cfg, update_state=True))
        out = fn(q, v)
        jax.block_until_ready(out.out)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(q, v).out)
            ts.append(time.perf_counter() - t0)
        us = float(np.median(ts) * 1e6)
        st_ = jax.device_get(out.stats)
        ent = float(np.mean(st_.entropy))
        rows.append((
            f"obs_sweep/N{N}", us,
            f"entropy={ent:.3f}/logk={np.log(kc):.3f};"
            f"dead={float(np.mean(st_.dead)):.2f}/{kc};"
            f"mismatch={float(np.mean(st_.mismatch)):.3f};"
            f"recall={float(np.mean(st_.recall)):.3f};"
            f"drift={float(np.mean(st_.drift)):.4f};"
            f"tok_s={B * N / (us / 1e6):.0f}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in obs_sweep_rows():
        print(f"{name},{us:.1f},{derived}")
