"""Render EXPERIMENTS.md from dryrun_results.json + roofline.json +
perf_log.json (+ bench CSV if present). Rerunnable:
    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os

HERE = os.path.dirname(__file__)
ROOT = os.path.join(HERE, "..")


def load(name):
    p = os.path.join(HERE, name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return {}


def dryrun_section(res) -> str:
    lines = ["## §Dry-run — 40 cells x {16x16, 2x16x16} meshes", ""]
    lines.append(
        "Every (architecture x input-shape) cell lowers **and compiles** "
        "with the production sharding rules against 512 host-platform "
        "placeholder devices; `memory_analysis()` proves per-chip fit, "
        "`cost_analysis()` + loop-aware HLO parsing feed §Roofline. "
        "Statuses: `ok` = compiled; skips are explicit and justified "
        "(encoder has no decode; native quadratic attention cannot run "
        "524k decode — the routing-variant row runs instead, which is the "
        "paper's point).")
    lines.append("")
    ok = sum(1 for v in res.values() if v.get("status") == "ok")
    sk = sum(1 for v in res.values()
             if str(v.get("status", "")).startswith("skip"))
    er = sum(1 for v in res.values() if v.get("status") == "error")
    lines.append(f"**{len(res)} records: {ok} ok, {sk} explicit skips, "
                 f"{er} errors.**")
    lines.append("")
    lines.append("| arch | cell | mesh | variant | status | peak GiB/chip | "
                 "compile s | collective GiB/chip (loop-aware) |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for key in sorted(res):
        r = res[key]
        arch, cell, mesh, var = key.split("|")
        if r.get("status") == "ok":
            lines.append(
                f"| {arch} | {cell} | {mesh} | {var} | ok "
                f"| {r['peak_device_bytes']/2**30:.2f} "
                f"| {r.get('compile_s', 0):.1f} "
                f"| {r['collectives']['total_bytes']/2**30:.1f} |")
        else:
            lines.append(f"| {arch} | {cell} | {mesh} | {var} "
                         f"| {r['status']} | — | — | — |")
    lines.append("")
    lines.append(
        "Memory-analysis caveat: the XLA **CPU** backend upcasts bf16 dot "
        "operands to f32, so `peak GiB` overstates a real TPU lowering by "
        "up to ~2x on matmul-heavy bf16 cells (verified by buffer dumps — "
        "the excess buffers are `convert f32[...]` of bf16 weights). Cells "
        "over 16 GiB are annotated in §Perf with their TPU-corrected "
        "estimate and recommended placement.")
    return "\n".join(lines)


def roofline_section(rows) -> str:
    from benchmarks.roofline import markdown_table
    lines = ["## §Roofline — three terms per cell (TPU v5e constants)", ""]
    lines.append(
        "compute = analytic FLOPs /(chips x 197 TF/s bf16); memory = "
        "analytic HBM bytes/chip / 819 GB/s; collective = loop-aware HLO "
        "collective bytes/chip (all-reduce weighted 2x for RS+AG phases) "
        "/ 50 GB/s ICI. Analytic models are used for FLOPs/bytes because "
        "XLA cost analysis does not multiply while-loop (scan) bodies by "
        "trip count (verified: 36-layer stack under-reported 34x); the "
        "full formulas are in benchmarks/roofline.py's docstring. "
        "`score` is MFU-style for train/prefill (useful 6ND / est step) "
        "and HBM-bandwidth fraction for decode cells (decode is "
        "bandwidth-bound by definition). `6ND/analytic` exposes how much "
        "compiled compute is useful model FLOPs (remat + attention + "
        "dispatch overheads).")
    for mesh in ("pod", "multipod"):
        lines.append("")
        lines.append(f"### mesh: {mesh}")
        lines.append("")
        lines.append(markdown_table(rows, mesh))
    lines.append("")
    pod = [r for r in rows.values() if r["mesh"] == "pod"]
    if pod:
        worst = sorted(pod, key=lambda r: r["score"])[:3]
        lines.append("**Dominant-bottleneck summary (single pod):** " +
                     "; ".join(
                         f"{sum(1 for r in pod if r['dominant']==d)} cells "
                         f"{d}-bound" for d in ("compute", "memory",
                                                "collective")) + ".")
        lines.append("")
        lines.append("Worst scores: " + ", ".join(
            f"{r['arch']}/{r['cell']}[{r['variant']}]={r['score']:.2f}"
            for r in worst) + ".")
    return "\n".join(lines)


def perf_section(log) -> str:
    lines = ["## §Perf — hypothesis -> change -> measure log", ""]
    lines.append(
        "Three cells hillclimbed per the methodology (baseline-all, "
        "iterate the dominant term, stop at <5% x3). Every number below "
        "is a real compiled-artifact measurement from this repo "
        "(benchmarks/dryrun_results_v*.json hold the raw before/after "
        "records). Refuted hypotheses are kept — they localize the true "
        "bottleneck.")
    for cell_key in ("cell_A", "cell_B", "cell_C"):
        c = log.get(cell_key)
        if not c:
            continue
        lines.append("")
        lines.append(f"### {c['cell']}")
        lines.append("")
        lines.append("| # | hypothesis | change | before | after | verdict |")
        lines.append("|---|---|---|---|---|---|")
        for it in c["iterations"]:
            lines.append(
                f"| {it['n']} | {it['hypothesis']} | {it['change']} "
                f"| {it['before']} | {it['after']} | {it['verdict']} |")
        lines.append("")
        lines.append(f"**Conclusion:** {c['conclusion']}")
    extra = log.get("paper_vs_optimized")
    if extra:
        lines.append("")
        lines.append("### Paper-faithful baseline vs beyond-paper optimized")
        lines.append("")
        lines.append("| cell | paper-faithful (native/full attention) | "
                     "routing (paper technique) | beyond-paper notes |")
        lines.append("|---|---|---|---|")
        for row in extra:
            lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def bench_section() -> str:
    path = os.path.join(ROOT, "bench_output.txt")
    lines = ["## §Benchmarks — paper tables 1-7", ""]
    lines.append(
        "`python -m benchmarks.run` measures the step mechanics of every "
        "published config at structure-preserving reduced scale and "
        "reports the paper's value as the target; Table 6 (JSD analysis) "
        "is reproduced outright — it is a mechanism property: "
        "local||local JSD stays low, local||routing approaches the ln2 "
        "bound, routing||routing sits between, exactly the paper's "
        "finding.")
    if os.path.exists(path):
        lines.append("")
        lines.append("```")
        with open(path) as f:
            lines.append(f.read().strip())
        lines.append("```")
    return "\n".join(lines)


def main():
    res = load("dryrun_results.json")
    import sys
    sys.path.insert(0, ROOT)
    from benchmarks import roofline as rl
    rows = rl.build()
    with open(os.path.join(HERE, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    log = load("perf_log.json")
    doc = "\n\n".join([
        "# EXPERIMENTS",
        "Everything below is generated from checked-in measurement "
        "artifacts by `python -m benchmarks.report`; raw records: "
        "`benchmarks/dryrun_results*.json`, `benchmarks/roofline.json`, "
        "`benchmarks/perf_log.json`.",
        dryrun_section(res),
        roofline_section(rows),
        perf_section(log),
        bench_section(),
    ])
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(doc + "\n")
    print(f"EXPERIMENTS.md written ({len(doc)} chars)")


if __name__ == "__main__":
    main()
